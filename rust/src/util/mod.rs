//! Utility substrates: deterministic RNG, JSON, statistics / least squares,
//! CLI parsing, and a mini property-test harness.
//!
//! These fill the roles of `rand`, `serde_json`, `clap`, and `proptest`,
//! which are unavailable in this offline build environment (DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Milliseconds since an arbitrary process-local epoch (monotonic).
pub fn now_ms() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_secs_f64() * 1e3
}
