//! TCP serving front-end: JSON-lines protocol over a leader/dispatcher loop.
//!
//! Production shape without tokio (DESIGN.md §2): a listener thread accepts
//! connections; per-connection threads parse newline-delimited JSON
//! requests into a shared pool; a dispatcher thread wakes every
//! `window_ms`, drains the pool, runs the configured scheduling policy
//! (SLO-aware SA by default), executes batches on instance workers, and
//! replies on each request's channel.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"generate","task":"chat","input_len":120,"max_tokens":40,
//!     "slo":{"kind":"interactive","ttft_ms":10000,"tpot_ms":50},
//!     "prompt":"optional text"}
//! <- {"id":3,"ok":true,"text":"…","e2e_ms":412.5,"ttft_ms":80.1,
//!     "tpot_ms":8.4,"slo_met":true}
//! -> {"op":"stats"}
//! <- {"ok":true,"served":17,"attainment":0.94,"g_req_per_s":1.3,…}
//! -> {"op":"shutdown"}
//! ```

pub mod protocol;

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::coordinator::objective::Evaluator;
use crate::coordinator::policies::Policy;
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::profiler::RequestProfiler;
use crate::coordinator::request::{Completion, Request};
use crate::engine::instance::InstanceHandle;
use crate::engine::EngineRequest;
use crate::metrics::RunMetrics;
use crate::util::json::Json;
use crate::util::rng::Rng;
use protocol::{completion_to_json, parse_generate};

/// A queued request plus its reply channel.
struct PendingReq {
    request: Request,
    reply: Sender<Json>,
}

/// Server configuration.
pub struct ServerConfig {
    /// Scheduling policy for each dispatch window.
    pub policy: Policy,
    /// Predictor used by the priority mapper.
    pub predictor: LatencyPredictor,
    /// Dispatch window (ms): how long requests pool before scheduling.
    pub window_ms: u64,
    /// Engine batch cap.
    pub max_batch: usize,
    /// Longest (input + output) accepted.
    pub max_total_tokens: usize,
}

struct Shared {
    pool: Mutex<VecDeque<PendingReq>>,
    served: Mutex<Vec<Completion>>,
    next_id: AtomicU64,
    running: AtomicBool,
}

/// Handle to a running server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
    dispatch_thread: Option<JoinHandle<()>>,
}

/// Start the server on an ephemeral local port with the given instances.
pub fn start(
    cfg: ServerConfig,
    instances: Vec<InstanceHandle>,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shared = Arc::new(Shared {
        pool: Mutex::new(VecDeque::new()),
        served: Mutex::new(Vec::new()),
        next_id: AtomicU64::new(0),
        running: AtomicBool::new(true),
    });

    // ---- acceptor + per-connection readers
    let accept_shared = shared.clone();
    let max_total = cfg.max_total_tokens;
    let accept_thread = std::thread::Builder::new()
        .name("server-accept".into())
        .spawn(move || {
            // Connection threads are detached: they block on client reads
            // and exit when the peer closes or a read times out with the
            // server stopped (joining them here would deadlock shutdown
            // against any still-open client).
            while accept_shared.running.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let sh = accept_shared.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, sh, max_total);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5),
                        );
                    }
                    Err(_) => break,
                }
            }
        })?;

    // ---- dispatcher: window -> schedule -> execute -> reply
    let dispatch_shared = shared.clone();
    let dispatch_thread = std::thread::Builder::new()
        .name("server-dispatch".into())
        .spawn(move || {
            dispatcher_loop(cfg, instances, dispatch_shared);
        })?;

    Ok(ServerHandle {
        addr,
        shared,
        accept_thread: Some(accept_thread),
        dispatch_thread: Some(dispatch_thread),
    })
}

fn handle_connection(
    stream: TcpStream,
    shared: Arc<Shared>,
    max_total_tokens: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Periodic read timeout so idle connections notice server shutdown.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(250)))
        .ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.running.load(Ordering::SeqCst) {
                    continue;
                }
                break;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                send_line(
                    &mut writer,
                    &Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str(format!("bad json: {e}"))),
                    ]),
                )?;
                continue;
            }
        };
        match msg.get("op").as_str() {
            Some("generate") => {
                let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                match parse_generate(&msg, id, max_total_tokens) {
                    Ok(request) => {
                        let (tx, rx) = std::sync::mpsc::channel();
                        shared
                            .pool
                            .lock()
                            .unwrap()
                            .push_back(PendingReq { request, reply: tx });
                        // block this connection until its reply is ready
                        match rx.recv() {
                            Ok(reply) => send_line(&mut writer, &reply)?,
                            Err(_) => {
                                send_line(
                                    &mut writer,
                                    &Json::obj(vec![
                                        ("ok", Json::Bool(false)),
                                        ("error", Json::str("server shutdown")),
                                    ]),
                                )?;
                            }
                        }
                    }
                    Err(e) => send_line(
                        &mut writer,
                        &Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::str(e.to_string())),
                        ]),
                    )?,
                }
            }
            Some("stats") => {
                let served = shared.served.lock().unwrap();
                let m = RunMetrics::from_completions(&served);
                send_line(
                    &mut writer,
                    &Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("served", Json::num(m.n as f64)),
                        ("met", Json::num(m.met as f64)),
                        ("attainment", Json::num(m.attainment())),
                        ("g_req_per_s", Json::num(m.g_req_per_s)),
                        ("avg_latency_ms", Json::num(m.avg_latency_ms())),
                    ]),
                )?;
            }
            Some("shutdown") => {
                shared.running.store(false, Ordering::SeqCst);
                send_line(
                    &mut writer,
                    &Json::obj(vec![("ok", Json::Bool(true))]),
                )?;
                break;
            }
            other => send_line(
                &mut writer,
                &Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    (
                        "error",
                        Json::str(format!("unknown op {other:?}")),
                    ),
                ]),
            )?,
        }
    }
    Ok(())
}

fn send_line(writer: &mut TcpStream, v: &Json) -> Result<()> {
    let mut text = v.to_string_compact();
    text.push('\n');
    writer.write_all(text.as_bytes())?;
    Ok(())
}

fn dispatcher_loop(
    cfg: ServerConfig,
    instances: Vec<InstanceHandle>,
    shared: Arc<Shared>,
) {
    let mut rng = Rng::new(0x5E12_70E);
    let mut profiler = RequestProfiler::new();
    let mut next_instance = 0usize;
    while shared.running.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(cfg.window_ms));
        let mut pending: Vec<PendingReq> = {
            let mut pool = shared.pool.lock().unwrap();
            pool.drain(..).collect()
        };
        if pending.is_empty() {
            continue;
        }
        // predicted output lengths from the profiler (falls back to prior)
        let requests: Vec<Request> =
            pending.iter().map(|p| p.request.clone()).collect();
        let predicted: Vec<usize> = requests
            .iter()
            .map(|r| {
                profiler
                    .predict_output(r.task, &mut rng, cfg.max_total_tokens / 2)
                    .min(r.output_len.max(1))
            })
            .collect();
        let jobs: Vec<crate::coordinator::objective::Job> = requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                crate::coordinator::objective::Job::from_request(
                    i,
                    r,
                    predicted[i],
                )
            })
            .collect();
        let ev = Evaluator::new(&jobs, &cfg.predictor);
        let (schedule, _) = cfg.policy.plan(&ev, cfg.max_batch);
        // dispatch batches round-robin over instances
        for (_, start, size) in schedule.batch_spans() {
            let member_ids: Vec<usize> = schedule.order
                [start..start + size]
                .iter()
                .map(|&j| jobs[j].req_idx)
                .collect();
            let batch: Vec<EngineRequest> = member_ids
                .iter()
                .map(|&i| {
                    let r = &requests[i];
                    EngineRequest {
                        id: r.id,
                        input_len: r.input_len,
                        max_new_tokens: r.output_len,
                        prompt: r.prompt.clone(),
                    }
                })
                .collect();
            let inst = &instances[next_instance % instances.len()];
            next_instance += 1;
            match inst.run_batch(batch) {
                Ok(items) => {
                    for (&i, item) in member_ids.iter().zip(&items) {
                        let req = &requests[i];
                        profiler.observe_output(req.task, item.generated);
                        let completion = Completion {
                            id: req.id,
                            task: req.task,
                            slo: req.slo,
                            input_len: req.input_len,
                            // the server plans at the client's token
                            // budget — that is its output prediction
                            predicted_lo: req.output_len,
                            generated: item.generated,
                            e2e_ms: item.finish_ms - req.arrival_ms,
                            ttft_ms: item.first_token_ms - req.arrival_ms,
                            tpot_ms: item.tpot_ms(),
                            wait_ms: item.start_ms - req.arrival_ms,
                            batch_size: item.batch_size,
                            text: item.text.clone(),
                        };
                        let reply = completion_to_json(&completion);
                        // record BEFORE replying: a client that got its
                        // reply must observe itself in `stats`
                        shared.served.lock().unwrap().push(completion);
                        if let Some(p) = pending
                            .iter_mut()
                            .find(|p| p.request.id == req.id)
                        {
                            let _ = p.reply.send(reply);
                        }
                    }
                }
                Err(e) => {
                    for &i in &member_ids {
                        if let Some(p) = pending
                            .iter_mut()
                            .find(|p| p.request.id == requests[i].id)
                        {
                            let _ = p.reply.send(Json::obj(vec![
                                ("ok", Json::Bool(false)),
                                ("error", Json::str(e.to_string())),
                            ]));
                        }
                    }
                }
            }
        }
    }
}

impl ServerHandle {
    /// Request shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.shared.running.store(false, Ordering::SeqCst);
        if let Some(t) = self.dispatch_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Completions served so far.
    pub fn served(&self) -> usize {
        self.shared.served.lock().unwrap().len()
    }
}

/// Minimal blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request object, wait for one reply line.
    pub fn call(&mut self, msg: &Json) -> Result<Json> {
        let mut text = msg.to_string_compact();
        text.push('\n');
        self.writer.write_all(text.as_bytes())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(anyhow!("connection closed"));
        }
        Json::parse(&line).map_err(|e| anyhow!("bad reply: {e}"))
    }
}
