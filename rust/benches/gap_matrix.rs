//! Optimality-gap bench matrix: SA and index/threshold baselines scored
//! against branch-and-bound certificates across
//! {N, SLO mix, divergence σ, KV mode, KV phase} × seeds.
//!
//! Emits `BENCH_gap.json` (cargo package root): one row per cell plus a
//! summary block CI's gap gate reads (`max_gated_sa_gap` ≤ 0.05 over the
//! rows where SA and the bound optimize the same problem). Matrix size is
//! env-tunable for CI: `GAP_NS`, `GAP_SEEDS`, `GAP_MAX_BATCH`,
//! `GAP_NODE_BUDGET`, `GAP_SIGMAS` (see [`slo_serve::bench::gap`]).
//!
//!     cargo bench --bench gap_matrix

use slo_serve::bench::gap::{
    render_table, report_json, run_matrix, summarize, GapConfig,
};

fn main() {
    let cfg = GapConfig::from_env();
    println!("== optimality-gap matrix: policies vs certified bounds ==");
    println!(
        "axes: N={:?} seeds={} mixes={} sigmas={:?} kv-variants={} \
         max_batch={} node_budget={}\n",
        cfg.ns,
        cfg.seeds.len(),
        cfg.mixes.len(),
        cfg.sigmas,
        cfg.kvs.len(),
        cfg.max_batch,
        cfg.node_budget
    );

    let rows = run_matrix(&cfg);
    print!("{}", render_table(&rows));
    let s = summarize(&rows);
    println!(
        "\n{} cells: {} closed exactly, max gated SA gap {:.3}%, \
         index policy matched/beat SA in {}",
        s.cells,
        s.closed,
        100.0 * s.max_gated_sa_gap,
        s.index_beats_sa_cells
    );

    let doc = report_json(&cfg, &rows);
    std::fs::write("BENCH_gap.json", format!("{}\n", doc.to_string_pretty()))
        .expect("writing BENCH_gap.json");
    println!("wrote BENCH_gap.json");
}
