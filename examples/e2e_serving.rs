//! End-to-end serving driver (the repository's validation workload).
//!
//! Proves all three layers compose on a REAL model: the Pallas-kernel
//! TinyLM is AOT-lowered to HLO text (L1+L2, `make artifacts`), loaded by
//! the Rust PJRT runtime, and served as batched requests under two
//! schedulers:
//!
//!   1. FCFS static batching (the no-SLO-awareness baseline), and
//!   2. the paper's simulated-annealing SLO-aware scheduler,
//!
//! with the latency predictor FITTED FROM THE REAL ENGINE's own profiling
//! rounds (paper §5.1 workflow) and SLOs derived as 10× the solo request
//! latency (paper §5.1). Reports attainment / average latency / G for
//! both. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use anyhow::Result;

use slo_serve::config::SloTargets;
use slo_serve::coordinator::objective::Evaluator;
use slo_serve::coordinator::policies::Policy;
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::profiler::RequestProfiler;
use slo_serve::coordinator::request::{Completion, Request, TaskType};
use slo_serve::engine::real::RealEngine;
use slo_serve::engine::{Engine, EngineRequest};
use slo_serve::metrics::{fmt, RunMetrics, Table};
use slo_serve::util::rng::Rng;
use slo_serve::workload::dataset::RequestFactory;

const MAX_BATCH: usize = 4;
const N_REQUESTS: usize = 16;
const MAX_INPUT: usize = 192;
const MAX_OUTPUT: usize = 48;

/// Profile the real engine: measure prefill/decode at several (batch, len)
/// points and fit Eq. 14–15 (paper §5.1 profiling rounds).
fn profile_engine(engine: &mut RealEngine) -> Result<LatencyPredictor> {
    let mut profiler = RequestProfiler::new();
    println!("compiling executables (warmup, excluded from profiling)...");
    for &b in &[1usize, 2, 4] {
        engine.warmup(b)?;
    }
    println!("profiling the real engine...");
    let mut uid = 9_000_000u64;
    for rep in 0..3 {
        for &b in &[1usize, 2, 4] {
            for &len in &[24usize, 56, 120, 240] {
                let batch: Vec<EngineRequest> = (0..b)
                    .map(|_| {
                        uid += 1;
                        EngineRequest {
                            id: uid,
                            input_len: len,
                            max_new_tokens: 16,
                            prompt: None,
                        }
                    })
                    .collect();
                let items = engine.run_batch(&batch)?;
                if rep == 0 {
                    continue; // first pass warms caches/allocators
                }
                for item in &items {
                    let prefill_ms = item.first_token_ms - item.start_ms;
                    profiler.observe_prefill(b, len, prefill_ms);
                    if item.generated > 1 {
                        profiler.observe_decode(b, len + 4, item.tpot_ms());
                    }
                }
            }
        }
    }
    let (predictor, r2p, r2d) = profiler
        .fit_predictor()
        .ok_or_else(|| anyhow::anyhow!("degenerate profiling fit"))?;
    println!("fitted predictor: R²(prefill)={r2p:.3} R²(decode)={r2d:.3}");
    println!(
        "  prefill: α={:.4} β={:.2} γ={:.4} δ={:.2}",
        predictor.prefill.alpha, predictor.prefill.beta,
        predictor.prefill.gamma, predictor.prefill.delta
    );
    println!(
        "  decode:  α={:.5} β={:.3} γ={:.5} δ={:.2}",
        predictor.decode.alpha, predictor.decode.beta,
        predictor.decode.gamma, predictor.decode.delta
    );
    Ok(predictor)
}

/// Derive SLO targets from the engine's measured solo latency (paper §5.1:
/// e2e SLO = 10× the solo processing time of an average request).
fn derive_slos(predictor: &LatencyPredictor) -> SloTargets {
    let code_solo = predictor.predict(1, 150, 36);
    let chat_solo = predictor.predict(1, 60, 24);
    SloTargets {
        // paper §5.1 sets e2e SLO at 10× solo processing time; this CPU
        // testbed's wall-clock noise is far higher than a dedicated GPU's,
        // so we tighten to 6× to keep the contended-but-feasible regime
        // where ordering matters, and keep the paper's 1:3 TTFT/e2e ratio.
        code_e2e_ms: 6.0 * code_solo.exec_ms,
        chat_ttft_ms: 2.0 * code_solo.exec_ms,
        chat_tpot_ms: 6.0 * chat_solo.tpot_ms,
    }
}

fn execute(
    engine: &mut RealEngine,
    requests: &[Request],
    plan: &slo_serve::coordinator::objective::Schedule,
    epoch_ms: f64,
) -> Result<Vec<Completion>> {
    let mut completions = Vec::new();
    for (_, start, size) in plan.batch_spans() {
        let members: Vec<usize> = plan.order[start..start + size].to_vec();
        let batch: Vec<EngineRequest> = members
            .iter()
            .map(|&i| {
                let r = &requests[i];
                EngineRequest {
                    id: r.id,
                    input_len: r.input_len,
                    max_new_tokens: r.output_len,
                    prompt: None,
                }
            })
            .collect();
        let items = engine.run_batch(&batch)?;
        for (&i, item) in members.iter().zip(&items) {
            let r = &requests[i];
            completions.push(Completion {
                id: r.id,
                task: r.task,
                slo: r.slo,
                input_len: r.input_len,
                predicted_lo: r.output_len,
                generated: item.generated,
                e2e_ms: item.finish_ms - epoch_ms,
                ttft_ms: item.first_token_ms - epoch_ms,
                tpot_ms: item.tpot_ms(),
                wait_ms: item.start_ms - epoch_ms,
                batch_size: item.batch_size,
                text: None,
            });
        }
    }
    Ok(completions)
}

fn report(label: &str, completions: &[Completion]) -> RunMetrics {
    let m = RunMetrics::from_completions(completions);
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["scheduler".into(), label.into()]);
    t.row(vec![
        "attainment".into(),
        format!("{}/{} ({:.0}%)", m.met, m.n, m.attainment() * 100.0),
    ]);
    t.row(vec!["avg latency (ms)".into(), fmt(m.avg_latency_ms())]);
    t.row(vec![
        "p99 e2e (ms)".into(),
        fmt(m.e2e.as_ref().map_or(0.0, |s| s.p99)),
    ]);
    t.row(vec!["G (req/s)".into(), format!("{:.4}", m.g_req_per_s)]);
    for (task, att, n) in RunMetrics::attainment_by_task(completions) {
        t.row(vec![
            format!("  {} attainment", task.name()),
            format!("{:.0}% of {n}", att * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!();
    m
}

fn main() -> Result<()> {
    println!("=== e2e_serving: TinyLM on PJRT CPU, SA vs FCFS ===\n");
    let mut engine = RealEngine::load("artifacts")?;
    println!(
        "loaded {}: {} params buckets, max batch {}, max tokens {}\n",
        engine.name(),
        engine.spec().n_layers,
        engine.max_batch(),
        engine.max_total_tokens()
    );

    // ---- 1. profiling rounds on the real engine
    let predictor = profile_engine(&mut engine)?;
    let slos = derive_slos(&predictor);
    println!(
        "\nderived SLOs: code e2e {:.0} ms | chat TTFT {:.0} ms, TPOT {:.1} ms\n",
        slos.code_e2e_ms, slos.chat_ttft_ms, slos.chat_tpot_ms
    );

    // ---- 2. workload: mixed chat+code wave scaled to the model
    let mut factory =
        RequestFactory::new(11, slos).with_caps(MAX_INPUT, MAX_OUTPUT);
    let requests = factory.mixed_wave(N_REQUESTS);

    // predicted output lengths from per-task history (profiler path)
    let mut profiler = RequestProfiler::new();
    let mut hist = RequestFactory::new(99, slos).with_caps(MAX_INPUT, MAX_OUTPUT);
    for task in [TaskType::Chat, TaskType::Code] {
        for r in hist.uniform_wave(100, task) {
            profiler.observe_output(task, r.output_len);
        }
    }
    let mut rng = Rng::new(11);
    let predicted: Vec<usize> = requests
        .iter()
        .map(|r| profiler.predict_output(r.task, &mut rng, MAX_OUTPUT))
        .collect();
    let jobs: Vec<slo_serve::coordinator::objective::Job> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            slo_serve::coordinator::objective::Job::from_request(
                i, r, predicted[i],
            )
        })
        .collect();
    let ev = Evaluator::new(&jobs, &predictor);

    // ---- 3. FCFS baseline
    let (fcfs_plan, _) = Policy::Fcfs.plan(&ev, MAX_BATCH);
    let epoch = engine.now_ms();
    let fcfs_completions = execute(&mut engine, &requests, &fcfs_plan, epoch)?;
    let fcfs = report("fcfs (static batching)", &fcfs_completions);

    // ---- 4. SLO-aware simulated annealing
    let (sa_plan, stats) = Policy::SloAware(SaParams {
        max_batch: MAX_BATCH,
        seed: 11,
        ..Default::default()
    })
    .plan(&ev, MAX_BATCH);
    if let Some(s) = stats {
        println!(
            "SA search: {} evals, {} accepted, overhead {:.2} ms{}\n",
            s.evals,
            s.accepted,
            s.overhead_ms,
            if s.early_exit { " (early exit)" } else { "" }
        );
    }
    let epoch = engine.now_ms();
    let sa_completions = execute(&mut engine, &requests, &sa_plan, epoch)?;
    let sa = report("slo-aware simulated annealing", &sa_completions);

    println!(
        "summary: attainment {} -> {} | avg latency {:.0} -> {:.0} ms | G {:.4} -> {:.4}",
        fcfs.met, sa.met,
        fcfs.avg_latency_ms(), sa.avg_latency_ms(),
        fcfs.g_req_per_s, sa.g_req_per_s
    );
    println!("e2e_serving OK");
    Ok(())
}
