//! Paper Table 1: priority-mapping overhead — simulated annealing vs
//! exhaustive search at request numbers 4/6/8/10 (max batch size 1).
//!
//! Absolute times differ from the paper (Rust vs the authors' 1.7k-line
//! Python; our testbed); the *shape* — SA flat vs exhaustive exploding
//! factorially — is the claim under test.

use slo_serve::bench::time_ms;
use slo_serve::coordinator::objective::{Evaluator, Job};
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{priority_mapping, SaParams};
use slo_serve::coordinator::priority::exhaustive::exhaustive_mapping;
use slo_serve::coordinator::request::Slo;
use slo_serve::metrics::Table;
use slo_serve::util::rng::Rng;

fn jobs(n: usize, seed: u64) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Job {
            req_idx: i,
            input_len: rng.range(50, 1500) as usize,
            output_len: rng.range(20, 400) as usize,
            slo: Slo::E2e { e2e_ms: rng.uniform(3_000.0, 30_000.0) },
        })
        .collect()
}

fn main() {
    println!("== Table 1: priority-mapping algorithm overhead (seconds) ==\n");
    let pred = LatencyPredictor::paper_table2();
    let mut t = Table::new(&[
        "request number", "SA (s)", "exhaustive (s)", "exhaustive evals",
    ]);
    for &n in &[4usize, 6, 8, 10] {
        let js = jobs(n, n as u64);
        let ev = Evaluator::new(&js, &pred);
        let sa_params = SaParams { max_batch: 1, seed: 7, ..Default::default() };
        let sa_ms = time_ms(1, 5, || {
            let _ = priority_mapping(&ev, &sa_params);
        });
        let mut evals = 0usize;
        let ex_ms = time_ms(0, 1, || {
            evals = exhaustive_mapping(&ev, 1).unwrap().evals;
        });
        t.row(vec![
            n.to_string(),
            format!("{:.5}", sa_ms / 1e3),
            format!("{:.5}", ex_ms / 1e3),
            evals.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper shape: SA ~flat (0.00023→0.00048 s), exhaustive exponential");
    println!("(0.0012 s @4 → 287 s @10 in the paper's Python implementation).");
}
