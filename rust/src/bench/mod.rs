//! Shared experiment runner for the benchmark suite (criterion substitute).
//!
//! Every paper table/figure bench (rust/benches/*.rs) composes the same
//! pipeline, faithful to the paper's §5.1 workflow:
//!
//! 1. **Profiling rounds** — sample the engine's (noisy) latencies over a
//!    grid of batch sizes and lengths, then least-squares fit the
//!    scheduler's predictor (the scheduler never sees the simulator's
//!    ground-truth coefficients).
//! 2. **Output-length history** — warm the profiler's per-task Gaussians
//!    with completed-request lengths.
//! 3. **Wave generation** — mixed 50/50 chat+code dataset, seeded.
//! 4. **Schedule + execute** — the selected policy against per-instance
//!    engines, measured metrics out.

pub mod gap;

use anyhow::{anyhow, Result};

use crate::config::profiles::{by_name, HardwareProfile};
use crate::config::RunConfig;
use crate::coordinator::objective::Evaluator;
use crate::coordinator::policies::Policy;
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::{SaParams, SearchStats};
use crate::coordinator::profiler::RequestProfiler;
use crate::coordinator::request::{Request, TaskType};
use crate::coordinator::scheduler::{assign_instances, InstanceInfo, InstancePlan};
use crate::coordinator::{execute_fcfs_continuous, execute_plans, predict_outputs};
use crate::engine::sim::SimEngine;
use crate::engine::Engine;
use crate::metrics::RunMetrics;
use crate::util::rng::Rng;
use crate::workload::dataset::RequestFactory;

/// Result of one scenario run.
#[derive(Debug, Clone)]
pub struct BenchRun {
    pub metrics: RunMetrics,
    /// Scheduling overhead (priority mapping + assignment), ms.
    pub sched_overhead_ms: f64,
    /// Search stats of the priority mapper (when the policy has one).
    pub search_stats: Option<SearchStats>,
}

/// Simulate the paper's profiling rounds against a hardware profile and fit
/// the scheduler's latency predictor (§5.1: batch 1–32, lengths 100–8000).
pub fn fit_predictor_from_profile(
    profile: &HardwareProfile,
    seed: u64,
) -> LatencyPredictor {
    let mut profiler = RequestProfiler::new();
    let mut rng = Rng::new(seed ^ 0xF17);
    for &b in &[1usize, 2, 4, 8, 16, 32] {
        for &l in &[100usize, 250, 500, 1000, 2000, 4000, 8000] {
            for _ in 0..3 {
                let noise_p = rng.gaussian(1.0, profile.noise_std).max(0.05);
                let noise_d = rng.gaussian(1.0, profile.noise_std).max(0.05);
                profiler.observe_prefill(
                    b,
                    l,
                    profile.truth.prefill.eval(b as f64, l as f64) * noise_p,
                );
                profiler.observe_decode(
                    b,
                    l,
                    profile.truth.decode.eval(b as f64, l as f64) * noise_d,
                );
            }
        }
    }
    profiler
        .fit_predictor()
        .map(|(p, _, _)| p)
        .unwrap_or(profile.truth)
}

/// Warm a profiler's output-length models with `n` historical completions
/// per task type (drawn from the same dataset distributions).
pub fn warm_output_profiler(seed: u64, n: usize) -> RequestProfiler {
    let mut profiler = RequestProfiler::new();
    let mut factory = RequestFactory::new(
        seed ^ 0x0117_0212,
        crate::config::SloTargets::default(),
    );
    for task in [TaskType::Chat, TaskType::Code] {
        for r in factory.uniform_wave(n, task) {
            profiler.observe_output(task, r.output_len);
        }
    }
    profiler
}

/// Parse a policy name (see [`Policy`]).
pub fn policy_from_name(name: &str, sa: SaParams) -> Result<Policy> {
    Ok(match name {
        "fcfs" => Policy::Fcfs,
        "sjf" => Policy::Sjf,
        "edf" => Policy::Edf,
        "mlfq" => Policy::Mlfq,
        "slack-index" => Policy::SlackIndex,
        "edf-threshold" => Policy::EdfThreshold,
        "slo-aware-sa" => Policy::SloAware(sa),
        "slo-aware-exhaustive" => Policy::Exhaustive,
        other => return Err(anyhow!("unknown policy '{other}'")),
    })
}

/// Build one simulated engine per instance. The engines mirror the
/// scheduler's KV demand model (`cfg.sa.kv.phase`), so a phased-planned
/// wave is admitted against the same occupancy-peak accounting it was
/// planned with (the default `Reserve` keeps the legacy behaviour), and
/// carry the configured output-length divergence model
/// (`cfg.divergence`; `Off` keeps the legacy engines bit for bit).
pub fn sim_engines(
    profile: &HardwareProfile,
    cfg: &RunConfig,
) -> Vec<SimEngine> {
    (0..cfg.n_instances)
        .map(|i| {
            SimEngine::new(
                profile.clone(),
                cfg.max_batch,
                cfg.seed ^ (i as u64).wrapping_mul(0xE5317),
            )
            .with_kv_phase(cfg.sa.kv.phase)
            .with_divergence(cfg.divergence)
        })
        .collect()
}

/// Generate the request wave for a config (the paper's mixed dataset).
pub fn make_wave(cfg: &RunConfig) -> Vec<Request> {
    let mut factory = RequestFactory::new(cfg.seed, cfg.slos);
    factory.mixed_wave(cfg.n_requests)
}

/// Plan a wave with a planned-batch policy across instances.
///
/// Non-SLO-aware policies still need instance assignment; they share the
/// round-robin memory-aware assigner (Algorithm 2 line 4, in Eq. 20 KV
/// blocks) and then order their own instance-local queues. Fails when a
/// request's KV footprint exceeds every instance pool.
pub fn plan_wave(
    requests: &[Request],
    predicted_out: &[usize],
    policy: &Policy,
    predictor: &LatencyPredictor,
    profile: &HardwareProfile,
    cfg: &RunConfig,
) -> Result<(Vec<InstancePlan>, f64, Option<SearchStats>)> {
    let t0 = crate::util::now_ms();
    let block_tokens = match policy {
        Policy::SloAware(sa) => sa.kv.block_tokens,
        _ => crate::coordinator::kv::DEFAULT_BLOCK_TOKENS,
    };
    let instances: Vec<InstanceInfo> = (0..cfg.n_instances)
        .map(|id| InstanceInfo { id, mem_mb: profile.kv_pool_mb })
        .collect();
    let assignment = assign_instances(
        requests,
        predicted_out,
        &instances,
        &profile.mem,
        block_tokens,
    )?;
    let mut plans = Vec::with_capacity(instances.len());
    let mut agg_stats: Option<SearchStats> = None;
    for (inst, req_indices) in assignment.into_iter().enumerate() {
        let jobs: Vec<crate::coordinator::objective::Job> = req_indices
            .iter()
            .map(|&ri| {
                crate::coordinator::objective::Job::from_request(
                    ri,
                    &requests[ri],
                    predicted_out[ri],
                )
            })
            .collect();
        let ev = Evaluator::new(&jobs, predictor);
        let policy_inst = match policy {
            Policy::SloAware(sa) => Policy::SloAware(SaParams {
                seed: sa.seed ^ (inst as u64).wrapping_mul(0x9E3779B9),
                ..*sa
            }),
            p => *p,
        };
        let (schedule, stats) = policy_inst.plan(&ev, cfg.max_batch);
        if let Some(s) = stats {
            agg_stats = Some(match agg_stats {
                None => s,
                Some(prev) => SearchStats {
                    evals: prev.evals + s.evals,
                    accepted: prev.accepted + s.accepted,
                    improved: prev.improved + s.improved,
                    early_exit: prev.early_exit && s.early_exit,
                    overhead_ms: prev.overhead_ms + s.overhead_ms,
                    cpu_ms: prev.cpu_ms + s.cpu_ms,
                    exchanges: prev.exchanges + s.exchanges,
                    // not meaningful summed across instances
                    winner_chain: 0,
                },
            });
        }
        plans.push(InstancePlan {
            instance: inst,
            jobs,
            schedule,
            stats: agg_stats.unwrap_or(SearchStats {
                evals: 0,
                accepted: 0,
                improved: 0,
                early_exit: false,
                overhead_ms: 0.0,
                cpu_ms: 0.0,
                exchanges: 0,
                winner_chain: 0,
            }),
        });
    }
    Ok((plans, crate::util::now_ms() - t0, agg_stats))
}

/// Run a full scenario on the simulated engine fleet.
///
/// `scheduler_predictor`: override the fitted predictor (Fig. 10 study);
/// None fits one from profiling rounds.
pub fn run_scenario_with(
    cfg: &RunConfig,
    scheduler_predictor: Option<LatencyPredictor>,
) -> Result<BenchRun> {
    let profile = by_name(&cfg.profile)
        .ok_or_else(|| anyhow!("unknown profile '{}'", cfg.profile))?;
    let wave = make_wave(cfg);
    let mut engines = sim_engines(&profile, cfg);

    // vLLM-style FCFS baseline = continuous batching, no planning.
    if cfg.policy == "fcfs" {
        let mut profiler = RequestProfiler::new();
        let completions =
            execute_fcfs_continuous(&wave, &mut engines, &mut profiler)?;
        return Ok(BenchRun {
            metrics: RunMetrics::from_completions(&completions),
            sched_overhead_ms: 0.0,
            search_stats: None,
        });
    }

    let predictor = scheduler_predictor
        .unwrap_or_else(|| fit_predictor_from_profile(&profile, cfg.seed));
    let mut profiler = warm_output_profiler(cfg.seed, 200);
    let mut rng = Rng::new(cfg.seed ^ 0x007_FEED);
    let max_out = profile.max_total_tokens / 2;
    let predicted = predict_outputs(
        &wave,
        &profiler,
        cfg.output_pred,
        &mut rng,
        max_out,
    );
    let policy = policy_from_name(&cfg.policy, SaParams {
        max_batch: cfg.max_batch,
        seed: cfg.seed,
        ..cfg.sa
    })?;
    let (plans, overhead_ms, stats) =
        plan_wave(&wave, &predicted, &policy, &predictor, &profile, cfg)?;
    let mut boxed: Vec<Box<dyn Engine + Send>> = engines
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Engine + Send>)
        .collect();
    let completions =
        execute_plans(&wave, &plans, &mut boxed, &mut profiler)?;
    Ok(BenchRun {
        metrics: RunMetrics::from_completions(&completions),
        sched_overhead_ms: overhead_ms,
        search_stats: stats,
    })
}

/// Run a scenario with the default fitted predictor.
pub fn run_scenario(cfg: &RunConfig) -> Result<BenchRun> {
    run_scenario_with(cfg, None)
}

/// Timing helper for algorithm micro-benchmarks (Table 1): run `f` after
/// `warmup` untimed calls, returning per-iteration ms over `iters` runs.
pub fn time_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: &str, n: usize, max_batch: usize) -> RunConfig {
        RunConfig {
            policy: policy.into(),
            n_requests: n,
            max_batch,
            ..Default::default()
        }
    }

    #[test]
    fn fitted_predictor_close_to_truth() {
        let profile = by_name("qwen7b-v100x2-vllm").unwrap();
        let fitted = fit_predictor_from_profile(&profile, 0);
        let rel = (fitted.prefill.alpha - profile.truth.prefill.alpha).abs()
            / profile.truth.prefill.alpha;
        assert!(rel < 0.05, "alpha rel err {rel}");
    }

    #[test]
    fn scenario_runs_for_all_policies() {
        for policy in ["fcfs", "sjf", "edf", "mlfq", "slo-aware-sa"] {
            let run = run_scenario(&cfg(policy, 8, 2)).unwrap();
            assert_eq!(run.metrics.n, 8, "{policy}");
        }
    }

    #[test]
    fn exhaustive_runs_small() {
        let run = run_scenario(&cfg("slo-aware-exhaustive", 5, 2)).unwrap();
        assert_eq!(run.metrics.n, 5);
    }

    #[test]
    fn unknown_policy_rejected() {
        assert!(run_scenario(&cfg("random", 4, 2)).is_err());
    }

    #[test]
    fn sa_beats_fcfs_attainment_with_oracle_outputs() {
        // Across seeds, SA with accurate output-length prediction must beat
        // the FCFS baseline on SLO attainment (the paper's headline; with
        // the noisier profiler-Gaussian predictor individual seeds may
        // regress slightly — §5.2 reports the same).
        let mut sa_met = 0usize;
        let mut fcfs_met = 0usize;
        for seed in 0..5 {
            let mut c = cfg("slo-aware-sa", 10, 2);
            c.seed = seed;
            c.output_pred =
                crate::config::OutputPrediction::Oracle { rel_err: 0.0 };
            // strict SLOs so ordering matters
            c.slos = crate::config::SloTargets::default().scaled(0.4);
            let sa = run_scenario(&c).unwrap();
            let mut f = c.clone();
            f.policy = "fcfs".into();
            let fcfs = run_scenario(&f).unwrap();
            sa_met += sa.metrics.met;
            fcfs_met += fcfs.metrics.met;
        }
        assert!(
            sa_met > fcfs_met,
            "SA Σmet {sa_met} <= FCFS Σmet {fcfs_met}"
        );
    }

    #[test]
    fn time_ms_positive() {
        let mut x = 0u64;
        let ms = time_ms(1, 5, || {
            x = x.wrapping_add(1);
        });
        assert!(ms >= 0.0);
        assert_eq!(x, 6);
    }
}
