//! Property suite for the baseline policy zoo ([`Policy`]):
//!
//! * ordering determinism — sort-based policies are invariant to the
//!   input permutation (same jobs in a different order produce the same
//!   schedule, mapped back through the permutation);
//! * total-order sanity — NaN SLO bounds and zero-coefficient predictors
//!   never panic a comparator (regression pin for the PR 5 `total_cmp`
//!   fix) and still yield valid schedules;
//! * reference agreement — the new index/threshold policies match naive
//!   brute-force re-implementations at small N (selection-argmin for
//!   `SlackIndex`, direct argmax over static batch sizes for
//!   `EdfThreshold`).

use slo_serve::coordinator::objective::{Evaluator, Job, Schedule};
use slo_serve::coordinator::policies::Policy;
use slo_serve::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
use slo_serve::coordinator::request::Slo;
use slo_serve::util::prop::check;
use slo_serve::util::rng::Rng;

/// Mixed wave with continuous SLO bounds and pairwise-distinct input
/// lengths — every sort key (solo e2e, deadline, slack) is then distinct
/// with probability 1, so permutation-invariance has no tie ambiguity.
fn random_jobs(rng: &mut Rng, n: usize) -> Vec<Job> {
    let mut lens = std::collections::BTreeSet::new();
    while lens.len() < n {
        lens.insert(1 + rng.below(1500));
    }
    let lens: Vec<usize> = lens.into_iter().collect();
    (0..n)
        .map(|i| Job {
            req_idx: i,
            input_len: lens[i],
            output_len: 1 + rng.below(400),
            slo: if rng.chance(0.5) {
                Slo::E2e { e2e_ms: rng.uniform(1_000.0, 60_000.0) }
            } else {
                Slo::Interactive {
                    ttft_ms: rng.uniform(500.0, 15_000.0),
                    tpot_ms: rng.uniform(15.0, 60.0),
                }
            },
        })
        .collect()
}

/// The deadline every EDF-family policy sorts by.
fn deadline(j: &Job) -> f64 {
    match j.slo {
        Slo::E2e { e2e_ms } => e2e_ms,
        Slo::Interactive { ttft_ms, .. } => ttft_ms,
    }
}

#[test]
fn sort_policies_are_permutation_invariant() {
    // Shuffling the input wave must not change what gets scheduled when:
    // position k of the permuted plan names the same job as position k
    // of the original plan. (FCFS is arrival-order by definition and
    // MLFQ is queue-order-sensitive; the sorted policies are the ones
    // that promise input-order independence.)
    let pred = LatencyPredictor::paper_table2();
    check("sorted policies ignore input permutation", 40, |rng| {
        let n = 2 + rng.below(10);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        // perm[k] = original index of the job at permuted position k
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let permuted: Vec<Job> = perm
            .iter()
            .enumerate()
            .map(|(k, &orig)| Job { req_idx: k, ..jobs[orig] })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let ev_p = Evaluator::new(&permuted, &pred);
        for policy in [
            Policy::Sjf,
            Policy::Edf,
            Policy::SlackIndex,
            Policy::EdfThreshold,
        ] {
            let (a, _) = policy.plan(&ev, max_batch);
            let (b, _) = policy.plan(&ev_p, max_batch);
            let mapped: Vec<usize> =
                b.order.iter().map(|&j| perm[j]).collect();
            if mapped != a.order {
                return Err(format!(
                    "{}: order {:?} != mapped {:?} (perm {:?})",
                    policy.name(),
                    a.order,
                    mapped,
                    perm
                ));
            }
            if b.batches != a.batches {
                return Err(format!(
                    "{}: batches {:?} != {:?}",
                    policy.name(),
                    a.batches,
                    b.batches
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn all_policies_total_under_nan_and_zero_predictors() {
    // PR 5 regression pin: every comparator in the policy zoo must be a
    // total order even when the predictor emits NaN/0 latencies or an
    // SLO bound is NaN — no panic, and the plan stays a valid partition.
    let zero = LatencyPredictor::new(PhaseCoeffs::ZERO, PhaseCoeffs::ZERO);
    let nan = LatencyPredictor::new(
        PhaseCoeffs { alpha: f64::NAN, beta: 0.0, gamma: 1.0, delta: 0.0 },
        PhaseCoeffs { alpha: 0.0, beta: f64::NAN, gamma: 0.0, delta: 1.0 },
    );
    let policies = [
        Policy::Fcfs,
        Policy::Sjf,
        Policy::Edf,
        Policy::Mlfq,
        Policy::SlackIndex,
        Policy::EdfThreshold,
    ];
    check("policies total under degenerate predictors", 30, |rng| {
        let n = 1 + rng.below(9);
        let max_batch = 1 + rng.below(4);
        let mut jobs = random_jobs(rng, n);
        // poison one SLO bound with NaN half the time
        if rng.chance(0.5) {
            let k = rng.below(n);
            jobs[k].slo = Slo::E2e { e2e_ms: f64::NAN };
        }
        for pred in [&zero, &nan] {
            let ev = Evaluator::new(&jobs, pred);
            for policy in policies {
                let (s, _) = policy.plan(&ev, max_batch);
                s.validate(max_batch)
                    .map_err(|e| format!("{}: {e}", policy.name()))?;
            }
        }
        Ok(())
    });
}

#[test]
fn slack_index_matches_selection_argmin_reference() {
    // SlackIndex is a sort by (deadline − solo_e2e)/solo_e2e; the naive
    // reference repeatedly extracts the argmin (first index on ties).
    // Stable sort ⇒ the two must agree exactly.
    let pred = LatencyPredictor::paper_table2();
    check("slack-index == selection argmin", 40, |rng| {
        let n = 1 + rng.below(7);
        let max_batch = 1 + rng.below(4);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let slack = |j: usize| {
            let e = ev.solo_e2e_ms(j);
            (deadline(&jobs[j]) - e) / e
        };
        let mut remaining: Vec<usize> = (0..n).collect();
        let mut reference = Vec::with_capacity(n);
        while !remaining.is_empty() {
            let (pos, _) = remaining
                .iter()
                .enumerate()
                .min_by(|(_, &a), (_, &b)| slack(a).total_cmp(&slack(b)))
                .unwrap();
            reference.push(remaining.remove(pos));
        }
        let (s, _) = Policy::SlackIndex.plan(&ev, max_batch);
        if s.order != reference {
            return Err(format!(
                "order {:?} != reference {:?}",
                s.order, reference
            ));
        }
        Ok(())
    });
}

#[test]
fn edf_threshold_matches_direct_argmax_reference() {
    // EdfThreshold = EDF order + the statically-batched G-argmax over
    // k ∈ 1..=max_batch (smallest k on ties). Recompute that argmax
    // directly and compare the chosen schedule.
    let pred = LatencyPredictor::paper_table2();
    check("edf-threshold == direct argmax", 40, |rng| {
        let n = 1 + rng.below(7);
        let max_batch = 1 + rng.below(6);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            deadline(&jobs[a]).total_cmp(&deadline(&jobs[b]))
        });
        let mut best: Option<(Schedule, f64)> = None;
        for k in 1..=max_batch {
            let s = Schedule::from_order(order.clone(), k);
            let g = ev.eval(&s).g;
            let better = match &best {
                None => true,
                Some((_, bg)) => g > *bg,
            };
            if better {
                best = Some((s, g));
            }
        }
        let (reference, g_ref) = best.unwrap();
        let (s, stats) = Policy::EdfThreshold.plan(&ev, max_batch);
        let stats = stats.ok_or("edf-threshold must report stats")?;
        if stats.evals != max_batch {
            return Err(format!(
                "evals {} != batch sizes tried {max_batch}",
                stats.evals
            ));
        }
        if s.order != reference.order || s.batches != reference.batches {
            return Err(format!(
                "schedule {:?}/{:?} != reference {:?}/{:?} (G {g_ref})",
                s.order, s.batches, reference.order, reference.batches
            ));
        }
        // the threshold search dominates plain EDF by construction
        let (edf, _) = Policy::Edf.plan(&ev, max_batch);
        let (g_thr, g_edf) = (ev.eval(&s).g, ev.eval(&edf).g);
        if g_thr < g_edf - 1e-12 {
            return Err(format!("threshold G {g_thr} below EDF G {g_edf}"));
        }
        Ok(())
    });
}
