//! Async streaming front door walkthrough: a 2-shard [`FrontDoor`] over
//! simulated engines, one streaming submission (token events printed as
//! they arrive), a burst of plain submissions, and the aggregate serving
//! stats.
//!
//! ```sh
//! cargo run --example front_door
//! ```

use anyhow::Result;
use slo_serve::config::profiles::by_name;
use slo_serve::config::SloTargets;
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::Engine;
use slo_serve::server::{FrontDoor, FrontDoorConfig, StreamEvent};
use slo_serve::workload::dataset::RequestFactory;

fn main() -> Result<()> {
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let seed = 42u64;

    let mut cfg = FrontDoorConfig::new(
        profile.truth,
        profile.max_total_tokens,
    );
    cfg.shards = 2;
    cfg.queue_depth = 64;
    cfg.stream_tokens = true;
    cfg.sa.max_batch = 4;
    cfg.sa.seed = seed;
    let engines: Vec<Box<dyn Engine + Send>> = (0..2)
        .map(|s| {
            Box::new(SimEngine::new(profile.clone(), 4, seed ^ s))
                as Box<dyn Engine + Send>
        })
        .collect();
    let door = FrontDoor::start(cfg, engines)?;

    let mut factory =
        RequestFactory::new(seed, SloTargets::default().scaled(4.0));
    let mut wave = factory.mixed_wave(32);

    // One streaming client: watch its tokens arrive.
    let streamed = wave.pop().unwrap();
    let stream = door
        .submit(0, streamed, true)
        .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?;
    println!("streaming request id={} -> shard {}", stream.id, stream.shard);

    // The rest submit fire-and-forget across 32 sessions.
    let handles: Vec<_> = wave
        .into_iter()
        .enumerate()
        .map(|(i, r)| door.submit(1 + i as u64, r, false).unwrap())
        .collect();

    let mut tokens = 0usize;
    while let Some(ev) = stream.next_event() {
        match ev {
            StreamEvent::Admitted { shard, queue_ms, .. } => {
                println!("  admitted on shard {shard} after {queue_ms:.2} ms in queue");
            }
            StreamEvent::Token { index, t_ms, .. } => {
                tokens += 1;
                if index < 3 {
                    println!("  token {index} at engine t={t_ms:.1} ms");
                }
            }
            StreamEvent::Done { completion, .. } => {
                println!(
                    "  done: {} tokens, e2e {:.1} ms, ttft {:.1} ms ({} total token events)",
                    completion.generated,
                    completion.e2e_ms,
                    completion.ttft_ms,
                    tokens
                );
                break;
            }
            StreamEvent::Failed { error, .. } => {
                println!("  failed: {error}");
                break;
            }
        }
    }

    for h in handles {
        h.wait_done()?;
    }
    assert!(door.wait_drained(60_000));
    door.shutdown();

    let stats = door.stats_json();
    println!(
        "served {} / accepted {} | attainment {:.3} | handoffs {} | p99 admission {:.2} ms",
        stats.get("served").as_usize().unwrap(),
        stats.get("accepted").as_usize().unwrap(),
        stats.get("attainment").as_f64().unwrap(),
        stats.get("handoffs").as_usize().unwrap(),
        stats.get("admission_ms").get("p99").as_f64().unwrap(),
    );
    Ok(())
}
