//! slo-serve CLI: leader entrypoint for the SLO-aware serving system.
//!
//! Subcommands:
//!   run        — run a scheduling scenario on the simulated fleet
//!   online     — online wave admission over a timed arrival trace
//!   serve      — async streaming front door (sharded controllers + TCP reactor)
//!   bench-http — in-process open-loop serving load test (JSON report)
//!   gap        — optimality-gap matrix vs branch-and-bound certificates
//!   profile    — profiling rounds + least-squares fit (paper Table 2)
//!   profiles   — list built-in hardware profiles
//!   help       — this text

use anyhow::{anyhow, Result};

use slo_serve::bench;
use slo_serve::config::profiles;
use slo_serve::config::RunConfig;
use slo_serve::coordinator::kv::{KvConfig, KvMode, KvPhaseModel};
use slo_serve::coordinator::online::{
    run_online_fleet_migrating, run_online_fleet_opts, OnlineOpts,
    ReplanStrategy,
};
use slo_serve::coordinator::predict_outputs;
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::request::TaskType;
use slo_serve::coordinator::predictor::quantile_multiplier;
use slo_serve::engine::sim::{
    DivergenceModel, PreemptConfig, PreemptMode, SimEngine,
};
use slo_serve::engine::Engine;
use slo_serve::metrics::{fmt, RunMetrics, Table};
use slo_serve::server;
use slo_serve::util::cli::{render_help, Args, OptSpec};
use slo_serve::util::rng::Rng;
use slo_serve::workload::trace::{ArrivalProcess, TraceSpec};
use slo_serve::workload::RequestFactory;

fn run_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "JSON config file", default: Some("") },
        OptSpec { name: "policy", help: "fcfs|sjf|edf|mlfq|slo-aware-sa|slo-aware-exhaustive", default: Some("slo-aware-sa") },
        OptSpec { name: "profile", help: "hardware profile name", default: Some("qwen7b-v100x2-vllm") },
        OptSpec { name: "requests", help: "wave size", default: Some("10") },
        OptSpec { name: "max-batch", help: "engine batch cap", default: Some("4") },
        OptSpec { name: "instances", help: "instance count", default: Some("1") },
        OptSpec { name: "seed", help: "rng seed", default: Some("42") },
        OptSpec { name: "slo-scale", help: "scale all SLO bounds", default: Some("1.0") },
        OptSpec { name: "output-pred", help: "profiler | oracle:<rel_err>", default: Some("profiler") },
        OptSpec { name: "kv", help: "off | hard | soft:<weight> (Eq. 20 pool from the profile)", default: Some("off") },
        OptSpec { name: "kv-phase", help: "reserve | phased (batch KV demand model under --kv)", default: Some("reserve") },
        OptSpec { name: "divergence", help: "off | lognormal:<σ> | quantile-trace:<σ> (actual-vs-predicted output lengths)", default: Some("off") },
        OptSpec { name: "kv-quantile", help: "output-length quantile KV reserves at (needs --kv and a --divergence σ; 0.5 = mean column)", default: Some("0.5") },
        OptSpec { name: "chains", help: "parallel-tempering chains per instance (1 = the single-chain search, bit for bit)", default: Some("1") },
        OptSpec { name: "exchange-period", help: "temperature levels between tempering best-exchanges", default: Some("4") },
    ]
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &run_specs())?;
    let mut cfg = if args.str("config").is_empty() {
        RunConfig::default()
    } else {
        RunConfig::from_file(&args.str("config"))?
    };
    cfg.policy = args.str("policy");
    cfg.profile = args.str("profile");
    cfg.n_requests = args.usize("requests")?;
    cfg.max_batch = args.usize("max-batch")?;
    cfg.n_instances = args.usize("instances")?;
    cfg.seed = args.u64("seed")?;
    cfg.sa.chains = args.usize("chains")?.max(1);
    cfg.sa.exchange_period = args.usize("exchange-period")?.max(1);
    cfg.slos = cfg.slos.scaled(args.f64("slo-scale")?);
    let op = args.str("output-pred");
    cfg.output_pred = if op == "profiler" {
        slo_serve::config::OutputPrediction::Profiler
    } else if let Some(err) = op.strip_prefix("oracle:") {
        slo_serve::config::OutputPrediction::Oracle { rel_err: err.parse().unwrap_or(0.0) }
    } else {
        return Err(anyhow!("bad --output-pred {op}"));
    };
    let kv_spec = args.str("kv");
    let kv_phase = parse_kv_phase(&args.str("kv-phase"))?;
    cfg.divergence = DivergenceModel::parse(&args.str("divergence"))
        .map_err(|e| anyhow!(e))?;
    if kv_spec != "off" {
        // KV enforcement lives in the SA search; for baseline policies the
        // flag would silently do nothing — refuse instead of misleading.
        if cfg.policy != "slo-aware-sa" {
            return Err(anyhow!(
                "--kv {kv_spec} requires --policy slo-aware-sa (the \
                 baselines do not consult the Eq. 20 pool)"
            ));
        }
        let profile = profiles::by_name(&cfg.profile)
            .ok_or_else(|| anyhow!("unknown profile '{}'", cfg.profile))?;
        cfg.sa.kv = parse_kv(&kv_spec, &profile)?.with_phase(kv_phase);
        cfg.sa.kv = cfg.sa.kv.with_lo_mult(parse_kv_quantile(
            args.f64("kv-quantile")?,
            cfg.divergence,
        )?);
    } else if kv_phase != KvPhaseModel::Reserve {
        return Err(anyhow!(
            "--kv-phase phased needs a binding pool: pass --kv hard or \
             --kv soft:<w> as well"
        ));
    } else if args.f64("kv-quantile")? != 0.5 {
        return Err(anyhow!(
            "--kv-quantile needs a binding pool: pass --kv hard or \
             --kv soft:<w> as well"
        ));
    }
    let run = bench::run_scenario(&cfg)?;
    let m = &run.metrics;
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["policy".into(), cfg.policy.clone()]);
    t.row(vec!["profile".into(), cfg.profile.clone()]);
    t.row(vec!["requests".into(), m.n.to_string()]);
    t.row(vec!["slo_met".into(), m.met.to_string()]);
    t.row(vec!["attainment".into(), fmt(m.attainment())]);
    t.row(vec!["avg_latency_ms".into(), fmt(m.avg_latency_ms())]);
    t.row(vec!["G (req/s)".into(), fmt(m.g_req_per_s)]);
    t.row(vec!["sched_overhead_ms".into(), fmt(run.sched_overhead_ms)]);
    print!("{}", t.render());
    Ok(())
}

fn online_specs() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "profile",
            help: "hardware profile name",
            default: Some("qwen7b-v100x2-vllm"),
        },
        OptSpec { name: "requests", help: "trace length", default: Some("64") },
        OptSpec { name: "max-batch", help: "engine batch cap", default: Some("4") },
        OptSpec { name: "instances", help: "instance count", default: Some("1") },
        OptSpec {
            name: "seed",
            help: "rng seed (trace + search + noise)",
            default: Some("42"),
        },
        OptSpec {
            name: "slo-scale",
            help: "scale all SLO bounds",
            default: Some("1.0"),
        },
        OptSpec {
            name: "arrival",
            help: "concurrent | poisson:RPS | bursty:B:PERIOD_MS | \
                   onoff:RPS:ON_MS:OFF_MS",
            default: Some("poisson:8"),
        },
        OptSpec {
            name: "replan",
            help: "warm | cold | compare",
            default: Some("compare"),
        },
        OptSpec {
            name: "kv",
            help: "off | hard | soft:<weight> (Eq. 20 pool from the profile)",
            default: Some("off"),
        },
        OptSpec {
            name: "kv-phase",
            help: "reserve | phased (batch KV demand model under --kv)",
            default: Some("reserve"),
        },
        OptSpec {
            name: "compact",
            help: "compact dispatched batches out of the controller (0|1)",
            default: Some("0"),
        },
        OptSpec {
            name: "arrival-aware",
            help: "evaluate the objective on the arrival-aware timeline \
                   (idle gaps + per-job arrival offsets) (0|1)",
            default: Some("0"),
        },
        OptSpec {
            name: "divergence",
            help: "off | lognormal:<σ> | quantile-trace:<σ> \
                   (actual-vs-predicted output lengths in the engine)",
            default: Some("off"),
        },
        OptSpec {
            name: "replan-drift-ms",
            help: "warm-replan when |measured − predicted| prefix-end \
                   drift reaches this many ms (0 = off)",
            default: Some("0"),
        },
        OptSpec {
            name: "chains",
            help: "parallel-tempering chains per instance (1 = the \
                   single-chain search, bit for bit)",
            default: Some("1"),
        },
        OptSpec {
            name: "exchange-period",
            help: "temperature levels between tempering best-exchanges",
            default: Some("4"),
        },
        OptSpec {
            name: "adaptive-budget",
            help: "size each replan's SA iteration budget to the next \
                   predicted dispatch gap (0|1)",
            default: Some("0"),
        },
        OptSpec {
            name: "kv-quantile",
            help: "output-length quantile KV reserves at (needs --kv and \
                   a --divergence σ; 0.5 = mean column)",
            default: Some("0.5"),
        },
        OptSpec {
            name: "preempt",
            help: "off | recompute | swap (on pool exhaustion suspend the \
                   SLO-slackest member instead of truncating it)",
            default: Some("off"),
        },
        OptSpec {
            name: "kv-swap-gbps",
            help: "host↔device link bandwidth for --preempt swap (GB/s)",
            default: Some("8"),
        },
        OptSpec {
            name: "kv-host-blocks",
            help: "host swap-buffer capacity in KV blocks (--preempt swap; \
                   a full buffer degrades to recompute)",
            default: Some("1024"),
        },
        OptSpec {
            name: "migrate",
            help: "shed deferred work from saturated instances to the \
                   least-loaded peer's wave queue (0|1; needs --kv and \
                   ≥ 2 instances to ever fire)",
            default: Some("0"),
        },
        OptSpec {
            name: "chunk-tokens",
            help: "off | <N>: split each prefill into N-token chunks in \
                   the engine and price per-member first tokens in the \
                   search (off = whole-prompt prefill, bit-identical to \
                   the unchunked stack)",
            default: Some("off"),
        },
        OptSpec {
            name: "window",
            help: "sliding-window SA: restrict moves to the next W \
                   undispatched batches (0 = whole-schedule search)",
            default: Some("0"),
        },
    ]
}

/// Parse `--chunk-tokens off|<N>` into the engine/evaluator chunk size
/// (0 = whole-prompt prefill — the byte-for-byte default, invariant 15).
fn parse_chunk_tokens(spec: &str) -> Result<usize> {
    if spec == "off" {
        return Ok(0);
    }
    let n: usize = spec
        .parse()
        .map_err(|_| anyhow!("bad --chunk-tokens {spec} (off|<tokens>)"))?;
    if n == 0 {
        return Err(anyhow!(
            "--chunk-tokens must be positive (or 'off' for whole-prompt \
             prefill)"
        ));
    }
    Ok(n)
}

/// Resolve `--kv-quantile <q>` into the [`KvConfig::with_lo_mult`]
/// multiplier: `exp(σ·Φ⁻¹(q))` using the divergence model's σ as the
/// operator's declared output-length uncertainty. `q = 0.5` is the mean
/// column (multiplier exactly 1 — the pre-quantile behaviour); any other
/// quantile needs a positive divergence σ to be meaningful.
fn parse_kv_quantile(q: f64, divergence: DivergenceModel) -> Result<f64> {
    if !(0.5..1.0).contains(&q) {
        // below the median the multiplier would be < 1 and KvConfig
        // clamps it back to the mean column — refuse loudly instead of
        // silently ignoring the request.
        return Err(anyhow!(
            "--kv-quantile must be in [0.5, 1) — reservations never \
             shrink below the prediction — got {q}"
        ));
    }
    if q == 0.5 {
        return Ok(1.0);
    }
    let sigma = divergence.sigma();
    if sigma <= 0.0 {
        return Err(anyhow!(
            "--kv-quantile {q} needs an output-length uncertainty: pass \
             --divergence lognormal:<σ> or quantile-trace:<σ> as well"
        ));
    }
    Ok(quantile_multiplier(sigma, q))
}

/// Parse `--kv-phase reserve|phased`.
fn parse_kv_phase(spec: &str) -> Result<KvPhaseModel> {
    match spec {
        "reserve" => Ok(KvPhaseModel::Reserve),
        "phased" => Ok(KvPhaseModel::Phased),
        other => Err(anyhow!("bad --kv-phase {other} (reserve|phased)")),
    }
}

/// Parse `--kv off|hard|soft:<w>` into a [`KvConfig`] over the profile's
/// Eq. 20 pool (μ·pool_mb/σ tokens at the engine's 16-token blocks).
fn parse_kv(
    spec: &str,
    profile: &slo_serve::config::profiles::HardwareProfile,
) -> Result<KvConfig> {
    let mode = match spec {
        "off" => return Ok(KvConfig::UNLIMITED),
        "hard" => KvMode::Hard,
        other => match other.strip_prefix("soft:") {
            Some(w) => {
                let weight: f64 = w
                    .parse()
                    .map_err(|_| anyhow!("bad soft weight in --kv {other}"))?;
                if !weight.is_finite() || weight <= 0.0 {
                    return Err(anyhow!(
                        "--kv soft weight must be finite and > 0, got {weight}"
                    ));
                }
                KvMode::Soft { weight }
            }
            None => return Err(anyhow!("bad --kv {spec} (off|hard|soft:<w>)")),
        },
    };
    Ok(KvConfig::from_pool_mb(profile.kv_pool_mb, &profile.mem, 16, mode))
}

/// Online wave admission over a timed arrival trace: warm-started SA
/// replanning on every admission, per-SLO-class attainment + replanning
/// overhead out (ISSUE 2's serving path; `compare` also runs the
/// cold-restart ablation at the same iteration budget).
fn cmd_online(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &online_specs())?;
    let profile = profiles::by_name(&args.str("profile"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let n = args.usize("requests")?;
    let max_batch = args.usize("max-batch")?.max(1);
    let n_inst = args.usize("instances")?.max(1);
    let seed = args.u64("seed")?;
    let arrivals =
        ArrivalProcess::parse(&args.str("arrival")).map_err(|e| anyhow!(e))?;
    let strategies: Vec<ReplanStrategy> = match args.str("replan").as_str() {
        "warm" => vec![ReplanStrategy::Warm],
        "cold" => vec![ReplanStrategy::Cold],
        "compare" => vec![ReplanStrategy::Warm, ReplanStrategy::Cold],
        other => return Err(anyhow!("bad --replan {other}")),
    };

    let slos = slo_serve::config::SloTargets::default()
        .scaled(args.f64("slo-scale")?);
    let mut factory = RequestFactory::new(seed, slos);
    let mut trace_rng = Rng::new(seed ^ 0x0411_13E);
    let trace = TraceSpec { n, arrivals }.generate(&mut factory, &mut trace_rng);

    let kv_phase = parse_kv_phase(&args.str("kv-phase"))?;
    let divergence = DivergenceModel::parse(&args.str("divergence"))
        .map_err(|e| anyhow!(e))?;
    // The declared divergence σ doubles as the predictor's quantile-head
    // residual model, so the head travels with the predictor everywhere
    // it is consulted (σ = 0 leaves the head unfitted — exact point
    // predictions, the pre-quantile behaviour).
    let predictor = bench::fit_predictor_from_profile(&profile, seed)
        .with_lo_sigma(divergence.sigma());
    let profiler = bench::warm_output_profiler(seed, 200);
    let mut pred_rng = Rng::new(seed ^ 0x007_FEED);
    let predicted = predict_outputs(
        &trace,
        &profiler,
        slo_serve::config::OutputPrediction::Profiler,
        &mut pred_rng,
        profile.max_total_tokens / 2,
    );
    let mut kv = parse_kv(&args.str("kv"), &profile)?.with_phase(kv_phase);
    if !kv.binding() && kv_phase != KvPhaseModel::Reserve {
        return Err(anyhow!(
            "--kv-phase phased needs a binding pool: pass --kv hard or \
             --kv soft:<w> as well"
        ));
    }
    if kv.binding() {
        kv = kv.with_lo_mult(parse_kv_quantile(
            args.f64("kv-quantile")?,
            divergence,
        )?);
    } else if args.f64("kv-quantile")? != 0.5 {
        return Err(anyhow!(
            "--kv-quantile needs a binding pool: pass --kv hard or \
             --kv soft:<w> as well"
        ));
    }
    let replan_drift_ms = args.f64("replan-drift-ms")?;
    if !replan_drift_ms.is_finite() || replan_drift_ms < 0.0 {
        return Err(anyhow!(
            "--replan-drift-ms must be finite and ≥ 0, got {replan_drift_ms}"
        ));
    }
    let preempt = PreemptConfig::parse(
        &args.str("preempt"),
        args.f64("kv-swap-gbps")?,
        args.u64("kv-host-blocks")?,
    )
    .map_err(|e| anyhow!(e))?;
    if preempt.mode == PreemptMode::Swap && kv.binding() {
        // Price recompute-vs-swap into the SA objective: the search sees
        // the same per-block transfer time the engine will charge.
        kv = kv.with_swap(
            preempt.swap_gbps,
            kv.block_tokens as f64 * profile.mem.mb_per_token,
            preempt.host_blocks,
        );
    }
    let opts = OnlineOpts {
        compact_dispatched: args.str("compact") == "1",
        arrival_aware: args.str("arrival-aware") == "1",
        replan_drift_ms,
        adaptive_budget: args.str("adaptive-budget") == "1",
        migrate: args.str("migrate") == "1",
    };
    let chunk_tokens = parse_chunk_tokens(&args.str("chunk-tokens"))?;
    let sa = SaParams {
        max_batch,
        seed,
        kv,
        chains: args.usize("chains")?.max(1),
        exchange_period: args.usize("exchange-period")?.max(1),
        window: args.usize("window")?,
        chunk_tokens,
        ..Default::default()
    };

    let mut t = Table::new(&[
        "replan",
        "attainment",
        "chat",
        "code",
        "G (req/s)",
        "replans",
        "drift replans",
        "avg replan ms",
        "preempts",
        "migrations",
        "pred G (req/s)",
    ]);
    for strategy in strategies {
        let mut engines: Vec<Box<dyn Engine + Send>> = (0..n_inst)
            .map(|i| {
                Box::new(
                    SimEngine::new(
                        profile.clone(),
                        max_batch,
                        seed ^ (i as u64).wrapping_mul(0xE5317),
                    )
                    .with_kv_phase(kv_phase)
                    .with_divergence(divergence)
                    .with_preemption(preempt)
                    .with_chunk_tokens(chunk_tokens),
                ) as Box<dyn Engine + Send>
            })
            .collect();
        let (completions, outcomes) = if opts.migrate {
            run_online_fleet_migrating(
                &trace, &predicted, &mut engines, &predictor, &sa, strategy,
                opts,
            )?
        } else {
            run_online_fleet_opts(
                &trace, &predicted, &mut engines, &predictor, &sa, strategy,
                opts,
            )?
        };
        let m = RunMetrics::from_completions(&completions);
        let by_task = RunMetrics::attainment_by_task(&completions);
        let task_att = |task: TaskType| {
            by_task
                .iter()
                .find(|(tt, _, _)| *tt == task)
                .map_or("-".to_string(), |(_, a, _)| fmt(*a))
        };
        let replans: usize = outcomes.iter().map(|o| o.stats.replans).sum();
        let drift_replans: usize =
            outcomes.iter().map(|o| o.stats.drift_replans).sum();
        let replan_ms: f64 =
            outcomes.iter().map(|o| o.stats.replan_ms_total).sum();
        let preempts: usize =
            outcomes.iter().map(|o| o.stats.preemptions).sum();
        let migrations: usize =
            outcomes.iter().map(|o| o.stats.migrations).sum();
        let pred_g: f64 =
            outcomes.iter().map(|o| o.final_eval.g * 1000.0).sum();
        t.row(vec![
            strategy.name().into(),
            fmt(m.attainment()),
            task_att(TaskType::Chat),
            task_att(TaskType::Code),
            fmt(m.g_req_per_s),
            replans.to_string(),
            drift_replans.to_string(),
            fmt(if replans == 0 { 0.0 } else { replan_ms / replans as f64 }),
            preempts.to_string(),
            migrations.to_string(),
            fmt(pred_g),
        ]);
    }
    print!("{}", t.render());
    println!(
        "trace: {} requests, {:?}, seed {seed} (recorded; reruns are \
         bit-identical)",
        n, arrivals
    );
    Ok(())
}

fn cmd_profile(argv: &[String]) -> Result<()> {
    let specs = vec![
        OptSpec { name: "profile", help: "hardware profile", default: Some("qwen7b-v100x2-vllm") },
        OptSpec { name: "seed", help: "rng seed", default: Some("42") },
    ];
    let args = Args::parse(argv, &specs)?;
    let profile = profiles::by_name(&args.str("profile"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let fitted = bench::fit_predictor_from_profile(&profile, args.u64("seed")?);
    print_fit_table(&fitted);
    Ok(())
}

fn print_fit_table(p: &LatencyPredictor) {
    let mut t = Table::new(&["parameter", "alpha", "beta", "gamma", "delta"]);
    t.row(vec![
        "for prefill".into(),
        format!("{:.4}", p.prefill.alpha),
        format!("{:.3}", p.prefill.beta),
        format!("{:.5}", p.prefill.gamma),
        format!("{:.2}", p.prefill.delta),
    ]);
    t.row(vec![
        "for decode".into(),
        format!("{:.6}", p.decode.alpha),
        format!("{:.4}", p.decode.beta),
        format!("{:.6}", p.decode.gamma),
        format!("{:.2}", p.decode.delta),
    ]);
    print!("{}", t.render());
}

fn serve_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "engine", help: "real|sim", default: Some("sim") },
        OptSpec { name: "artifacts", help: "artifacts dir (real engine)", default: Some("artifacts") },
        OptSpec { name: "profile", help: "profile (sim engine)", default: Some("qwen7b-v100x2-vllm") },
        OptSpec { name: "shards", help: "controller shards (one engine each)", default: Some("1") },
        OptSpec { name: "queue-depth", help: "bounded queue depth per shard", default: Some("1024") },
        OptSpec { name: "max-batch", help: "batch cap", default: Some("4") },
        OptSpec { name: "iters-per-temp", help: "SA iteration budget per temperature", default: Some("20") },
        OptSpec { name: "handoff", help: "cross-shard handoff when the home queue is full (0|1)", default: Some("1") },
        OptSpec { name: "stream", help: "record step traces for per-token streaming (0|1)", default: Some("1") },
        OptSpec { name: "seed", help: "base SA seed (shard 0 runs it verbatim)", default: Some("42") },
        OptSpec { name: "addr", help: "bind address", default: Some("127.0.0.1:0") },
        OptSpec { name: "requests", help: "exit after N served (0 = until shutdown op)", default: Some("0") },
        OptSpec { name: "chunk-tokens", help: "off | <N>: chunked prefill in sim engines + per-member TTFT pricing in the shards", default: Some("off") },
        OptSpec { name: "window", help: "sliding-window SA over the next W undispatched batches (0 = whole schedule)", default: Some("0") },
    ]
}

/// Start the async streaming front end: sharded [`server::FrontDoor`]
/// admission behind the single-threaded TCP reactor.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &serve_specs())?;
    let shards = args.usize("shards")?.max(1);
    let max_batch = args.usize("max-batch")?.max(1);
    let chunk_tokens = parse_chunk_tokens(&args.str("chunk-tokens"))?;
    let (engines, predictor, max_total) = if args.str("engine") == "real" {
        if chunk_tokens != 0 {
            return Err(anyhow!(
                "--chunk-tokens applies to the simulated engines only; \
                 the real engine prefills whole prompts"
            ));
        }
        build_real_engines(&args, shards, max_batch)?
    } else {
        let profile = profiles::by_name(&args.str("profile"))
            .ok_or_else(|| anyhow!("unknown profile"))?;
        let max_total = profile.max_total_tokens;
        let seed = args.u64("seed")?;
        let engines: Vec<Box<dyn Engine + Send>> = (0..shards)
            .map(|s| {
                Box::new(
                    SimEngine::new(
                        profile.clone(),
                        max_batch,
                        seed ^ (s as u64).wrapping_mul(0xE531_7AB1),
                    )
                    .with_chunk_tokens(chunk_tokens),
                ) as Box<dyn Engine + Send>
            })
            .collect();
        (
            engines,
            bench::fit_predictor_from_profile(&profile, seed),
            max_total,
        )
    };
    let mut cfg = server::FrontDoorConfig::new(predictor, max_total);
    cfg.shards = shards;
    cfg.queue_depth = args.usize("queue-depth")?.max(1);
    cfg.handoff = args.str("handoff") != "0";
    cfg.stream_tokens = args.str("stream") != "0";
    cfg.sa.max_batch = max_batch;
    cfg.sa.iters_per_temp = args.usize("iters-per-temp")?.max(1);
    cfg.sa.seed = args.u64("seed")?;
    cfg.sa.chunk_tokens = chunk_tokens;
    cfg.sa.window = args.usize("window")?;
    let door = server::FrontDoor::start(cfg, engines)?;
    let mut tcp = server::serve_tcp(door.clone(), &args.str("addr"))?;
    println!("slo-serve listening on {} ({shards} shard(s))", tcp.addr);
    let stop_after = args.usize("requests")?;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if tcp.stopped() {
            break; // a client sent {"op":"shutdown"}
        }
        if stop_after > 0 && door.served() >= stop_after as u64 {
            break;
        }
    }
    tcp.stop();
    door.shutdown();
    Ok(())
}

/// Build PJRT-backed real engines (requires the `real-engine` feature,
/// which in turn needs the external `xla` crate).
#[cfg(feature = "real-engine")]
fn build_real_engines(
    args: &Args,
    shards: usize,
    max_batch: usize,
) -> Result<(Vec<Box<dyn Engine + Send>>, LatencyPredictor, usize)> {
    use slo_serve::engine::real::RealEngine;
    let mut engines: Vec<Box<dyn Engine + Send>> = Vec::new();
    let mut max_total = 0;
    for _ in 0..shards {
        let mut e = RealEngine::load(&args.str("artifacts"))?;
        e.warmup(max_batch.min(e.max_batch()))?;
        max_total = e.max_total_tokens();
        engines.push(Box::new(e));
    }
    let p = profiles::by_name("tinylm-cpu").unwrap();
    Ok((engines, p.truth, max_total))
}

#[cfg(not(feature = "real-engine"))]
fn build_real_engines(
    _args: &Args,
    _shards: usize,
    _max_batch: usize,
) -> Result<(Vec<Box<dyn Engine + Send>>, LatencyPredictor, usize)> {
    Err(anyhow!(
        "this binary was built without the 'real-engine' feature \
         (the PJRT runtime needs the external xla crate); use --engine sim"
    ))
}

fn bench_http_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "clients", help: "concurrent simulated clients (burst size + session modulus)", default: Some("200") },
        OptSpec { name: "shards", help: "controller shards", default: Some("2") },
        OptSpec { name: "queue-depth", help: "bounded queue depth per shard", default: Some("4096") },
        OptSpec { name: "max-batch", help: "engine batch cap", default: Some("8") },
        OptSpec { name: "profile", help: "hardware profile for the simulated engines", default: Some("qwen7b-v100x2-vllm") },
        OptSpec { name: "seed", help: "rng seed (trace + search)", default: Some("42") },
        OptSpec { name: "duration-s", help: "Poisson tail duration (s); 0 = burst only", default: Some("0") },
        OptSpec { name: "rps", help: "Poisson tail rate (req/s); 0 = burst only", default: Some("0") },
        OptSpec { name: "slo-scale", help: "scale all SLO bounds", default: Some("10") },
        OptSpec { name: "iters-per-temp", help: "SA iteration budget per temperature", default: Some("10") },
        OptSpec { name: "handoff", help: "cross-shard handoff (0|1)", default: Some("1") },
        OptSpec { name: "stream", help: "stream every 8th request (0|1)", default: Some("1") },
        OptSpec { name: "kv-pool-mb", help: "override the engines' KV pool (MB); 0 = profile value", default: Some("0") },
        OptSpec { name: "divergence", help: "off | lognormal:<σ> | quantile-trace:<σ> (engine output-length divergence)", default: Some("off") },
        OptSpec { name: "preempt", help: "off | recompute | swap (engine pool-exhaustion policy)", default: Some("off") },
        OptSpec { name: "kv-swap-gbps", help: "host↔device link bandwidth for --preempt swap (GB/s)", default: Some("8") },
        OptSpec { name: "kv-host-blocks", help: "host swap-buffer capacity in KV blocks (--preempt swap)", default: Some("1024") },
        OptSpec { name: "chunk-tokens", help: "off | <N>: chunked prefill in the engines + per-member TTFT pricing in the shards", default: Some("off") },
        OptSpec { name: "window", help: "sliding-window SA over the next W undispatched batches (0 = whole schedule)", default: Some("0") },
        OptSpec { name: "out", help: "write the JSON report here too", default: Some("") },
    ]
}

/// In-process open-loop serving load test over the front door; prints
/// the JSON report (CI's serving smoke gate reads it).
fn cmd_bench_http(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &bench_http_specs())?;
    let duration_s = args.f64("duration-s")?;
    let rps = args.f64("rps")?;
    if !duration_s.is_finite() || duration_s < 0.0 {
        return Err(anyhow!("--duration-s must be finite and ≥ 0"));
    }
    if !rps.is_finite() || rps < 0.0 {
        return Err(anyhow!("--rps must be finite and ≥ 0"));
    }
    let cfg = server::bench_http::BenchHttpConfig {
        clients: args.usize("clients")?.max(1),
        shards: args.usize("shards")?.max(1),
        queue_depth: args.usize("queue-depth")?.max(1),
        max_batch: args.usize("max-batch")?.max(1),
        profile: args.str("profile"),
        seed: args.u64("seed")?,
        duration_s,
        rps,
        slo_scale: args.f64("slo-scale")?,
        iters_per_temp: args.usize("iters-per-temp")?.max(1),
        handoff: args.str("handoff") != "0",
        stream: args.str("stream") != "0",
        kv_pool_mb: args.f64("kv-pool-mb")?,
        divergence: args.str("divergence"),
        preempt: args.str("preempt"),
        kv_swap_gbps: args.f64("kv-swap-gbps")?,
        kv_host_blocks: args.u64("kv-host-blocks")?,
        chunk_tokens: parse_chunk_tokens(&args.str("chunk-tokens"))?,
        window: args.usize("window")?,
    };
    let report = server::bench_http::run(&cfg)?;
    println!("{}", report.to_string_pretty());
    let out = args.str("out");
    if !out.is_empty() {
        std::fs::write(&out, report.to_string_compact())?;
        eprintln!("report written to {out}");
    }
    if report.get("drained").as_bool() != Some(true) {
        return Err(anyhow!(
            "front door failed to drain within the timeout — wedged \
             shard or runaway backlog"
        ));
    }
    Ok(())
}

fn gap_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "ns", help: "comma list of wave sizes", default: Some("6,9,12") },
        OptSpec { name: "seeds", help: "seed count (seeds 1..=k)", default: Some("3") },
        OptSpec { name: "mix", help: "e2e | interactive | mixed | all (SLO class mix)", default: Some("all") },
        OptSpec { name: "sigmas", help: "comma list of divergence σ (KV 0.9-quantile axis)", default: Some("0,0.5") },
        OptSpec { name: "max-batch", help: "batch cap (search + bound)", default: Some("4") },
        OptSpec { name: "node-budget", help: "branch-and-bound node budget per cell", default: Some("400000") },
        OptSpec { name: "out", help: "also write the JSON report here", default: Some("") },
    ]
}

/// Optimality-gap matrix: branch-and-bound certificates vs SA and the
/// index/threshold baselines across {N, mix, σ, KV mode, KV phase}.
fn cmd_gap(argv: &[String]) -> Result<()> {
    use slo_serve::bench::gap::{
        render_table, report_json, run_matrix, summarize, GapConfig, SloMix,
    };
    let args = Args::parse(argv, &gap_specs())?;
    let ns = args
        .str("ns")
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad --ns entry {t:?}"))
        })
        .collect::<Result<Vec<_>>>()?;
    if ns.is_empty() {
        return Err(anyhow!("--ns must name at least one wave size"));
    }
    let mixes = match args.str("mix").as_str() {
        "all" => GapConfig::default().mixes,
        m => vec![SloMix::parse(m).ok_or_else(|| {
            anyhow!("bad --mix {m} (e2e|interactive|mixed|all)")
        })?],
    };
    let sigmas = args
        .str("sigmas")
        .split(',')
        .map(|t| {
            let s: f64 = t
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad --sigmas entry {t:?}"))?;
            if !s.is_finite() || s < 0.0 {
                return Err(anyhow!("σ must be finite and ≥ 0, got {s}"));
            }
            Ok(s)
        })
        .collect::<Result<Vec<_>>>()?;
    let cfg = GapConfig {
        ns,
        seeds: (1..=args.u64("seeds")?.max(1)).collect(),
        mixes,
        sigmas,
        max_batch: args.usize("max-batch")?.max(1),
        node_budget: args.usize("node-budget")?,
        ..GapConfig::default()
    };

    let rows = run_matrix(&cfg);
    print!("{}", render_table(&rows));
    let s = summarize(&rows);
    println!(
        "\n{} cells: {} closed exactly, max gated SA gap {:.3}%, index \
         policy matched/beat SA in {} (bounds are certified: every gap \
         is an upper bound on true suboptimality)",
        s.cells,
        s.closed,
        100.0 * s.max_gated_sa_gap,
        s.index_beats_sa_cells
    );
    let out = args.str("out");
    if !out.is_empty() {
        let doc = report_json(&cfg, &rows);
        std::fs::write(&out, format!("{}\n", doc.to_string_pretty()))?;
        eprintln!("report written to {out}");
    }
    Ok(())
}

fn cmd_profiles() {
    let mut t = Table::new(&["profile", "kv_pool_mb", "max_tokens"]);
    for p in profiles::builtin_profiles() {
        t.row(vec![
            p.name.clone(),
            fmt(p.kv_pool_mb),
            p.max_total_tokens.to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("online") => cmd_online(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("bench-http") => cmd_bench_http(&argv[1..]),
        Some("gap") => cmd_gap(&argv[1..]),
        Some("profile") => cmd_profile(&argv[1..]),
        Some("profiles") => {
            cmd_profiles();
            Ok(())
        }
        Some("help") | None => {
            println!(
                "slo-serve — SLO-aware LLM inference scheduling (CS.DC 2025 reproduction)\n\n\
                 subcommands: run | online | serve | bench-http | gap | profile | profiles | help\n"
            );
            print!("{}", render_help("slo-serve run", "run a scheduling scenario", &run_specs()));
            print!(
                "{}",
                render_help(
                    "slo-serve online",
                    "online admission over an arrival trace",
                    &online_specs(),
                )
            );
            print!(
                "{}",
                render_help(
                    "slo-serve serve",
                    "async streaming front door (TCP JSON-lines)",
                    &serve_specs(),
                )
            );
            print!(
                "{}",
                render_help(
                    "slo-serve bench-http",
                    "open-loop serving load test",
                    &bench_http_specs(),
                )
            );
            print!(
                "{}",
                render_help(
                    "slo-serve gap",
                    "optimality-gap matrix vs certified bounds",
                    &gap_specs(),
                )
            );
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try help)")),
    }
}
