//! Serving metrics: SLO attainment, the paper's objective `G`, latency
//! summaries, and table rendering for the bench harness.
//!
//! [`histogram`] adds the serving-side counterpart: fixed-memory latency
//! histograms for the front door's admission/e2e percentiles.

pub mod histogram;

pub use histogram::Histogram;

use crate::coordinator::request::{Completion, TaskType};
use crate::util::stats::Summary;

/// Aggregated metrics over a set of completions (measured, not predicted).
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub n: usize,
    /// Requests meeting their SLO (Eq. 6/7).
    pub met: usize,
    /// Σ t_e2e over all requests (ms).
    pub total_e2e_ms: f64,
    /// `G = n_met / Σ t_e2e`, in req/s (paper Eq. 2; the paper plots req/s).
    pub g_req_per_s: f64,
    pub e2e: Option<Summary>,
    pub ttft: Option<Summary>,
    pub tpot: Option<Summary>,
    pub wait: Option<Summary>,
}

impl RunMetrics {
    pub fn from_completions(completions: &[Completion]) -> RunMetrics {
        let n = completions.len();
        let met = completions.iter().filter(|c| c.slo_met()).count();
        let total_e2e_ms: f64 = completions.iter().map(|c| c.e2e_ms).sum();
        let g = if total_e2e_ms > 0.0 {
            met as f64 / (total_e2e_ms / 1000.0)
        } else {
            0.0
        };
        let collect = |f: fn(&Completion) -> f64| {
            Summary::from(&completions.iter().map(f).collect::<Vec<_>>())
        };
        RunMetrics {
            n,
            met,
            total_e2e_ms,
            g_req_per_s: g,
            e2e: collect(|c| c.e2e_ms),
            ttft: collect(|c| c.ttft_ms),
            tpot: collect(|c| c.tpot_ms),
            wait: collect(|c| c.wait_ms),
        }
    }

    /// SLO attainment ratio in [0, 1].
    pub fn attainment(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.met as f64 / self.n as f64
        }
    }

    /// Average e2e latency (ms).
    pub fn avg_latency_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.total_e2e_ms / self.n as f64
        }
    }

    /// Per-task-type attainment breakdown.
    pub fn attainment_by_task(
        completions: &[Completion],
    ) -> Vec<(TaskType, f64, usize)> {
        let mut tasks: Vec<TaskType> =
            completions.iter().map(|c| c.task).collect();
        tasks.sort();
        tasks.dedup();
        tasks
            .into_iter()
            .map(|t| {
                let of_task: Vec<&Completion> =
                    completions.iter().filter(|c| c.task == t).collect();
                let met =
                    of_task.iter().filter(|c| c.slo_met()).count();
                (t, met as f64 / of_task.len() as f64, of_task.len())
            })
            .collect()
    }
}

/// Markdown-style table renderer for bench output (criterion substitute).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |\n", padded.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format a float compactly for table cells.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Slo;

    fn completion(id: u64, task: TaskType, e2e: f64, bound: f64) -> Completion {
        Completion {
            id,
            task,
            slo: Slo::E2e { e2e_ms: bound },
            input_len: 10,
            predicted_lo: 5,
            generated: 5,
            e2e_ms: e2e,
            ttft_ms: e2e * 0.2,
            tpot_ms: 10.0,
            wait_ms: 0.0,
            batch_size: 1,
            text: None,
        }
    }

    #[test]
    fn g_matches_paper_units() {
        // Fig. 3(C): 3 met, Σe2e = 2900 ms -> G = 1.03 req/s
        let completions = vec![
            completion(0, TaskType::Code, 800.0, 800.0),
            completion(1, TaskType::Code, 500.0, 500.0),
            completion(2, TaskType::Code, 1600.0, 1800.0),
        ];
        let m = RunMetrics::from_completions(&completions);
        assert_eq!(m.met, 3);
        assert!((m.g_req_per_s - 1.0345).abs() < 1e-3);
        assert_eq!(m.attainment(), 1.0);
        assert!((m.avg_latency_ms() - 2900.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn attainment_counts_misses() {
        let completions = vec![
            completion(0, TaskType::Code, 100.0, 50.0), // miss
            completion(1, TaskType::Chat, 100.0, 200.0), // met
        ];
        let m = RunMetrics::from_completions(&completions);
        assert_eq!(m.met, 1);
        assert_eq!(m.attainment(), 0.5);
        let per_task = RunMetrics::attainment_by_task(&completions);
        assert_eq!(per_task.len(), 2);
        assert_eq!(per_task[0].0, TaskType::Chat);
        assert_eq!(per_task[0].1, 1.0);
        assert_eq!(per_task[1].1, 0.0);
    }

    #[test]
    fn empty_metrics() {
        let m = RunMetrics::from_completions(&[]);
        assert_eq!(m.n, 0);
        assert_eq!(m.attainment(), 0.0);
        assert_eq!(m.avg_latency_ms(), 0.0);
        assert!(m.e2e.is_none());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("| name "));
        assert!(s.contains("| a-much-longer-name | 2.5"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(123.4), "123");
        assert_eq!(fmt(3.14159), "3.14");
        assert_eq!(fmt(0.012345), "0.0123");
    }
}
