"""AOT compile path: TinyLM → HLO text + weights + manifest.

Runs ONCE at build time (``make artifacts``).  Python never touches the
request path: the Rust runtime loads these artifacts and serves from them.

Outputs (under ``--out``, default ``../artifacts``):

* ``prefill_b{B}_s{S}.hlo.txt`` — one executable per (batch, seq) bucket.
* ``decode_b{B}.hlo.txt``       — one executable per batch bucket.
* ``weights.bin``               — TLMW1 binary tensor container (see below).
* ``manifest.json``             — model config, parameter order/shapes,
                                  bucket table, token conventions.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

TLMW1 weights format (little-endian):
    magic   6 bytes  b"TLMW1\\0"
    count   u32
    per tensor:
        name_len u32, name utf-8,
        dtype    u8  (0 = f32),
        ndim     u8,
        dims     u32 × ndim,
        data     f32 × prod(dims)
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Bucket grid served by the Rust engine.  Requests are padded up to the
# nearest bucket; keep the grid small — executables are compiled lazily by
# the Rust runtime but each adds artifact bytes and compile time.
PREFILL_BATCHES = (1, 2, 4)
PREFILL_SEQS = (32, 64, 128, 256)
DECODE_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights(path: str, cfg: M.ModelConfig, params) -> None:
    order = M.param_order(cfg)
    with open(path, "wb") as f:
        f.write(b"TLMW1\0")
        f.write(struct.pack("<I", len(order)))
        for name in order:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes(order="C"))


def _param_specs(cfg: M.ModelConfig):
    shapes = M.param_shapes(cfg)
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32)
            for n in M.param_order(cfg)]


def lower_prefill(cfg: M.ModelConfig, batch: int, seq: int,
                  attn_impl: str = "pallas") -> str:
    fn = M.prefill_flat(cfg, attn_impl)
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lowered = jax.jit(fn).lower(*_param_specs(cfg), tokens)
    return to_hlo_text(lowered)


def lower_decode(cfg: M.ModelConfig, batch: int,
                 attn_impl: str = "pallas") -> str:
    fn = M.decode_flat(cfg, attn_impl)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim),
        jnp.float32)
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(fn).lower(*_param_specs(cfg), cache, cache, tokens, pos)
    return to_hlo_text(lowered)


def build(out_dir: str, cfg: M.ModelConfig, seed: int = 42,
          attn_impl: str = "pallas",
          prefill_batches=PREFILL_BATCHES, prefill_seqs=PREFILL_SEQS,
          decode_batches=DECODE_BATCHES, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)

    params = M.init_params(cfg, seed)
    write_weights(os.path.join(out_dir, "weights.bin"), cfg, params)

    shapes = M.param_shapes(cfg)
    manifest = {
        "format": 1,
        "model": cfg.to_dict(),
        "seed": seed,
        "attn_impl": attn_impl,
        "weights": "weights.bin",
        "params": [{"name": n, "shape": list(shapes[n])}
                   for n in M.param_order(cfg)],
        "tokens": {"vocab": cfg.vocab, "bos": M.BOS_ID, "eos": M.EOS_ID},
        "buckets": {"prefill": [], "decode": []},
        # Result tuple layouts for the rust runtime:
        #   prefill -> (logits[B,S,V], k_caches[L,B,maxS,H,Dh], v_caches same)
        #   decode  -> (logits[B,V],   k_caches,                v_caches)
        "outputs": {"prefill": ["logits", "k_caches", "v_caches"],
                    "decode": ["logits", "k_caches", "v_caches"]},
    }

    for b in prefill_batches:
        for s in prefill_seqs:
            if s > cfg.max_seq:
                continue
            name = f"prefill_b{b}_s{s}.hlo.txt"
            if verbose:
                print(f"lowering {name} ...", flush=True)
            text = lower_prefill(cfg, b, s, attn_impl)
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(text)
            manifest["buckets"]["prefill"].append(
                {"batch": b, "seq": s, "file": name})

    for b in decode_batches:
        name = f"decode_b{b}.hlo.txt"
        if verbose:
            print(f"lowering {name} ...", flush=True)
        text = lower_decode(cfg, b, attn_impl)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["buckets"]["decode"].append({"batch": b, "file": name})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        total = sum(os.path.getsize(os.path.join(out_dir, e))
                    for e in os.listdir(out_dir))
        print(f"artifacts complete: {out_dir} ({total / 1e6:.1f} MB, "
              f"{cfg.param_count} params)")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--attn-impl", choices=("pallas", "ref"), default="pallas")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=384)
    args = ap.parse_args(argv)
    cfg = M.ModelConfig(d_model=args.d_model, n_layers=args.n_layers,
                        n_heads=args.n_heads,
                        head_dim=args.d_model // args.n_heads,
                        max_seq=args.max_seq)
    build(args.out, cfg, seed=args.seed, attn_impl=args.attn_impl)
    return 0


if __name__ == "__main__":
    sys.exit(main())
