//! Determinism guarantees of the parallel-tempering search at the
//! [`ScheduleOutcome`] level (Algorithm 2 over a fleet):
//!
//! * **Invariant 11** (K = 1 escape hatch): a tempered search with one
//!   chain replays the untempered single-chain search bit for bit — the
//!   same RNG stream, the same plans, the same deterministic stats — for
//!   any `exchange_period`. The per-search version of this invariant is
//!   unit-tested in `annealing.rs`; this file pins the end-to-end wave
//!   outcome across instances.
//! * **Reproducibility at K > 1**: for a fixed seed and exchange schedule
//!   the tempered search is a pure function of its inputs — scoped
//!   threads, per-chain derived RNG streams, and the deterministic
//!   best-exchange make the outcome identical across runs.

use slo_serve::coordinator::objective::{Evaluator, Job};
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{
    priority_mapping, priority_mapping_full, SaParams, SearchStats,
};
use slo_serve::coordinator::profiler::MemoryModel;
use slo_serve::coordinator::request::{Request, Slo, TaskType};
use slo_serve::coordinator::scheduler::{schedule, InstanceInfo, ScheduleOutcome};
use slo_serve::util::rng::Rng;

fn requests(n: usize, seed: u64) -> (Vec<Request>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            Request::synthetic(
                i as u64,
                if rng.chance(0.5) { TaskType::Chat } else { TaskType::Code },
                50 + rng.below(1200),
                10 + rng.below(300),
                if rng.chance(0.5) {
                    Slo::E2e { e2e_ms: rng.uniform(400.0, 20_000.0) }
                } else {
                    Slo::Interactive {
                        ttft_ms: rng.uniform(200.0, 6_000.0),
                        tpot_ms: rng.uniform(10.0, 50.0),
                    }
                },
            )
        })
        .collect();
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    (reqs, outs)
}

fn instances(n: usize) -> Vec<InstanceInfo> {
    (0..n).map(|id| InstanceInfo { id, mem_mb: 16_000.0 }).collect()
}

/// The deterministic slice of [`SearchStats`] — everything except the
/// wall/cpu timings, which legitimately vary across runs.
fn det_stats(s: &SearchStats) -> (usize, usize, usize, bool, usize, usize) {
    (s.evals, s.accepted, s.improved, s.early_exit, s.exchanges, s.winner_chain)
}

fn assert_outcomes_identical(a: &ScheduleOutcome, b: &ScheduleOutcome) {
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.exchanges, b.exchanges);
    assert_eq!(a.plans.len(), b.plans.len());
    for (pa, pb) in a.plans.iter().zip(&b.plans) {
        assert_eq!(pa.instance, pb.instance);
        assert_eq!(pa.jobs, pb.jobs);
        assert_eq!(pa.schedule, pb.schedule, "instance {}", pa.instance);
        assert_eq!(
            det_stats(&pa.stats),
            det_stats(&pb.stats),
            "instance {}",
            pa.instance
        );
    }
}

#[test]
fn single_chain_outcome_is_byte_identical_to_the_untempered_stack() {
    let (reqs, outs) = requests(24, 0xD15C);
    let predictor = LatencyPredictor::paper_table2();
    let mem = MemoryModel::default();
    let untempered = SaParams { max_batch: 4, seed: 31, ..Default::default() };
    // exchange_period must be inert at K = 1 — the single chain never
    // synchronizes, so the round structure cannot exist to observe it.
    for period in [1usize, 3, 16] {
        let tempered = SaParams {
            chains: 1,
            exchange_period: period,
            ..untempered
        };
        let a = schedule(&reqs, &outs, &instances(3), &predictor, &mem, &untempered)
            .unwrap();
        let b = schedule(&reqs, &outs, &instances(3), &predictor, &mem, &tempered)
            .unwrap();
        assert_outcomes_identical(&a, &b);
        assert_eq!(b.exchanges, 0, "single chain can never exchange");
    }
}

#[test]
fn single_chain_search_replays_the_full_reference_stream() {
    // Invariant 11 against the *untempered* reference implementation:
    // priority_mapping_full ignores `chains` entirely, so a K = 1
    // tempered priority_mapping must land on its exact trajectory.
    let predictor = LatencyPredictor::paper_table2();
    for seed in [1u64, 9, 77] {
        let (reqs, outs) = requests(18, 0xFACE ^ seed);
        let jobs: Vec<Job> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| Job {
                req_idx: i,
                input_len: r.input_len,
                output_len: outs[i],
                slo: r.slo,
            })
            .collect();
        let ev = Evaluator::new(&jobs, &predictor);
        let params = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 25,
            chains: 1,
            exchange_period: 2,
            ..Default::default()
        };
        let fast = priority_mapping(&ev, &params);
        let full = priority_mapping_full(&ev, &params);
        assert_eq!(fast.schedule, full.schedule, "seed {seed}");
        assert_eq!(fast.eval, full.eval, "seed {seed}");
        assert_eq!(det_stats(&fast.stats), det_stats(&full.stats), "seed {seed}");
    }
}

#[test]
fn tempered_outcome_is_reproducible_for_a_fixed_seed() {
    let (reqs, outs) = requests(28, 0xBEE5);
    let predictor = LatencyPredictor::paper_table2();
    let mem = MemoryModel::default();
    for chains in [2usize, 4] {
        let sa = SaParams {
            max_batch: 4,
            seed: 1234,
            chains,
            exchange_period: 3,
            ..Default::default()
        };
        let a =
            schedule(&reqs, &outs, &instances(2), &predictor, &mem, &sa).unwrap();
        let b =
            schedule(&reqs, &outs, &instances(2), &predictor, &mem, &sa).unwrap();
        assert_outcomes_identical(&a, &b);
        // per-chain cpu accounting: the summed figure can never read
        // below the wall clock of the parallel mapping section alone
        for outcome in [&a, &b] {
            for plan in &outcome.plans {
                assert!(plan.stats.cpu_ms >= plan.stats.overhead_ms - 1e-9);
            }
        }
    }
}

#[test]
fn exchange_schedule_is_part_of_the_reproducibility_key() {
    // Different exchange periods synchronize the chains at different
    // ladder points — both runs are internally deterministic, and the
    // winning plan is still a valid schedule either way.
    let (reqs, outs) = requests(20, 0xCAB1);
    let predictor = LatencyPredictor::paper_table2();
    let mem = MemoryModel::default();
    for period in [1usize, 2, 8] {
        let sa = SaParams {
            max_batch: 4,
            seed: 7,
            chains: 3,
            exchange_period: period,
            ..Default::default()
        };
        let a =
            schedule(&reqs, &outs, &instances(1), &predictor, &mem, &sa).unwrap();
        let b =
            schedule(&reqs, &outs, &instances(1), &predictor, &mem, &sa).unwrap();
        assert_outcomes_identical(&a, &b);
        for plan in &a.plans {
            plan.schedule.validate(4).unwrap();
            assert!(plan.stats.winner_chain < 3);
        }
    }
}
