//! Sharded admission front door: bounded queues in front of per-shard
//! [`WaveController`] workers.
//!
//! The [`FrontDoor`] is the serving system's admission boundary. Each
//! shard owns one engine and one controller, fed by a bounded
//! [`std::sync::mpsc::sync_channel`]; submission is non-blocking:
//!
//! * the session's home shard (consistent hash, [`session_shard`]) is
//!   tried first;
//! * if its queue is full and handoff is enabled, the remaining shards
//!   are tried in ring order ([`DoorStats::handoffs`] counts the moves);
//! * if every queue is full the request is rejected with
//!   [`SubmitError::Saturated`] and a `retry_after_ms` hint sized from
//!   the home shard's measured drain rate — the 429 path, explicit
//!   backpressure instead of unbounded buffering.
//!
//! Accepted requests return a [`StreamHandle`] delivering
//! [`StreamEvent`]s: `Admitted` once the shard's controller plans the
//! request, `Token` per decode step (when the engine records step traces,
//! [`crate::engine::Engine::enable_step_trace`]), and a final `Done` with
//! the measured [`Completion`] (or `Failed`).
//!
//! **Escape hatch (invariant 12)**: [`serve_trace`] is the synchronous
//! zero-queue replay of the same sharded topology — it partitions a
//! recorded trace by [`session_shard`] over request ids and runs each
//! shard through [`run_online_opts`] with the shard's seed
//! ([`shard_seed`], which is the base seed verbatim for shard 0). With
//! one shard it is byte-for-byte `run_online_opts` on the full trace:
//! no queue, no threads, no divergence.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::online::{
    run_online_opts, OnlineOpts, OnlineOutcome, ReplanStrategy,
};
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::SaParams;
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::scheduler::instance_seed;
use crate::engine::Engine;
use crate::server::shard::{
    shard_loop, ShardCtx, ShardShared, SubmitMsg,
};
use crate::util;
use crate::util::json::Json;

/// Fallback per-item drain estimate (ms) used for the `retry_after_ms`
/// hint before a shard has measured anything.
const DEFAULT_DRAIN_MS: f64 = 5.0;

/// Events a client observes for one submitted request, in order:
/// `Admitted`, zero or more `Token`s, then exactly one `Done` or `Failed`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// The shard's controller admitted (planned) the request.
    Admitted {
        id: u64,
        /// Shard that accepted it (after any handoff).
        shard: usize,
        /// Queue wait: submit to admission (ms).
        queue_ms: f64,
    },
    /// One token emitted at a decode step (step-traced engines only).
    Token {
        id: u64,
        /// 0-based token index within the reply.
        index: usize,
        /// Engine clock at emission (ms).
        t_ms: f64,
    },
    /// The request finished; the measured completion record.
    Done { id: u64, completion: Completion },
    /// The request failed inside the shard (admission or engine error).
    Failed { id: u64, error: String },
}

/// Why a submission was not accepted.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    /// Every eligible shard queue is full — retry after the hint.
    #[error("saturated: retry after {retry_after_ms} ms")]
    Saturated { retry_after_ms: u64 },
    /// The request can never be served (empty prompt, over token cap).
    #[error("invalid request: {0}")]
    Invalid(String),
    /// The front door is shutting down.
    #[error("shutting down")]
    ShuttingDown,
}

/// Non-blocking poll result of a [`StreamHandle`].
#[derive(Debug)]
pub enum TryNext {
    /// An event is ready.
    Event(StreamEvent),
    /// No event yet; the request is still in flight.
    Empty,
    /// The stream ended (terminal event already delivered, or the shard
    /// dropped the sender without one — a server-side failure).
    Closed,
}

/// Client-side end of one accepted request's event stream.
pub struct StreamHandle {
    /// Request id assigned by the front door.
    pub id: u64,
    /// Shard the request landed on (after any handoff).
    pub shard: usize,
    rx: Receiver<StreamEvent>,
}

impl StreamHandle {
    /// Block for the next event; `None` once the stream is closed.
    pub fn next_event(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll (the TCP reactor's accessor).
    pub fn try_next(&self) -> TryNext {
        match self.rx.try_recv() {
            Ok(e) => TryNext::Event(e),
            Err(std::sync::mpsc::TryRecvError::Empty) => TryNext::Empty,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                TryNext::Closed
            }
        }
    }

    /// Block until the terminal event and return the completion.
    pub fn wait_done(self) -> Result<Completion> {
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Done { completion, .. }) => {
                    return Ok(completion)
                }
                Ok(StreamEvent::Failed { error, .. }) => {
                    anyhow::bail!("request {} failed: {error}", self.id)
                }
                Ok(_) => {}
                Err(_) => anyhow::bail!(
                    "request {} stream closed without completion",
                    self.id
                ),
            }
        }
    }
}

/// Front-door configuration. [`FrontDoorConfig::new`] picks serving
/// defaults (1 shard, queue depth 1024, compacted arrival-aware
/// controllers with a light SA budget); override fields as needed.
pub struct FrontDoorConfig {
    /// Controller workers (each owns one engine).
    pub shards: usize,
    /// Bounded queue depth per shard (≥ 1 for the live door; the
    /// zero-queue configuration is the synchronous [`serve_trace`]).
    pub queue_depth: usize,
    /// SA parameters for every shard's controller; `sa.seed` is the base
    /// seed shards derive theirs from ([`shard_seed`]), `sa.max_batch`
    /// bounds dispatch batches.
    pub sa: SaParams,
    pub strategy: ReplanStrategy,
    pub opts: OnlineOpts,
    pub predictor: LatencyPredictor,
    /// Longest input + output accepted per request.
    pub max_total_tokens: usize,
    /// Cross-shard handoff when the home queue is full.
    pub handoff: bool,
    /// Record engine step traces and relay per-token events to streaming
    /// clients.
    pub stream_tokens: bool,
}

impl FrontDoorConfig {
    pub fn new(
        predictor: LatencyPredictor,
        max_total_tokens: usize,
    ) -> FrontDoorConfig {
        FrontDoorConfig {
            shards: 1,
            queue_depth: 1024,
            sa: SaParams { iters_per_temp: 20, ..SaParams::default() },
            strategy: ReplanStrategy::Warm,
            opts: OnlineOpts {
                compact_dispatched: true,
                arrival_aware: true,
                ..OnlineOpts::default()
            },
            predictor,
            max_total_tokens,
            handoff: true,
            stream_tokens: false,
        }
    }
}

/// Door-level counters (shard-independent admission accounting).
#[derive(Debug, Default)]
pub(crate) struct DoorShared {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub invalid: AtomicU64,
    pub handoffs: AtomicU64,
    /// Accepted but not yet completed (queued + admitted + executing).
    pub inflight: AtomicU64,
    pub peak_inflight: AtomicU64,
    pub running: AtomicBool,
}

/// Point-in-time door counters (`accepted + rejected + invalid` equals
/// submissions attempted).
#[derive(Debug, Clone, Copy)]
pub struct DoorStats {
    pub accepted: u64,
    pub rejected: u64,
    pub invalid: u64,
    pub handoffs: u64,
    pub inflight: u64,
    pub peak_inflight: u64,
}

struct ShardHandle {
    tx: SyncSender<SubmitMsg>,
    shared: Arc<ShardShared>,
    join: Mutex<Option<JoinHandle<()>>>,
}

/// The sharded admission front door (module docs).
pub struct FrontDoor {
    shards: Vec<ShardHandle>,
    door: Arc<DoorShared>,
    handoff: bool,
    queue_depth: usize,
    max_total_tokens: usize,
    next_id: AtomicU64,
}

/// Consistent session → shard hash (splitmix64 finalizer): stable across
/// runs, uniform across shards, and independent of shard load so a
/// session's requests always start on the same home shard.
pub fn session_shard(session: u64, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// Per-shard SA seed. Shard 0 runs the base seed **verbatim** — so the
/// single-shard topology replays [`run_online_opts`] bit for bit
/// (invariant 12) — and shards > 0 decorrelate via [`instance_seed`].
pub fn shard_seed(base: u64, shard: usize) -> u64 {
    if shard == 0 {
        base
    } else {
        instance_seed(base, shard)
    }
}

impl FrontDoor {
    /// Start the door: one worker thread per shard, each owning one
    /// engine from `engines` (`engines.len()` must equal `cfg.shards`).
    pub fn start(
        cfg: FrontDoorConfig,
        mut engines: Vec<Box<dyn Engine + Send>>,
    ) -> Result<Arc<FrontDoor>> {
        let n = cfg.shards.max(1);
        anyhow::ensure!(
            engines.len() == n,
            "need exactly one engine per shard ({} != {n})",
            engines.len()
        );
        anyhow::ensure!(
            cfg.queue_depth >= 1,
            "live front door needs queue_depth >= 1 \
             (the zero-queue configuration is serve_trace)"
        );
        let door = Arc::new(DoorShared {
            running: AtomicBool::new(true),
            ..DoorShared::default()
        });
        let mut shards = Vec::with_capacity(n);
        for (s, mut engine) in engines.drain(..).enumerate() {
            if cfg.stream_tokens {
                engine.enable_step_trace();
            }
            let (tx, rx) = std::sync::mpsc::sync_channel(cfg.queue_depth);
            let shared = Arc::new(ShardShared::default());
            let ctx = ShardCtx {
                shard: s,
                predictor: cfg.predictor,
                sa: SaParams {
                    seed: shard_seed(cfg.sa.seed, s),
                    ..cfg.sa
                },
                strategy: cfg.strategy,
                opts: cfg.opts,
                max_total_tokens: cfg.max_total_tokens,
                stream_tokens: cfg.stream_tokens,
            };
            let worker_shared = shared.clone();
            let worker_door = door.clone();
            let join = std::thread::Builder::new()
                .name(format!("shard-{s}"))
                .spawn(move || {
                    shard_loop(ctx, rx, worker_shared, worker_door, engine);
                })?;
            shards.push(ShardHandle {
                tx,
                shared,
                join: Mutex::new(Some(join)),
            });
        }
        Ok(Arc::new(FrontDoor {
            shards,
            door,
            handoff: cfg.handoff,
            queue_depth: cfg.queue_depth,
            max_total_tokens: cfg.max_total_tokens,
            next_id: AtomicU64::new(0),
        }))
    }

    /// Submit one request. Non-blocking: either it lands on a shard
    /// queue (home first, then ring handoff when enabled) and a
    /// [`StreamHandle`] is returned, or it is rejected with a
    /// [`SubmitError`]. The request's `id` and `arrival_ms` are assigned
    /// here; `stream` opts into per-token events.
    pub fn submit(
        &self,
        session: u64,
        mut request: Request,
        stream: bool,
    ) -> Result<StreamHandle, SubmitError> {
        if !self.door.running.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        let input = request
            .prompt
            .as_ref()
            .map_or(request.input_len, |p| p.len());
        if input == 0 {
            self.door.invalid.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        if input + request.output_len.max(1) > self.max_total_tokens {
            self.door.invalid.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::Invalid(format!(
                "input {} + output {} exceeds cap {}",
                input,
                request.output_len.max(1),
                self.max_total_tokens
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        request.id = id;
        request.arrival_ms = util::now_ms();
        let submit_ms = request.arrival_ms;
        let home = session_shard(session, self.shards.len());
        let (events, rx) = std::sync::mpsc::channel();
        let mut msg = SubmitMsg {
            request,
            submit_ms,
            deferred: false,
            stream,
            events,
        };
        let tries = if self.handoff { self.shards.len() } else { 1 };
        for k in 0..tries {
            let s = (home + k) % self.shards.len();
            match self.shards[s].tx.try_send(msg) {
                Ok(()) => {
                    if k > 0 {
                        self.door.handoffs.fetch_add(1, Ordering::SeqCst);
                    }
                    self.door.accepted.fetch_add(1, Ordering::SeqCst);
                    let inflight =
                        self.door.inflight.fetch_add(1, Ordering::SeqCst) + 1;
                    self.door
                        .peak_inflight
                        .fetch_max(inflight, Ordering::SeqCst);
                    return Ok(StreamHandle { id, shard: s, rx });
                }
                Err(TrySendError::Full(m)) => msg = m,
                Err(TrySendError::Disconnected(_)) => {
                    return Err(SubmitError::ShuttingDown)
                }
            }
        }
        self.door.rejected.fetch_add(1, Ordering::SeqCst);
        Err(SubmitError::Saturated {
            retry_after_ms: self.retry_after_ms(home),
        })
    }

    /// 429 hint: time to drain the home shard's full queue at its
    /// measured per-item drain rate (EWMA; [`DEFAULT_DRAIN_MS`] before
    /// any measurement), clamped to [1 ms, 30 s].
    fn retry_after_ms(&self, home: usize) -> u64 {
        let bits = self.shards[home]
            .shared
            .drain_ewma_ms_bits
            .load(Ordering::SeqCst);
        let per_item = match f64::from_bits(bits) {
            v if v > 0.0 && v.is_finite() => v,
            _ => DEFAULT_DRAIN_MS,
        };
        (self.queue_depth as f64 * per_item).clamp(1.0, 30_000.0) as u64
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Longest input + output accepted per request.
    pub fn max_total_tokens(&self) -> usize {
        self.max_total_tokens
    }

    /// Door-level counter snapshot.
    pub fn door_stats(&self) -> DoorStats {
        DoorStats {
            accepted: self.door.accepted.load(Ordering::SeqCst),
            rejected: self.door.rejected.load(Ordering::SeqCst),
            invalid: self.door.invalid.load(Ordering::SeqCst),
            handoffs: self.door.handoffs.load(Ordering::SeqCst),
            inflight: self.door.inflight.load(Ordering::SeqCst),
            peak_inflight: self.door.peak_inflight.load(Ordering::SeqCst),
        }
    }

    /// Completions served across all shards.
    pub fn served(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.shared.served.load(Ordering::SeqCst))
            .sum()
    }

    /// Per-shard shared state (metrics readers).
    pub fn shard_shared(&self, s: usize) -> &Arc<ShardShared> {
        &self.shards[s].shared
    }

    /// Poll until nothing is in flight (accepted == completed) or the
    /// timeout expires. Returns whether the door drained.
    pub fn wait_drained(&self, timeout_ms: u64) -> bool {
        let deadline = util::now_ms() + timeout_ms as f64;
        loop {
            if self.door.inflight.load(Ordering::SeqCst) == 0 {
                return true;
            }
            if util::now_ms() > deadline {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// Aggregate serving stats (door counters + merged shard metrics) as
    /// the `stats` reply / bench report body.
    pub fn stats_json(&self) -> Json {
        let d = self.door_stats();
        let mut admission = crate::metrics::Histogram::new();
        let mut e2e = crate::metrics::Histogram::new();
        let mut served = 0u64;
        let mut met = 0u64;
        let mut failed = 0u64;
        let mut tokens_out = 0u64;
        let mut deferrals = 0usize;
        let mut replans = 0usize;
        let mut preemptions = 0u64;
        let mut kv_truncations = 0u64;
        let mut per_class: Vec<(
            crate::coordinator::request::TaskType,
            usize,
            usize,
        )> = Vec::new();
        let mut shard_rows = Vec::new();
        for (s, h) in self.shards.iter().enumerate() {
            served += h.shared.served.load(Ordering::SeqCst);
            met += h.shared.met.load(Ordering::SeqCst);
            failed += h.shared.failed.load(Ordering::SeqCst);
            tokens_out += h.shared.tokens_out.load(Ordering::SeqCst);
            preemptions += h.shared.preemptions.load(Ordering::SeqCst);
            kv_truncations +=
                h.shared.kv_truncations.load(Ordering::SeqCst);
            let m = h.shared.metrics.lock().unwrap();
            admission.merge(&m.admission);
            e2e.merge(&m.e2e);
            deferrals += m.online.deferrals;
            replans += m.online.replans;
            for &(task, n, k) in &m.per_class {
                match per_class.iter_mut().find(|(t, _, _)| *t == task) {
                    Some(row) => {
                        row.1 += n;
                        row.2 += k;
                    }
                    None => per_class.push((task, n, k)),
                }
            }
            shard_rows.push(Json::obj(vec![
                ("shard", Json::num(s as f64)),
                (
                    "served",
                    Json::num(h.shared.served.load(Ordering::SeqCst) as f64),
                ),
                ("admitted", Json::num(m.online.admitted as f64)),
                ("replans", Json::num(m.online.replans as f64)),
                ("sa_evals", Json::num(m.online.sa_evals as f64)),
                (
                    "drift_replans",
                    Json::num(m.online.drift_replans as f64),
                ),
                ("deferrals", Json::num(m.online.deferrals as f64)),
                (
                    "preemptions",
                    Json::num(
                        h.shared.preemptions.load(Ordering::SeqCst) as f64,
                    ),
                ),
                (
                    "kv_truncations",
                    Json::num(
                        h.shared.kv_truncations.load(Ordering::SeqCst)
                            as f64,
                    ),
                ),
            ]));
        }
        let attainment = if served > 0 {
            met as f64 / served as f64
        } else {
            0.0
        };
        let classes: Vec<Json> = per_class
            .iter()
            .map(|&(task, n, k)| {
                Json::obj(vec![
                    ("task", Json::str(task.name())),
                    ("n", Json::num(n as f64)),
                    ("met", Json::num(k as f64)),
                    (
                        "attainment",
                        Json::num(if n > 0 {
                            k as f64 / n as f64
                        } else {
                            0.0
                        }),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("accepted", Json::num(d.accepted as f64)),
            ("rejected", Json::num(d.rejected as f64)),
            ("invalid", Json::num(d.invalid as f64)),
            ("handoffs", Json::num(d.handoffs as f64)),
            ("inflight", Json::num(d.inflight as f64)),
            ("peak_inflight", Json::num(d.peak_inflight as f64)),
            ("served", Json::num(served as f64)),
            ("met", Json::num(met as f64)),
            ("failed", Json::num(failed as f64)),
            ("tokens_out", Json::num(tokens_out as f64)),
            ("deferrals", Json::num(deferrals as f64)),
            ("replans", Json::num(replans as f64)),
            ("preemptions", Json::num(preemptions as f64)),
            ("kv_truncations", Json::num(kv_truncations as f64)),
            ("attainment", Json::num(attainment)),
            ("admission_ms", admission.to_json()),
            ("e2e_ms", e2e.to_json()),
            ("per_class", Json::Arr(classes)),
            ("shards", Json::Arr(shard_rows)),
        ])
    }

    /// Stop accepting, let the shards finish their backlog, and join the
    /// worker threads. Idempotent.
    pub fn shutdown(&self) {
        self.door.running.store(false, Ordering::SeqCst);
        for h in &self.shards {
            let join = h.join.lock().unwrap().take();
            if let Some(j) = join {
                let _ = j.join();
            }
        }
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Synchronous sharded trace replay — the zero-queue, zero-thread escape
/// hatch (module docs, invariant 12). Partitions the recorded trace by
/// [`session_shard`] over request ids and runs each non-empty shard
/// through [`run_online_opts`] at its [`shard_seed`]. With
/// `cfg.shards == 1` this is byte-identical to calling
/// [`run_online_opts`] on the full trace with `cfg.sa` directly.
///
/// Returns merged completions (sorted by id) plus the per-shard outcomes
/// tagged with their shard index (empty shards are skipped).
pub fn serve_trace(
    cfg: &FrontDoorConfig,
    requests: &[Request],
    predicted_out: &[usize],
    engines: &mut [Box<dyn Engine + Send>],
) -> Result<(Vec<Completion>, Vec<(usize, OnlineOutcome)>)> {
    assert_eq!(requests.len(), predicted_out.len());
    let n = cfg.shards.max(1);
    anyhow::ensure!(
        engines.len() == n,
        "need exactly one engine per shard ({} != {n})",
        engines.len()
    );
    let mut per_req: Vec<Vec<Request>> = vec![Vec::new(); n];
    let mut per_out: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, r) in requests.iter().enumerate() {
        let s = session_shard(r.id, n);
        per_req[s].push(r.clone());
        per_out[s].push(predicted_out[i]);
    }
    let mut completions: Vec<Completion> =
        Vec::with_capacity(requests.len());
    let mut outcomes = Vec::new();
    for (s, engine) in engines.iter_mut().enumerate() {
        if per_req[s].is_empty() {
            continue;
        }
        let p = SaParams { seed: shard_seed(cfg.sa.seed, s), ..cfg.sa };
        let outcome = run_online_opts(
            &per_req[s],
            &per_out[s],
            engine.as_mut(),
            &cfg.predictor,
            &p,
            cfg.strategy,
            cfg.opts,
        )?;
        completions.extend_from_slice(&outcome.completions);
        outcomes.push((s, outcome));
    }
    completions.sort_by_key(|c| c.id);
    Ok((completions, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Slo, TaskType};

    /// Door wired to raw queues with NO worker threads: deterministic
    /// backpressure tests (queues fill and stay full).
    fn test_door(
        shards: usize,
        queue_depth: usize,
        handoff: bool,
    ) -> (FrontDoor, Vec<Receiver<SubmitMsg>>) {
        let mut handles = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..shards {
            let (tx, rx) = std::sync::mpsc::sync_channel(queue_depth);
            handles.push(ShardHandle {
                tx,
                shared: Arc::new(ShardShared::default()),
                join: Mutex::new(None),
            });
            rxs.push(rx);
        }
        let door = FrontDoor {
            shards: handles,
            door: Arc::new(DoorShared {
                running: AtomicBool::new(true),
                ..DoorShared::default()
            }),
            handoff,
            queue_depth,
            max_total_tokens: 4096,
            next_id: AtomicU64::new(0),
        };
        (door, rxs)
    }

    fn req(input: usize, output: usize) -> Request {
        Request::synthetic(
            0,
            TaskType::Chat,
            input,
            output,
            Slo::Interactive { ttft_ms: 10_000.0, tpot_ms: 50.0 },
        )
    }

    #[test]
    fn session_shard_stable_and_in_range() {
        for session in 0..256u64 {
            let s = session_shard(session, 4);
            assert!(s < 4);
            assert_eq!(s, session_shard(session, 4), "stable");
        }
        // single shard: everything routes to 0
        assert_eq!(session_shard(12345, 1), 0);
        // multi-shard hashing actually spreads sessions out
        let hit: std::collections::HashSet<usize> =
            (0..64u64).map(|s| session_shard(s, 4)).collect();
        assert_eq!(hit.len(), 4, "64 sessions should cover 4 shards");
    }

    #[test]
    fn shard_seed_is_base_verbatim_at_zero() {
        // invariant 12 hinges on this: the single-shard replay must run
        // the SAME seed run_online would.
        assert_eq!(shard_seed(42, 0), 42);
        assert_eq!(shard_seed(42, 1), instance_seed(42, 1));
        assert_ne!(shard_seed(42, 1), 42);
    }

    #[test]
    fn submit_routes_to_home_shard_queue() {
        let (door, rxs) = test_door(2, 4, true);
        let session = 7u64;
        let home = session_shard(session, 2);
        let h = door.submit(session, req(100, 10), false).unwrap();
        assert_eq!(h.shard, home);
        let msg = rxs[home].try_recv().expect("queued on home shard");
        assert_eq!(msg.request.id, h.id);
        assert_eq!(msg.request.input_len, 100);
        assert!(!msg.deferred);
        let d = door.door_stats();
        assert_eq!(d.accepted, 1);
        assert_eq!(d.inflight, 1);
        assert_eq!(d.handoffs, 0);
    }

    #[test]
    fn full_home_queue_hands_off_to_idle_shard() {
        let (door, rxs) = test_door(2, 2, true);
        // find a session homed on shard 0 and fill shard 0's queue
        let session =
            (0..64u64).find(|&s| session_shard(s, 2) == 0).unwrap();
        door.submit(session, req(10, 1), false).unwrap();
        door.submit(session, req(10, 1), false).unwrap();
        // third submission: home full -> lands on shard 1
        let h = door.submit(session, req(10, 1), false).unwrap();
        assert_eq!(h.shard, 1);
        assert_eq!(door.door_stats().handoffs, 1);
        assert_eq!(rxs[1].try_recv().unwrap().request.id, h.id);
    }

    #[test]
    fn all_queues_full_rejects_with_retry_after() {
        let (door, _rxs) = test_door(2, 1, true);
        door.submit(0, req(10, 1), false).unwrap();
        door.submit(1, req(10, 1), false).unwrap();
        // some session's home is full AND the handoff target is full
        let err = door.submit(2, req(10, 1), false).unwrap_err();
        match err {
            SubmitError::Saturated { retry_after_ms } => {
                assert!(retry_after_ms >= 1);
                assert!(retry_after_ms <= 30_000);
            }
            other => panic!("expected Saturated, got {other:?}"),
        }
        let d = door.door_stats();
        assert_eq!(d.rejected, 1);
        assert_eq!(d.accepted, 2);
    }

    #[test]
    fn handoff_disabled_rejects_despite_idle_peer() {
        let (door, _rxs) = test_door(2, 1, false);
        let s_home0 =
            (0..64u64).find(|&s| session_shard(s, 2) == 0).unwrap();
        door.submit(s_home0, req(10, 1), false).unwrap();
        let err = door.submit(s_home0, req(10, 1), false).unwrap_err();
        assert!(matches!(err, SubmitError::Saturated { .. }));
        // shard 1 never saw traffic, yet the request was rejected
        assert_eq!(door.door_stats().handoffs, 0);
    }

    #[test]
    fn invalid_requests_rejected_up_front() {
        let (door, rxs) = test_door(1, 4, true);
        let err = door.submit(0, req(0, 10), false).unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)));
        let err = door.submit(0, req(4000, 4000), false).unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)));
        assert_eq!(door.door_stats().invalid, 2);
        assert_eq!(door.door_stats().accepted, 0);
        assert!(rxs[0].try_recv().is_err(), "nothing reached the queue");
    }

    #[test]
    fn ids_are_unique_and_monotonic() {
        let (door, rxs) = test_door(1, 8, true);
        let ids: Vec<u64> = (0..5)
            .map(|_| door.submit(0, req(50, 5), false).unwrap().id)
            .collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        for want in ids {
            assert_eq!(rxs[0].try_recv().unwrap().request.id, want);
        }
    }
}
