//! Single-threaded non-blocking TCP reactor over the [`FrontDoor`].
//!
//! One thread serves every connection — no thread-per-connection, no
//! blocking reads. Each tick the reactor accepts new sockets, drains
//! readable bytes into per-connection buffers, handles complete
//! JSON-lines, polls each connection's pending [`StreamHandle`]s for
//! events (forwarding them as protocol frames), and flushes write
//! buffers with partial-write carry-over. Clients that merely submitted
//! (no `"stream":true`) get exactly one reply line — the completion —
//! so the wire behaviour of the old blocking server is preserved while
//! the server no longer spends a thread per idle connection.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::server::front::{
    FrontDoor, StreamEvent, StreamHandle, SubmitError, TryNext,
};
use crate::server::protocol::{
    admitted_json, completion_to_json, done_json, error_json, failed_json,
    parse_generate, parse_generate_opts, reject_saturated_json, token_json,
};
use crate::util::json::Json;

/// Handle to the running reactor thread.
pub struct TcpServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Ask the reactor to exit and join it. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Whether the reactor already exited (a client sent `shutdown`).
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One live connection's state.
struct Conn {
    stream: TcpStream,
    /// Read buffer; complete lines are consumed from the front.
    rbuf: Vec<u8>,
    /// Write buffer; flushed as the socket accepts bytes.
    wbuf: Vec<u8>,
    /// In-flight requests submitted by this connection.
    subs: Vec<Sub>,
    /// Connection id — the default routing session.
    id: u64,
    closed: bool,
}

struct Sub {
    handle: StreamHandle,
    /// Client asked for streaming frames.
    stream: bool,
    /// Terminal frame written; the sub can be dropped.
    done: bool,
}

/// Start the reactor on `bind` (e.g. `127.0.0.1:0` for an ephemeral
/// port). The door is shared — callers shut it down separately after
/// stopping the reactor.
pub fn serve_tcp(door: Arc<FrontDoor>, bind: &str) -> Result<TcpServer> {
    let listener = TcpListener::bind(bind)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let reactor_stop = stop.clone();
    let join = std::thread::Builder::new()
        .name("tcp-reactor".into())
        .spawn(move || reactor(listener, door, reactor_stop))?;
    Ok(TcpServer { addr, stop, join: Some(join) })
}

fn reactor(
    listener: TcpListener,
    door: Arc<FrontDoor>,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut next_conn_id = 0u64;
    while !stop.load(Ordering::SeqCst) {
        let mut busy = false;
        // ---- accept
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    conns.push(Conn {
                        stream,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        subs: Vec::new(),
                        id: next_conn_id,
                        closed: false,
                    });
                    next_conn_id += 1;
                    busy = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    break
                }
                Err(_) => break,
            }
        }
        // ---- per-connection: read, handle lines, pump events, flush
        for conn in conns.iter_mut() {
            busy |= read_into(conn);
            while let Some(line) = take_line(&mut conn.rbuf) {
                handle_line(&door, conn, &line, &stop);
                busy = true;
            }
            busy |= pump_events(conn);
            busy |= flush(conn);
        }
        conns.retain(|c| !c.closed);
        if !busy {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

/// Drain readable bytes; returns whether anything was read.
fn read_into(conn: &mut Conn) -> bool {
    let mut any = false;
    let mut buf = [0u8; 4096];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.closed = true;
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&buf[..n]);
                any = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.closed = true;
                break;
            }
        }
    }
    any
}

/// Pop one complete line (without the newline) off the read buffer.
fn take_line(rbuf: &mut Vec<u8>) -> Option<String> {
    let pos = rbuf.iter().position(|&b| b == b'\n')?;
    let line: Vec<u8> = rbuf.drain(..=pos).collect();
    Some(String::from_utf8_lossy(&line[..pos]).into_owned())
}

fn push_frame(conn: &mut Conn, v: &Json) {
    conn.wbuf.extend_from_slice(v.to_string_compact().as_bytes());
    conn.wbuf.push(b'\n');
}

fn handle_line(
    door: &Arc<FrontDoor>,
    conn: &mut Conn,
    line: &str,
    stop: &AtomicBool,
) {
    if line.trim().is_empty() {
        return;
    }
    let msg = match Json::parse(line) {
        Ok(m) => m,
        Err(e) => {
            push_frame(conn, &error_json(400, &format!("bad json: {e}")));
            return;
        }
    };
    match msg.get("op").as_str() {
        Some("generate") => {
            // The front door assigns the real id; 0 is a placeholder.
            let request =
                match parse_generate(&msg, 0, door.max_total_tokens()) {
                    Ok(r) => r,
                    Err(e) => {
                        push_frame(conn, &error_json(400, &e.to_string()));
                        return;
                    }
                };
            let opts = parse_generate_opts(&msg);
            let session = opts.session.unwrap_or(conn.id);
            match door.submit(session, request, opts.stream) {
                Ok(handle) => conn.subs.push(Sub {
                    handle,
                    stream: opts.stream,
                    done: false,
                }),
                Err(SubmitError::Saturated { retry_after_ms }) => {
                    push_frame(
                        conn,
                        &reject_saturated_json(retry_after_ms),
                    );
                }
                Err(SubmitError::Invalid(e)) => {
                    push_frame(conn, &error_json(400, &e));
                }
                Err(SubmitError::ShuttingDown) => {
                    push_frame(conn, &error_json(503, "shutting down"));
                }
            }
        }
        Some("stats") => push_frame(conn, &door.stats_json()),
        Some("shutdown") => {
            push_frame(conn, &Json::obj(vec![("ok", Json::Bool(true))]));
            stop.store(true, Ordering::SeqCst);
        }
        other => push_frame(
            conn,
            &error_json(400, &format!("unknown op {other:?}")),
        ),
    }
}

/// Forward pending stream events as frames; returns whether any event
/// was handled.
fn pump_events(conn: &mut Conn) -> bool {
    let mut any = false;
    for sub in conn.subs.iter_mut() {
        loop {
            match sub.handle.try_next() {
                TryNext::Event(ev) => {
                    any = true;
                    match ev {
                        StreamEvent::Admitted { id, shard, queue_ms } => {
                            if sub.stream {
                                let f = admitted_json(id, shard, queue_ms);
                                conn.wbuf.extend_from_slice(
                                    f.to_string_compact().as_bytes(),
                                );
                                conn.wbuf.push(b'\n');
                            }
                        }
                        StreamEvent::Token { id, index, t_ms } => {
                            if sub.stream {
                                let f = token_json(id, index, t_ms);
                                conn.wbuf.extend_from_slice(
                                    f.to_string_compact().as_bytes(),
                                );
                                conn.wbuf.push(b'\n');
                            }
                        }
                        StreamEvent::Done { completion, .. } => {
                            let f = if sub.stream {
                                done_json(&completion)
                            } else {
                                completion_to_json(&completion)
                            };
                            conn.wbuf.extend_from_slice(
                                f.to_string_compact().as_bytes(),
                            );
                            conn.wbuf.push(b'\n');
                            sub.done = true;
                        }
                        StreamEvent::Failed { id, error } => {
                            let f = failed_json(id, &error);
                            conn.wbuf.extend_from_slice(
                                f.to_string_compact().as_bytes(),
                            );
                            conn.wbuf.push(b'\n');
                            sub.done = true;
                        }
                    }
                    if sub.done {
                        break;
                    }
                }
                TryNext::Empty => break,
                TryNext::Closed => {
                    // No terminal event arrived — a server-side drop.
                    if !sub.done {
                        let f = failed_json(
                            sub.handle.id,
                            "stream closed without completion",
                        );
                        conn.wbuf.extend_from_slice(
                            f.to_string_compact().as_bytes(),
                        );
                        conn.wbuf.push(b'\n');
                        sub.done = true;
                    }
                    break;
                }
            }
        }
    }
    conn.subs.retain(|s| !s.done);
    any
}

/// Flush as much of the write buffer as the socket accepts; returns
/// whether bytes moved.
fn flush(conn: &mut Conn) -> bool {
    if conn.wbuf.is_empty() {
        return false;
    }
    match conn.stream.write(&conn.wbuf) {
        Ok(0) => {
            conn.closed = true;
            false
        }
        Ok(n) => {
            conn.wbuf.drain(..n);
            true
        }
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => false,
        Err(_) => {
            conn.closed = true;
            false
        }
    }
}

/// Minimal blocking client for the JSON-lines protocol (tests, examples,
/// and the CLI's smoke path).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one message line.
    pub fn send(&mut self, msg: &Json) -> Result<()> {
        let mut text = msg.to_string_compact();
        text.push('\n');
        self.writer.write_all(text.as_bytes())?;
        Ok(())
    }

    /// Block for the next reply line.
    pub fn next_line(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(anyhow!("connection closed"));
        }
        Json::parse(&line).map_err(|e| anyhow!("bad reply: {e}"))
    }

    /// Send one request, wait for one reply line.
    pub fn call(&mut self, msg: &Json) -> Result<Json> {
        self.send(msg)?;
        self.next_line()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_line_pops_complete_lines_in_order() {
        let mut rbuf = b"first\nsecond\npartial".to_vec();
        assert_eq!(take_line(&mut rbuf).as_deref(), Some("first"));
        assert_eq!(take_line(&mut rbuf).as_deref(), Some("second"));
        // no newline yet: nothing consumed, the partial tail stays intact
        assert_eq!(take_line(&mut rbuf), None);
        assert_eq!(rbuf, b"partial");
        rbuf.extend_from_slice(b" done\n");
        assert_eq!(take_line(&mut rbuf).as_deref(), Some("partial done"));
        assert!(rbuf.is_empty());
    }

    #[test]
    fn take_line_edge_frames() {
        // empty line (bare newline) is a line — handle_line ignores it
        let mut rbuf = b"\nx\n".to_vec();
        assert_eq!(take_line(&mut rbuf).as_deref(), Some(""));
        assert_eq!(take_line(&mut rbuf).as_deref(), Some("x"));
        // CRLF: the \r survives into the line (trimmed by handle_line)
        let mut rbuf = b"ok\r\n".to_vec();
        assert_eq!(take_line(&mut rbuf).as_deref(), Some("ok\r"));
        // invalid UTF-8 is replaced, never panics, and the buffer advances
        let mut rbuf = vec![0xff, 0xfe, b'a', b'\n', b'z'];
        let line = take_line(&mut rbuf).unwrap();
        assert!(line.ends_with('a'));
        assert!(line.contains('\u{FFFD}'));
        assert_eq!(rbuf, b"z");
    }

    /// Connected nonblocking pair: (reactor side wrapped in a Conn, peer).
    fn conn_pair() -> (Conn, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        let conn = Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            subs: Vec::new(),
            id: 0,
            closed: false,
        };
        (conn, peer)
    }

    fn spin(mut f: impl FnMut() -> bool) {
        for _ in 0..500 {
            if f() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        panic!("condition not reached within timeout");
    }

    #[test]
    fn read_into_handles_wouldblock_data_and_eof() {
        let (mut conn, mut peer) = conn_pair();
        // nothing sent yet: the nonblocking read hits EWOULDBLOCK —
        // no bytes, and crucially the connection is NOT treated as closed
        assert!(!read_into(&mut conn));
        assert!(!conn.closed);
        assert!(conn.rbuf.is_empty());
        // peer writes a frame: read_into drains it
        peer.write_all(b"{\"op\":\"stats\"}\n").unwrap();
        spin(|| read_into(&mut conn));
        assert_eq!(
            take_line(&mut conn.rbuf).as_deref(),
            Some("{\"op\":\"stats\"}")
        );
        assert!(!conn.closed);
        // peer hangs up: read returns 0 ⇒ the conn is marked closed
        drop(peer);
        spin(|| {
            read_into(&mut conn);
            conn.closed
        });
    }

    #[test]
    fn flush_drains_write_buffer_with_carry_over() {
        let (mut conn, mut peer) = conn_pair();
        // empty write buffer: nothing to do
        assert!(!flush(&mut conn));
        conn.wbuf.extend_from_slice(b"hello\n");
        spin(|| {
            flush(&mut conn);
            conn.wbuf.is_empty()
        });
        assert!(!conn.closed);
        let mut reader = BufReader::new(&mut peer);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "hello\n");
    }
}
