//! Token sampling strategies for the real engine.

use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    /// Deterministic argmax.
    Greedy,
    /// Top-k sampling with temperature.
    TopK { k: usize, temperature: f64 },
}

impl Sampler {
    /// Pick the next token id from a logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        match *self {
            Sampler::Greedy => argmax(logits),
            Sampler::TopK { k, temperature } => {
                top_k_sample(logits, k.max(1), temperature.max(1e-6), rng)
            }
        }
    }
}

/// Index of the maximum logit (first on ties).
pub fn argmax(logits: &[f32]) -> i32 {
    assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut best_v = logits[0];
    for (i, &v) in logits.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best as i32
}

/// Softmax-normalized top-k sampling with temperature.
pub fn top_k_sample(
    logits: &[f32],
    k: usize,
    temperature: f64,
    rng: &mut Rng,
) -> i32 {
    assert!(!logits.is_empty());
    let k = k.min(logits.len());
    // indices of the k largest logits
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    let max_logit = logits[idx[0]] as f64;
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - max_logit) / temperature).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut target = rng.f64() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        target -= w;
        if target <= 0.0 {
            return i as i32;
        }
    }
    idx[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0); // first wins ties
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn greedy_sampler_matches_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0f32, 9.0, 3.0];
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn top_k_only_picks_top_k() {
        let mut rng = Rng::new(1);
        let logits = vec![10.0f32, 9.0, -50.0, -50.0];
        for _ in 0..200 {
            let t = top_k_sample(&logits, 2, 1.0, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = vec![2.0f32, 1.0, 0.0];
        let picks: Vec<i32> = (0..200)
            .map(|_| top_k_sample(&logits, 3, 0.01, &mut rng))
            .collect();
        assert!(picks.iter().all(|&p| p == 0));
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Rng::new(3);
        let logits = vec![2.0f32, 1.9, 1.8];
        let mut seen = [false; 3];
        for _ in 0..500 {
            seen[top_k_sample(&logits, 3, 5.0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
