//! Instance workers: one OS thread per LLM inference instance.
//!
//! tokio is unavailable offline (DESIGN.md §2); the concurrency model is a
//! worker thread per instance with an mpsc command channel — the same
//! leader/worker topology a tokio runtime would express, with the leader
//! (coordinator / server) dispatching planned batches and collecting
//! results.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::engine::{Engine, EngineRequest, ItemResult};

enum Cmd {
    RunBatch(Vec<EngineRequest>, Sender<Result<Vec<ItemResult>>>),
    Clock(Sender<f64>),
    Shutdown,
}

/// Handle to a running instance worker.
pub struct InstanceHandle {
    pub id: usize,
    tx: Sender<Cmd>,
    join: Option<JoinHandle<()>>,
}

impl InstanceHandle {
    /// Spawn a worker owning `engine`.
    pub fn spawn(id: usize, mut engine: Box<dyn Engine + Send>) -> Self {
        let (tx, rx): (Sender<Cmd>, Receiver<Cmd>) = channel();
        let join = std::thread::Builder::new()
            .name(format!("instance-{id}"))
            .spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::RunBatch(batch, reply) => {
                            let _ = reply.send(engine.run_batch(&batch));
                        }
                        Cmd::Clock(reply) => {
                            let _ = reply.send(engine.now_ms());
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })
            .expect("spawn instance worker");
        InstanceHandle { id, tx, join: Some(join) }
    }

    /// Submit a batch; returns a receiver for the result (non-blocking
    /// dispatch — await with [`BatchTicket::wait`]).
    pub fn submit(&self, batch: Vec<EngineRequest>) -> BatchTicket {
        let (reply_tx, reply_rx) = channel();
        let _ = self.tx.send(Cmd::RunBatch(batch, reply_tx));
        BatchTicket { rx: reply_rx }
    }

    /// Blocking convenience wrapper.
    pub fn run_batch(
        &self,
        batch: Vec<EngineRequest>,
    ) -> Result<Vec<ItemResult>> {
        self.submit(batch).wait()
    }

    /// Engine clock (ms).
    pub fn now_ms(&self) -> Result<f64> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Clock(tx))
            .map_err(|_| anyhow!("instance worker gone"))?;
        rx.recv().map_err(|_| anyhow!("instance worker gone"))
    }
}

impl Drop for InstanceHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Pending batch execution.
pub struct BatchTicket {
    rx: Receiver<Result<Vec<ItemResult>>>,
}

impl BatchTicket {
    pub fn wait(self) -> Result<Vec<ItemResult>> {
        self.rx.recv().map_err(|_| anyhow!("instance worker dropped"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::by_name;
    use crate::engine::sim::SimEngine;

    fn sim_instance(id: usize) -> InstanceHandle {
        let engine = SimEngine::new(
            by_name("qwen7b-v100x2-vllm").unwrap(),
            4,
            id as u64,
        );
        InstanceHandle::spawn(id, Box::new(engine))
    }

    fn req(id: u64) -> EngineRequest {
        EngineRequest { id, input_len: 100, max_new_tokens: 5, prompt: None }
    }

    #[test]
    fn run_batch_roundtrip() {
        let inst = sim_instance(0);
        let out = inst.run_batch(vec![req(1), req(2)]).unwrap();
        assert_eq!(out.len(), 2);
        assert!(inst.now_ms().unwrap() > 0.0);
    }

    #[test]
    fn concurrent_instances_progress_independently() {
        let a = sim_instance(0);
        let b = sim_instance(1);
        let ta = a.submit(vec![req(1)]);
        let tb = b.submit(vec![req(2)]);
        assert!(ta.wait().is_ok());
        assert!(tb.wait().is_ok());
    }

    #[test]
    fn error_propagates() {
        let inst = sim_instance(0);
        // batch too large for max_batch=4
        let batch: Vec<EngineRequest> = (0..9).map(req).collect();
        assert!(inst.run_batch(batch).is_err());
    }

    #[test]
    fn queued_batches_execute_in_order() {
        let inst = sim_instance(0);
        let t1 = inst.submit(vec![req(1)]);
        let t2 = inst.submit(vec![req(2)]);
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        // second batch starts after the first finishes (same engine clock)
        assert!(r2[0].start_ms >= r1[0].finish_ms);
    }
}
