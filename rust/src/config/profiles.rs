//! Hardware/framework profiles for the simulated engine.
//!
//! The paper evaluates Qwen2.5-7B/32B on 2×V100, 4×V100, and 1×A800 under
//! vLLM and LMDeploy (Figs. 7, 12–18). Those testbeds are unavailable here
//! (DESIGN.md §2); each is modelled as a coefficient set for the paper's own
//! latency structure (Eqs. 14–15), anchored at the paper's measured Table 2
//! values for Qwen2.5-7B @ 2×V100 (vLLM) and scaled for the others:
//!
//! * Qwen2.5-32B ≈ 4.5× the compute of 7B (params ratio, same architecture
//!   family) but runs on 4×V100 (2× the devices) ⇒ ~2.3× the latency.
//! * A800 ≈ 2.5× the effective throughput of 2×V100 for FP16 inference.
//! * LMDeploy's quantized kernels ⇒ ~0.85× of vLLM's latency on identical
//!   hardware (its headline claim), with a slightly better decode constant.
//!
//! The *shape* of the scheduling results depends only on this latency
//! structure + memory capacity, which is what the profiles preserve.

use crate::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
use crate::coordinator::profiler::MemoryModel;

/// A simulated serving testbed: model × hardware × framework.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    pub name: String,
    /// Ground-truth latency model for the simulated engine (the scheduler
    /// must *fit* its own predictor from profiling, per §4.2).
    pub truth: LatencyPredictor,
    /// KV-cache pool (MB).
    pub kv_pool_mb: f64,
    pub mem: MemoryModel,
    /// Relative execution-time noise (std of a ~N(1, σ) multiplier).
    pub noise_std: f64,
    /// Longest request (input + output tokens) the testbed accepts.
    pub max_total_tokens: usize,
}

fn scale(p: &LatencyPredictor, factor: f64) -> LatencyPredictor {
    LatencyPredictor::new(p.prefill.scaled(factor), p.decode.scaled(factor))
}

/// Paper Table 2 anchor: Qwen2.5-7B @ 2×V100, vLLM (ms).
fn table2() -> LatencyPredictor {
    LatencyPredictor::paper_table2()
}

/// All built-in profiles (paper Figs. 7, 12–18 testbeds).
pub fn builtin_profiles() -> Vec<HardwareProfile> {
    let mem7b = MemoryModel { utility: 0.9, mb_per_token: 0.5 };
    let mem32b = MemoryModel { utility: 0.9, mb_per_token: 1.6 };
    let t2 = table2();
    vec![
        // ---- Fig. 7 / Fig. 12 testbed: Qwen2.5-7B @ 2×V100
        HardwareProfile {
            name: "qwen7b-v100x2-vllm".into(),
            truth: t2,
            // 2×32 GB minus ~15 GB weights, 90% usable
            kv_pool_mb: 20_000.0,
            mem: mem7b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        HardwareProfile {
            name: "qwen7b-v100x2-lmdeploy".into(),
            truth: LatencyPredictor::new(
                t2.prefill.scaled(0.85),
                PhaseCoeffs { delta: t2.decode.delta * 0.80, ..t2.decode.scaled(0.85) },
            ),
            kv_pool_mb: 22_000.0, // quantized weights free memory
            mem: mem7b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        // ---- Fig. 13/14 testbed: Qwen2.5-32B @ 4×V100
        HardwareProfile {
            name: "qwen32b-v100x4-vllm".into(),
            truth: scale(&t2, 2.3),
            kv_pool_mb: 24_000.0,
            mem: mem32b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        HardwareProfile {
            name: "qwen32b-v100x4-lmdeploy".into(),
            truth: scale(&t2, 2.3 * 0.85),
            kv_pool_mb: 27_000.0,
            mem: mem32b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        // ---- Fig. 15/16 testbed: Qwen2.5-7B @ 1×A800
        HardwareProfile {
            name: "qwen7b-a800-vllm".into(),
            truth: scale(&t2, 0.4),
            kv_pool_mb: 50_000.0,
            mem: mem7b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        HardwareProfile {
            name: "qwen7b-a800-lmdeploy".into(),
            truth: scale(&t2, 0.4 * 0.85),
            kv_pool_mb: 52_000.0,
            mem: mem7b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        // ---- Fig. 17/18 testbed: Qwen2.5-32B @ 1×A800 (the "strict" corner
        // that shows the paper's 5× attainment headline: slow model, one
        // fast-but-saturated device)
        HardwareProfile {
            name: "qwen32b-a800-vllm".into(),
            truth: scale(&t2, 1.8),
            kv_pool_mb: 30_000.0,
            mem: mem32b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        HardwareProfile {
            name: "qwen32b-a800-lmdeploy".into(),
            truth: scale(&t2, 1.8 * 0.85),
            kv_pool_mb: 33_000.0,
            mem: mem32b,
            noise_std: 0.03,
            max_total_tokens: 2048,
        },
        // ---- the real TinyLM CPU testbed (calibrated at startup by
        // profiling the actual PJRT engine; placeholder coefficients here)
        HardwareProfile {
            name: "tinylm-cpu".into(),
            truth: LatencyPredictor::new(
                PhaseCoeffs {
                    alpha: 0.002,
                    beta: 2.0,
                    gamma: 0.05,
                    delta: 5.0,
                },
                PhaseCoeffs {
                    alpha: 0.0002,
                    beta: 1.0,
                    gamma: 0.002,
                    delta: 8.0,
                },
            ),
            kv_pool_mb: 2_000.0,
            mem: MemoryModel { utility: 0.9, mb_per_token: 0.03 },
            noise_std: 0.05,
            max_total_tokens: 380,
        },
    ]
}

/// Look up a profile by name.
pub fn by_name(name: &str) -> Option<HardwareProfile> {
    builtin_profiles().into_iter().find(|p| p.name == name)
}

/// Names of all built-in profiles.
pub fn profile_names() -> Vec<String> {
    builtin_profiles().into_iter().map(|p| p.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_profile_matches_table2() {
        let p = by_name("qwen7b-v100x2-vllm").unwrap();
        assert_eq!(p.truth, LatencyPredictor::paper_table2());
    }

    #[test]
    fn all_profiles_resolvable_and_sane() {
        for p in builtin_profiles() {
            assert!(by_name(&p.name).is_some());
            assert!(p.kv_pool_mb > 0.0);
            assert!(p.noise_std >= 0.0);
            assert!(p.max_total_tokens > 0);
            // latency must be positive over the supported range
            let solo = p.truth.predict(1, 100, 50);
            assert!(solo.exec_ms > 0.0, "{}", p.name);
            assert!(p.truth.prefill_ms(1, 1) > 0.0, "{}", p.name);
        }
    }

    #[test]
    fn lmdeploy_faster_than_vllm() {
        for (v, l) in [
            ("qwen7b-v100x2-vllm", "qwen7b-v100x2-lmdeploy"),
            ("qwen32b-a800-vllm", "qwen32b-a800-lmdeploy"),
        ] {
            let v = by_name(v).unwrap();
            let l = by_name(l).unwrap();
            assert!(
                l.truth.predict(4, 500, 100).exec_ms
                    < v.truth.predict(4, 500, 100).exec_ms
            );
        }
    }

    #[test]
    fn bigger_model_slower() {
        let small = by_name("qwen7b-v100x2-vllm").unwrap();
        let big = by_name("qwen32b-v100x4-vllm").unwrap();
        assert!(
            big.truth.predict(1, 500, 100).exec_ms
                > small.truth.predict(1, 500, 100).exec_ms
        );
    }

    #[test]
    fn unknown_profile_is_none() {
        assert!(by_name("h100-cluster").is_none());
    }
}
