"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block sizes; numpy.testing asserts allclose.
This is the CORE correctness signal for the compute hot spot — everything
the Rust engine executes flows through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

F32_TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash_attention (prefill)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_matches_ref_f32(b, h, s, d, causal, seed):
    q = _rand(seed, (b, h, s, d), jnp.float32)
    k = _rand(seed + 1, (b, h, s, d), jnp.float32)
    v = _rand(seed + 2, (b, h, s, d), jnp.float32)
    out = A.flash_attention(q, k, v, causal=causal)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, **F32_TOL)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**16),
)
def test_flash_matches_ref_bf16(s, seed):
    shape = (2, 2, s, 32)
    q = _rand(seed, shape, jnp.bfloat16)
    k = _rand(seed + 1, shape, jnp.bfloat16)
    v = _rand(seed + 2, shape, jnp.bfloat16)
    out = A.flash_attention(q, k, v, causal=True)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32), ref.astype(np.float32),
                               **BF16_TOL)


@pytest.mark.parametrize("bq,bk", [(16, 16), (16, 32), (32, 16), (64, 64)])
def test_flash_block_size_invariance(bq, bk):
    """Output must not depend on the VMEM tile decomposition."""
    shape = (2, 2, 64, 32)
    q, k, v = (_rand(i, shape, jnp.float32) for i in range(3))
    base = A.flash_attention(q, k, v, block_q=64, block_k=64)
    out = A.flash_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(out, base, **F32_TOL)


def test_flash_rejects_bad_blocks():
    shape = (1, 1, 48, 16)
    q, k, v = (_rand(i, shape, jnp.float32) for i in range(3))
    with pytest.raises(ValueError):
        A.flash_attention(q, k, v, block_q=32, block_k=32)


def test_flash_rejects_shape_mismatch():
    q = _rand(0, (1, 1, 32, 16), jnp.float32)
    k = _rand(1, (1, 1, 64, 16), jnp.float32)
    with pytest.raises(ValueError):
        A.flash_attention(q, k, k)
    with pytest.raises(ValueError):
        A.flash_attention(q, q, k)


def test_flash_causal_ignores_future():
    """Perturbing tokens after position p must not change output at p."""
    shape = (1, 2, 64, 16)
    q, k, v = (_rand(i, shape, jnp.float32) for i in range(3))
    out1 = A.flash_attention(q, k, v, causal=True)
    k2 = k.at[:, :, 40:, :].set(99.0)
    v2 = v.at[:, :, 40:, :].set(-99.0)
    out2 = A.flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(out1[:, :, :40], out2[:, :, :40], **F32_TOL)
    # sanity: later positions DO change
    assert not np.allclose(out1[:, :, 40:], out2[:, :, 40:], atol=1e-3)


def test_flash_softmax_rowsum_property():
    """With v = ones, attention output must be exactly ones (softmax sums 1)."""
    q = _rand(0, (2, 2, 64, 16), jnp.float32)
    k = _rand(1, (2, 2, 64, 16), jnp.float32)
    v = jnp.ones((2, 2, 64, 16), jnp.float32)
    out = A.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([64, 128, 384]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_decode_matches_ref_f32(b, h, s, d, seed, data):
    pos = np.array(
        [data.draw(st.integers(0, s - 1)) for _ in range(b)], np.int32)
    q = _rand(seed, (b, h, d), jnp.float32)
    kc = _rand(seed + 1, (b, h, s, d), jnp.float32)
    vc = _rand(seed + 2, (b, h, s, d), jnp.float32)
    out = A.decode_attention(q, kc, vc, jnp.asarray(pos))
    ref = R.decode_attention_ref(q, kc, vc, jnp.asarray(pos))
    np.testing.assert_allclose(out, ref, **F32_TOL)


def test_decode_masks_garbage_beyond_pos():
    """Slots beyond pos are garbage in real serving; they must not leak."""
    b, h, s, d = 2, 2, 128, 16
    q = _rand(0, (b, h, d), jnp.float32)
    kc = _rand(1, (b, h, s, d), jnp.float32)
    vc = _rand(2, (b, h, s, d), jnp.float32)
    pos = jnp.array([10, 50], jnp.int32)
    base = A.decode_attention(q, kc, vc, pos)
    kc2 = kc.at[0, :, 11:, :].set(1e6).at[1, :, 51:, :].set(1e6)
    vc2 = vc.at[0, :, 11:, :].set(-1e6).at[1, :, 51:, :].set(-1e6)
    out = A.decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(out, base, **F32_TOL)


def test_decode_pos_zero():
    """pos = 0: output must equal v[0] exactly (single-key softmax)."""
    b, h, s, d = 1, 2, 64, 16
    q = _rand(0, (b, h, d), jnp.float32)
    kc = _rand(1, (b, h, s, d), jnp.float32)
    vc = _rand(2, (b, h, s, d), jnp.float32)
    out = A.decode_attention(q, kc, vc, jnp.zeros((b,), jnp.int32))
    np.testing.assert_allclose(out, vc[:, :, 0, :], **F32_TOL)


def test_decode_block_size_invariance():
    b, h, s, d = 2, 2, 128, 32
    q = _rand(0, (b, h, d), jnp.float32)
    kc = _rand(1, (b, h, s, d), jnp.float32)
    vc = _rand(2, (b, h, s, d), jnp.float32)
    pos = jnp.array([17, 100], jnp.int32)
    base = A.decode_attention(q, kc, vc, pos, block_k=128)
    for bk in (16, 32, 64):
        out = A.decode_attention(q, kc, vc, pos, block_k=bk)
        np.testing.assert_allclose(out, base, **F32_TOL)


def test_decode_rejects_bad_shapes():
    q = _rand(0, (2, 2, 16), jnp.float32)
    kc = _rand(1, (2, 2, 64, 16), jnp.float32)
    with pytest.raises(ValueError):
        A.decode_attention(q, kc, kc, jnp.zeros((3,), jnp.int32))  # pos len
    with pytest.raises(ValueError):
        A.decode_attention(q, kc[:, :1], kc, jnp.zeros((2,), jnp.int32))


def test_decode_matches_flash_last_row():
    """Decode at pos = S-1 over a fully-populated cache must equal the last
    row of causal flash attention with the same q/k/v."""
    b, h, s, d = 2, 2, 64, 32
    q = _rand(0, (b, h, s, d), jnp.float32)
    k = _rand(1, (b, h, s, d), jnp.float32)
    v = _rand(2, (b, h, s, d), jnp.float32)
    full = A.flash_attention(q, k, v, causal=True)
    last = A.decode_attention(q[:, :, -1, :], k, v,
                              jnp.full((b,), s - 1, jnp.int32))
    np.testing.assert_allclose(last, full[:, :, -1, :], **F32_TOL)
