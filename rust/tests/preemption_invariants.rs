//! Preemption / KV-swap / migration fault-injection harness (ISSUE 9
//! acceptance).
//!
//! PR 9 replaces the force-stop truncation of overcommitted decodes with
//! a real preemption model: suspend the slackest member mid-decode, hold
//! its KV for recompute or swap it to a modeled host buffer, resume it
//! most-urgent-first when blocks free up, and (at the fleet level) shed
//! work from a saturated instance to an idle peer. This harness pins the
//! properties that make that safe to ship:
//!
//! * **fault-injection grid** — seeds × {Reserve, Phased} × {Hard, Soft}
//!   KV modes × σ ∈ {0, 0.5} × {recompute, swap}: after any sequence of
//!   suspend / swap-out / swap-in / resume events, no KV block leaks
//!   (the allocator drains to empty), every admitted request completes
//!   exactly once with its full divergent output, and `kv_truncations`
//!   stays zero whenever preemption is enabled and a single context fits
//!   the pool;
//! * **invariant 14 (escape hatch)** — preemption off replays the PR 8
//!   truncating stack byte for byte (`to_bits()` on completions and
//!   predictions), and the migrating fleet loop with `migrate: false`
//!   replays the plain fleet loop byte for byte;
//! * **directed scenarios** — a two-overrunner batch whose geometry is
//!   computed in-test (QuantileTrace actuals are a pure function of the
//!   request id) pins victim selection by SLO slack, the exact
//!   suspension context, and swap/recompute cost accounting against a
//!   sequential reference;
//! * **PR 5 regression** — with preemption disabled, pool exhaustion
//!   still truncates (`kv_truncations` increments) and rolls back
//!   leak-free.

use slo_serve::config::profiles::{by_name, HardwareProfile};
use slo_serve::coordinator::kv::{KvConfig, KvPhaseModel};
use slo_serve::coordinator::online::{
    run_online_fleet_migrating, run_online_fleet_opts, run_online_opts,
    OnlineOpts, OnlineOutcome, ReplanStrategy,
};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::request::{Completion, Request, Slo, TaskType};
use slo_serve::engine::sim::{
    DivergenceModel, PreemptConfig, PreemptMode, SimEngine,
};
use slo_serve::engine::{Engine, EngineRequest, PreemptionStats};
use slo_serve::util::rng::Rng;

/// Engine block granularity (tokens per KV block), fixed by
/// `SimEngine`'s pool construction.
const BLOCK_TOKENS: usize = 16;

fn blocks(tokens: usize) -> usize {
    tokens.div_ceil(BLOCK_TOKENS)
}

/// Profile with a pool of exactly `pool_blocks` KV blocks and no timing
/// noise (noise only scales step times; preemption costs are noiseless
/// by construction, but determinism assertions are simplest at σ = 0).
fn pooled_profile(pool_blocks: usize) -> HardwareProfile {
    let mut p = by_name("qwen7b-v100x2-vllm").unwrap();
    p.noise_std = 0.0;
    p.kv_pool_mb =
        pool_blocks as f64 * BLOCK_TOKENS as f64 * p.mem.mb_per_token;
    p
}

/// QuantileTrace actuals are a pure function of the request id — the
/// rng parameter is never consumed — so tests can *choose* how far a
/// request overruns by searching ids.
fn actual_of(model: &DivergenceModel, id: u64, nominal: usize) -> usize {
    let mut rng = Rng::new(0);
    model.actual_lo(id, nominal, &mut rng)
}

/// Smallest unused id whose QuantileTrace actual for `nominal` lands in
/// `[lo, hi]`.
fn find_id(
    model: &DivergenceModel,
    nominal: usize,
    lo: usize,
    hi: usize,
    used: &[u64],
) -> u64 {
    (0..1_000_000u64)
        .find(|id| {
            !used.contains(id) && {
                let a = actual_of(model, *id, nominal);
                a >= lo && a <= hi
            }
        })
        .expect("no id with the requested overrun window")
}

fn completion_bits(c: &Completion) -> (u64, u64, u64, u64, usize) {
    (
        c.id,
        c.e2e_ms.to_bits(),
        c.ttft_ms.to_bits(),
        c.wait_ms.to_bits(),
        c.generated,
    )
}

struct GridTrace {
    requests: Vec<Request>,
    outs: Vec<usize>,
    /// True output length per request under the cell's divergence model
    /// (position-aligned with `requests`).
    actuals: Vec<usize>,
}

/// `n` requests whose ids are chosen so that *every* request overruns
/// its nominal output 2–5× under σ = 0.5 QuantileTrace divergence. At
/// σ = 0 the same ids produce exactly-nominal outputs, so one trace
/// shape serves both grid columns.
fn overrun_trace(model: &DivergenceModel, n: usize) -> GridTrace {
    let search = DivergenceModel::QuantileTrace { sigma: 0.5 };
    let mut used: Vec<u64> = Vec::new();
    let mut requests = Vec::new();
    let mut t = 0.0f64;
    for i in 0..n {
        let input = 32 + 8 * (i % 8);
        let nominal = 8 + 4 * (i % 5);
        let id = find_id(&search, nominal, 2 * nominal, 5 * nominal, &used);
        used.push(id);
        t += 40.0 + 90.0 * (i % 3) as f64;
        let mut r = Request::synthetic(
            id,
            if i % 2 == 0 { TaskType::Chat } else { TaskType::Code },
            input,
            nominal,
            Slo::E2e { e2e_ms: 3_000.0 + 2_500.0 * i as f64 },
        );
        r.arrival_ms = t;
        requests.push(r);
    }
    let outs: Vec<usize> = requests.iter().map(|r| r.output_len).collect();
    let actuals: Vec<usize> = requests
        .iter()
        .map(|r| actual_of(model, r.id, r.output_len))
        .collect();
    GridTrace { requests, outs, actuals }
}

/// Pool (blocks) that provably prevents truncation under preemption:
/// big enough that the single largest true context always fits with a
/// one-block growth margin, yet far below a typical batch's true
/// demand, so divergence overruns *must* preempt to make progress.
fn tight_pool(gt: &GridTrace) -> usize {
    gt.requests
        .iter()
        .zip(&gt.actuals)
        .map(|(r, &a)| blocks(r.input_len + a.max(r.output_len) + 1))
        .max()
        .unwrap()
        + 2
}

fn sorted_ids(completions: &[Completion]) -> Vec<u64> {
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids
}

/// One grid cell: run the online loop under fault injection, check
/// every safety invariant, and return the engine-side preemption stats
/// so the caller can assert the grid as a whole actually preempted.
fn run_grid_cell(
    seed: u64,
    phase: KvPhaseModel,
    soft: bool,
    sigma: f64,
    preempt: PreemptConfig,
    check_determinism: bool,
) -> PreemptionStats {
    let model = DivergenceModel::QuantileTrace { sigma };
    let n = 10;
    let gt = overrun_trace(&model, n);
    let tag = format!(
        "seed {seed} {phase:?} soft={soft} sigma={sigma} {:?}",
        preempt.mode
    );

    // Hard cells bind the engine pool and the planner to the same tight
    // budget; soft cells keep the engine pool at the profile default
    // (the soft penalty may plan nominal overcommit of its *own* pool,
    // and the engine rejects truly infeasible batches outright).
    let (profile, kv) = if soft {
        let mut p = by_name("qwen7b-v100x2-vllm").unwrap();
        p.noise_std = 0.0;
        (p, KvConfig::soft(48, 1.0).with_phase(phase))
    } else {
        let pool = tight_pool(&gt);
        (pooled_profile(pool), KvConfig::hard(pool as u64).with_phase(phase))
    };
    let predictor = profile.truth;
    let sa = SaParams {
        max_batch: 4,
        seed,
        t0: 100.0,
        iters_per_temp: 8,
        kv,
        ..Default::default()
    };
    let opts = OnlineOpts {
        arrival_aware: true,
        replan_drift_ms: 150.0,
        ..Default::default()
    };

    let run = || -> (OnlineOutcome, PreemptionStats) {
        let mut engine = SimEngine::new(profile.clone(), 4, seed)
            .with_kv_phase(phase)
            .with_divergence(model)
            .with_preemption(preempt);
        let out = run_online_opts(
            &gt.requests,
            &gt.outs,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            opts,
        )
        .unwrap_or_else(|e| panic!("{tag}: run failed: {e}"));
        let p = engine.preemption_stats();
        // no KV leak after any suspend/swap/resume sequence
        assert_eq!(engine.kv().active_seqs(), 0, "{tag}: leaked seqs");
        assert_eq!(
            engine.kv().free_blocks(),
            engine.kv().config().total_blocks,
            "{tag}: leaked blocks"
        );
        assert!(
            engine.peak_used_blocks() <= engine.kv().config().total_blocks,
            "{tag}: pool overrun"
        );
        (out, p)
    };
    let (out, p) = run();

    // every admitted request completes exactly once …
    assert_eq!(out.completions.len(), n, "{tag}: wrong completion count");
    let mut expect: Vec<u64> = gt.requests.iter().map(|r| r.id).collect();
    expect.sort_unstable();
    assert_eq!(
        sorted_ids(&out.completions),
        expect,
        "{tag}: duplicate or missing completions"
    );
    // … with its *full* divergent output: preemption suspends and
    // resumes instead of truncating.
    assert_eq!(p.kv_truncations, 0, "{tag}: truncated under preemption");
    for c in &out.completions {
        let i = gt.requests.iter().position(|r| r.id == c.id).unwrap();
        assert_eq!(
            c.generated, gt.actuals[i],
            "{tag}: id {} generated {} != true length {}",
            c.id, c.generated, gt.actuals[i]
        );
    }

    // mode-specific accounting: with truncations at zero, every
    // suspension is resumed exactly once, by swap-in when the host
    // buffer fits (it always does here) and by recompute otherwise.
    match preempt.mode {
        PreemptMode::Swap => {
            assert_eq!(p.swap_outs, p.preemptions, "{tag}");
            assert_eq!(p.swap_ins, p.swap_outs, "{tag}: unresumed swap");
            assert_eq!(p.recompute_resumes, 0, "{tag}");
            // swap-cost accounting: total ms == total blocks moved ×
            // the link's per-block cost (sequential reference).
            let per_block = profile.mem.mb_per_token
                * BLOCK_TOKENS as f64
                / preempt.swap_gbps;
            let expect_ms = p.swap_blocks as f64 * per_block;
            assert!(
                (p.swap_ms - expect_ms).abs()
                    <= 1e-9 * expect_ms.max(1.0),
                "{tag}: swap_ms {} != blocks×cost {}",
                p.swap_ms,
                expect_ms
            );
        }
        PreemptMode::Recompute => {
            assert_eq!(p.swap_outs, 0, "{tag}");
            assert_eq!(p.swap_ins, 0, "{tag}");
            assert_eq!(p.swap_blocks, 0, "{tag}");
            assert_eq!(p.swap_ms, 0.0, "{tag}");
            assert_eq!(p.recompute_resumes, p.preemptions, "{tag}");
            if p.preemptions > 0 {
                assert!(p.recompute_ms > 0.0, "{tag}: free recompute");
            }
        }
        PreemptMode::Off => unreachable!("grid only runs enabled modes"),
    }
    // the online counter mirrors the engine-side count (and stays
    // distinct from admission deferrals by construction)
    assert_eq!(out.stats.preemptions, p.preemptions, "{tag}");
    assert_eq!(out.stats.migrations, 0, "{tag}");

    if sigma == 0.0 {
        // exactly-nominal outputs: a Reserve-planned batch can never
        // outgrow its reservation, so nothing is ever suspended.
        assert!(gt.actuals == gt.outs, "{tag}: σ=0 must be nominal");
        if phase == KvPhaseModel::Reserve {
            assert_eq!(p.preemptions, 0, "{tag}: preempted at σ=0");
        }
    }

    if check_determinism {
        let (out2, p2) = run();
        assert_eq!(p, p2, "{tag}: preemption stats not deterministic");
        assert_eq!(out.completions.len(), out2.completions.len(), "{tag}");
        for (a, b) in out.completions.iter().zip(&out2.completions) {
            assert_eq!(
                completion_bits(a),
                completion_bits(b),
                "{tag}: completions not deterministic"
            );
        }
    }
    p
}

/// The tentpole grid: seeds × {Reserve, Phased} × {Hard, Soft} ×
/// σ ∈ {0, 0.5} × {recompute, swap}. Invariants per cell are asserted
/// inside `run_grid_cell`; across the grid, fault injection must have
/// actually fired (hard pools + universal 2–5× overruns guarantee it).
#[test]
fn grid_invariants_under_fault_injection() {
    let mut total_preemptions = 0usize;
    let mut total_swaps = 0usize;
    for seed in [1u64, 2] {
        for phase in [KvPhaseModel::Reserve, KvPhaseModel::Phased] {
            for soft in [false, true] {
                for sigma in [0.0, 0.5] {
                    for preempt in [
                        PreemptConfig::recompute(),
                        PreemptConfig::swap(8.0, 10_000),
                    ] {
                        let deterministic = seed == 1 && sigma == 0.5;
                        let p = run_grid_cell(
                            seed,
                            phase,
                            soft,
                            sigma,
                            preempt,
                            deterministic,
                        );
                        total_preemptions += p.preemptions;
                        total_swaps += p.swap_ins;
                    }
                }
            }
        }
    }
    assert!(
        total_preemptions > 0,
        "fault injection never fired: the grid exercised no preemption"
    );
    assert!(
        total_swaps > 0,
        "fault injection never fired: the grid exercised no swap"
    );
}

/// Invariant 14, engine half: an engine that never saw the preemption
/// API and one configured with `PreemptConfig::OFF` replay the PR 8
/// truncating stack byte for byte — completions, predictions, stats —
/// even when σ = 0.5 overruns exhaust a tight pool.
#[test]
fn preemption_off_replays_truncating_stack_bit_identically() {
    let model = DivergenceModel::QuantileTrace { sigma: 0.5 };
    let gt = overrun_trace(&model, 12);
    let pool = tight_pool(&gt);
    let profile = pooled_profile(pool);
    let predictor = profile.truth;
    let sa = SaParams {
        max_batch: 4,
        seed: 5,
        t0: 100.0,
        iters_per_temp: 10,
        kv: KvConfig::hard(pool as u64),
        ..Default::default()
    };
    let run = |explicit_off: bool| {
        let mut engine = SimEngine::new(profile.clone(), 4, 5)
            .with_divergence(model);
        if explicit_off {
            engine = engine.with_preemption(PreemptConfig::OFF);
        }
        let out = run_online_opts(
            &gt.requests,
            &gt.outs,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            OnlineOpts {
                arrival_aware: true,
                replan_drift_ms: 150.0,
                ..Default::default()
            },
        )
        .unwrap();
        (out, engine.preemption_stats())
    };
    let (base, pb) = run(false);
    let (off, po) = run(true);
    assert_eq!(base.completions.len(), off.completions.len());
    for (x, y) in base.completions.iter().zip(&off.completions) {
        assert_eq!(
            completion_bits(x),
            completion_bits(y),
            "preemption-off diverged from the pre-preemption engine"
        );
    }
    for (x, y) in base.predicted.iter().zip(&off.predicted) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits());
        assert_eq!(x.wait_ms.to_bits(), y.wait_ms.to_bits());
    }
    assert_eq!(base.stats.admitted, off.stats.admitted);
    assert_eq!(base.stats.replans, off.stats.replans);
    assert_eq!(base.stats.drift_replans, off.stats.drift_replans);
    assert_eq!(base.stats.deferrals, off.stats.deferrals);
    assert_eq!(base.stats.dispatched_jobs, off.stats.dispatched_jobs);
    assert_eq!(base.stats.preemptions, 0);
    assert_eq!(off.stats.preemptions, 0);
    assert_eq!(pb, po, "engine-side stats diverged");
    // the truncating legacy path was actually exercised — this is the
    // PR 5 behavior the escape hatch preserves
    assert!(pb.kv_truncations > 0, "tight pool never truncated");
}

/// Invariant 14, σ = 0 corner: with exactly-nominal outputs the
/// preempting decode path is arithmetic- and RNG-identical to the
/// truncating path — enabling preemption changes nothing until a pool
/// actually exhausts.
#[test]
fn preemption_enabled_at_sigma_zero_is_bit_identical() {
    let model = DivergenceModel::QuantileTrace { sigma: 0.0 };
    let gt = overrun_trace(&model, 10);
    let pool = tight_pool(&gt);
    let profile = pooled_profile(pool);
    let predictor = profile.truth;
    let sa = SaParams {
        max_batch: 4,
        seed: 9,
        t0: 100.0,
        iters_per_temp: 8,
        kv: KvConfig::hard(pool as u64),
        ..Default::default()
    };
    let run = |preempt: PreemptConfig| {
        let mut engine = SimEngine::new(profile.clone(), 4, 9)
            .with_divergence(model)
            .with_preemption(preempt);
        run_online_opts(
            &gt.requests,
            &gt.outs,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            OnlineOpts { arrival_aware: true, ..Default::default() },
        )
        .unwrap()
    };
    let on = run(PreemptConfig::recompute());
    let off = run(PreemptConfig::OFF);
    assert_eq!(on.stats.preemptions, 0);
    assert_eq!(on.completions.len(), off.completions.len());
    for (x, y) in on.completions.iter().zip(&off.completions) {
        assert_eq!(completion_bits(x), completion_bits(y));
    }
}

fn fleet_trace(n: usize, seed: u64) -> (Vec<Request>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let requests: Vec<Request> = (0..n)
        .map(|i| {
            t += rng.uniform(0.0, 250.0);
            let mut r = Request::synthetic(
                i as u64,
                if rng.chance(0.5) { TaskType::Chat } else { TaskType::Code },
                1 + rng.below(240),
                1 + rng.below(60),
                Slo::E2e { e2e_ms: rng.uniform(2_000.0, 60_000.0) },
            );
            r.arrival_ms = t;
            r
        })
        .collect();
    let outs = requests.iter().map(|r| r.output_len).collect();
    (requests, outs)
}

fn fleet_engines(
    profile: &HardwareProfile,
    n: usize,
    seed: u64,
    model: DivergenceModel,
    preempt: PreemptConfig,
) -> Vec<Box<dyn Engine + Send>> {
    (0..n)
        .map(|i| {
            Box::new(
                SimEngine::new(profile.clone(), 4, seed ^ ((i as u64) << 8))
                    .with_divergence(model)
                    .with_preemption(preempt),
            ) as Box<dyn Engine + Send>
        })
        .collect()
}

/// Invariant 14, fleet half: the three-phase migrating fleet loop with
/// `migrate: false` replays the plain round-robin fleet loop byte for
/// byte on a multi-instance fleet.
#[test]
fn fleet_migrate_off_is_bit_identical() {
    let profile = {
        let mut p = by_name("qwen7b-v100x2-vllm").unwrap();
        p.noise_std = 0.0;
        p
    };
    let predictor = profile.truth;
    let (requests, outs) = fleet_trace(14, 0xF1EE7);
    let sa = SaParams {
        max_batch: 4,
        seed: 2,
        t0: 100.0,
        iters_per_temp: 10,
        kv: KvConfig::hard(48),
        ..Default::default()
    };
    let opts = OnlineOpts { arrival_aware: true, ..Default::default() };
    let mut base_engines = fleet_engines(
        &profile, 2, 11, DivergenceModel::Off, PreemptConfig::OFF,
    );
    let (base_c, base_o) = run_online_fleet_opts(
        &requests, &outs, &mut base_engines, &predictor, &sa,
        ReplanStrategy::Warm, opts,
    )
    .unwrap();
    let mut mig_engines = fleet_engines(
        &profile, 2, 11, DivergenceModel::Off, PreemptConfig::OFF,
    );
    let (mig_c, mig_o) = run_online_fleet_migrating(
        &requests, &outs, &mut mig_engines, &predictor, &sa,
        ReplanStrategy::Warm, opts,
    )
    .unwrap();
    assert_eq!(base_c.len(), mig_c.len());
    for (x, y) in base_c.iter().zip(&mig_c) {
        assert_eq!(
            completion_bits(x),
            completion_bits(y),
            "migrate:false diverged from the plain fleet loop"
        );
    }
    assert_eq!(base_o.len(), mig_o.len());
    for (x, y) in base_o.iter().zip(&mig_o) {
        assert_eq!(x.stats.admitted, y.stats.admitted);
        assert_eq!(x.stats.replans, y.stats.replans);
        assert_eq!(x.stats.deferrals, y.stats.deferrals);
        assert_eq!(y.stats.migrations, 0);
        for (a, b) in x.completions.iter().zip(&y.completions) {
            assert_eq!(completion_bits(a), completion_bits(b));
        }
    }
}

/// Migration determinism (satellite 3): a fixed seed reproduces the
/// exact victim/target choices — identical migration counts and
/// bit-identical completions across runs — and a single-instance fleet
/// never migrates.
#[test]
fn fleet_migration_is_deterministic_and_single_instance_never_migrates() {
    let model = DivergenceModel::QuantileTrace { sigma: 0.5 };
    let gt = overrun_trace(&model, 12);
    let pool = tight_pool(&gt);
    let profile = pooled_profile(pool);
    let predictor = profile.truth;
    let sa = SaParams {
        max_batch: 4,
        seed: 3,
        t0: 100.0,
        iters_per_temp: 8,
        kv: KvConfig::hard(pool as u64),
        ..Default::default()
    };
    let opts = OnlineOpts {
        arrival_aware: true,
        replan_drift_ms: 150.0,
        migrate: true,
        ..Default::default()
    };
    let run = |n_inst: usize| {
        let mut engines = fleet_engines(
            &profile, n_inst, 21, model, PreemptConfig::recompute(),
        );
        run_online_fleet_migrating(
            &gt.requests, &gt.outs, &mut engines, &predictor, &sa,
            ReplanStrategy::Warm, opts,
        )
        .unwrap()
    };
    let (c1, o1) = run(2);
    let (c2, o2) = run(2);
    // exactly-once across the fleet
    let mut expect: Vec<u64> = gt.requests.iter().map(|r| r.id).collect();
    expect.sort_unstable();
    assert_eq!(sorted_ids(&c1), expect, "duplicate or missing completions");
    // fixed seed ⇒ identical victim/target choices and completions
    let m1: Vec<usize> = o1.iter().map(|o| o.stats.migrations).collect();
    let m2: Vec<usize> = o2.iter().map(|o| o.stats.migrations).collect();
    assert_eq!(m1, m2, "migration choices not deterministic");
    assert_eq!(c1.len(), c2.len());
    for (x, y) in c1.iter().zip(&c2) {
        assert_eq!(completion_bits(x), completion_bits(y));
    }
    // single-instance fleets have no peer to steal work
    let (c_solo, o_solo) = run(1);
    assert_eq!(sorted_ids(&c_solo), expect);
    assert_eq!(o_solo.len(), 1);
    assert_eq!(o_solo[0].stats.migrations, 0, "migrated with no peer");
}

/// Directed two-overrunner scenario, shared by the recompute and swap
/// tests. Pool of exactly 8 blocks; both members are 48-token prompts
/// with nominal 16 but true outputs in [48, 72] (ids searched at
/// runtime), so both allocate 4 blocks (49 tokens), fill the pool, and
/// collide at the first block-boundary crossing: context 64 → 65 needs
/// a 5th block. Member A gets an effectively infinite deadline and B a
/// tight one, pinning victim selection to A (max SLO slack).
struct Scenario {
    profile: HardwareProfile,
    id_a: u64,
    id_b: u64,
    actual_a: usize,
    actual_b: usize,
}

fn two_overrunner_scenario() -> Scenario {
    let model = DivergenceModel::QuantileTrace { sigma: 0.5 };
    let id_a = find_id(&model, 16, 48, 72, &[]);
    let id_b = find_id(&model, 16, 48, 72, &[id_a]);
    Scenario {
        profile: pooled_profile(8),
        id_a,
        id_b,
        actual_a: actual_of(&model, id_a, 16),
        actual_b: actual_of(&model, id_b, 16),
    }
}

fn ereq(id: u64, input: usize, output: usize) -> EngineRequest {
    EngineRequest { id, input_len: input, max_new_tokens: output, prompt: None }
}

fn scenario_engine(s: &Scenario, preempt: PreemptConfig) -> SimEngine {
    SimEngine::new(s.profile.clone(), 2, 0)
        .with_divergence(DivergenceModel::QuantileTrace { sigma: 0.5 })
        .with_preemption(preempt)
}

/// Both members suspend-collide exactly once, at context 64 (the block
/// boundary after the 4-block admission alloc): the victim is A (the
/// slack-maximal member), A yields its step, B runs to its true EOS,
/// then A resumes by recompute at a cost of exactly one 64-token
/// prefill — and the whole dance is bit-deterministic.
#[test]
fn directed_recompute_preempts_slackest_member_exactly_once() {
    let s = two_overrunner_scenario();
    let truth = s.profile.truth;
    let batch = vec![ereq(s.id_a, 48, 16), ereq(s.id_b, 48, 16)];
    let run = || {
        let mut e = scenario_engine(&s, PreemptConfig::recompute());
        assert_eq!(e.kv().config().total_blocks, 8, "pool sizing drifted");
        e.set_deadlines(&[(s.id_a, 1e15), (s.id_b, 1_000.0)]);
        let out = e.run_batch(&batch).unwrap();
        let p = e.preemption_stats();
        assert_eq!(e.kv().active_seqs(), 0, "leaked seqs");
        assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
        (out, p)
    };
    let (out, p) = run();
    assert_eq!(p.preemptions, 1, "expected exactly one suspension");
    assert_eq!(p.recompute_resumes, 1);
    assert_eq!(p.kv_truncations, 0);
    assert_eq!(p.swap_outs, 0);
    // the resume recomputes A's exact suspension context: 64 tokens
    // (the 4-block admission alloc), priced as a batch-1 prefill
    let expect_ms = truth.prefill_ms(1, 64);
    assert!(
        (p.recompute_ms - expect_ms).abs() <= 1e-9 * expect_ms.max(1.0),
        "recompute_ms {} != reference prefill {}",
        p.recompute_ms,
        expect_ms
    );
    // exactly-once, full divergent outputs, and A (the victim) finishes
    // after B (the survivor)
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].id, s.id_a);
    assert_eq!(out[0].generated, s.actual_a);
    assert_eq!(out[1].generated, s.actual_b);
    assert!(
        out[0].finish_ms > out[1].finish_ms,
        "victim should finish after the survivor"
    );
    // bit-determinism of the whole suspend/resume dance
    let (out2, p2) = run();
    assert_eq!(p, p2);
    for (x, y) in out.iter().zip(&out2) {
        assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
        assert_eq!(x.first_token_ms.to_bits(), y.first_token_ms.to_bits());
        assert_eq!(x.generated, y.generated);
    }
}

/// Swap flavor of the directed scenario: the suspension swaps A's
/// 4-block context out and back in, and the accounted cost matches a
/// sequential reference recomputation of the geometry — 8 block moves
/// at the link's per-block cost.
#[test]
fn directed_swap_cost_matches_sequential_reference() {
    let s = two_overrunner_scenario();
    let batch = vec![ereq(s.id_a, 48, 16), ereq(s.id_b, 48, 16)];
    let mut e = scenario_engine(&s, PreemptConfig::swap(8.0, 64));
    e.set_deadlines(&[(s.id_a, 1e15), (s.id_b, 1_000.0)]);
    let per_block = e.swap_ms_per_block();
    assert!(per_block > 0.0);
    let out = e.run_batch(&batch).unwrap();
    let p = e.preemption_stats();
    assert_eq!(p.preemptions, 1);
    assert_eq!(p.swap_outs, 1);
    assert_eq!(p.swap_ins, 1);
    assert_eq!(p.recompute_resumes, 0);
    assert_eq!(p.kv_truncations, 0);
    // sequential reference: A is suspended at context 64 = 4 blocks;
    // one swap-out + one swap-in moves 8 blocks total
    assert_eq!(p.swap_blocks, 8, "suspension context drifted");
    let expect_ms = 8.0 * per_block;
    assert!(
        (p.swap_ms - expect_ms).abs() <= 1e-9 * expect_ms.max(1.0),
        "swap_ms {} != sequential reference {}",
        p.swap_ms,
        expect_ms
    );
    assert_eq!(out[0].generated, s.actual_a);
    assert_eq!(out[1].generated, s.actual_b);
    assert!(out[0].finish_ms > out[1].finish_ms);
    assert_eq!(e.kv().active_seqs(), 0);
    assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
}

/// PR 5 regression (satellite 2): with preemption disabled, exhausting
/// the pool still force-stops the overrunning member — `kv_truncations`
/// increments, the member keeps its partial output, and the allocator
/// rolls back leak-free.
#[test]
fn truncation_path_still_fires_when_preemption_disabled() {
    // σ = 1.0 makes >5× overruns common enough to find by id search: the
    // big member's true context (48 + actual > 128 tokens) exceeds the
    // whole 8-block pool, so no amount of freed blocks can save it.
    let model = DivergenceModel::QuantileTrace { sigma: 1.0 };
    let id_small = find_id(&model, 16, 1, 16, &[]);
    let id_big = find_id(&model, 16, 81, 120, &[id_small]);
    let actual_big = actual_of(&model, id_big, 16);
    let profile = pooled_profile(8);
    let mut e = SimEngine::new(profile, 2, 0).with_divergence(model);
    let out = e
        .run_batch(&[ereq(id_small, 48, 16), ereq(id_big, 48, 16)])
        .unwrap();
    let p = e.preemption_stats();
    assert_eq!(p.kv_truncations, 1, "pool exhaustion must truncate");
    assert_eq!(p.preemptions, 0, "preemption is disabled");
    // the big member is force-stopped exactly when its context fills
    // the pool: 8 blocks × 16 tokens − 48 prompt = 80 generated
    assert_eq!(out[1].id, id_big);
    assert_eq!(out[1].generated, 80);
    assert!(out[1].generated < actual_big);
    // the short member is untouched
    assert_eq!(
        out[0].generated,
        actual_of(&model, id_small, 16)
    );
    // leak-free rollback
    assert_eq!(e.kv().active_seqs(), 0);
    assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
}

/// Failed admissions roll back cleanly: a batch the planner should
/// never have sent (nominal demand beyond the physical pool) errors out
/// without leaking partially-allocated sequences.
#[test]
fn infeasible_batch_rejection_rolls_back_leak_free() {
    let profile = pooled_profile(8);
    let mut e = SimEngine::new(profile, 2, 0)
        .with_divergence(DivergenceModel::QuantileTrace { sigma: 0.5 })
        .with_preemption(PreemptConfig::recompute());
    // nominal footprint 48 + 1000 tokens = 66 blocks >> 8: rejected
    // before any decode work
    assert!(e.run_batch(&[ereq(1, 48, 1000)]).is_err());
    assert_eq!(e.kv().active_seqs(), 0, "rejection leaked a sequence");
    assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
    // and the engine is still serviceable afterwards
    let out = e.run_batch(&[ereq(2, 48, 4)]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(e.kv().active_seqs(), 0);
    assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
}
