//! Paper Fig. 7: overall performance — G, SLO attainment, average latency
//! for the simulated-annealing SLO-aware scheduler (SA), the exhaustive
//! counterpart, and the vLLM FCFS baseline, across request numbers 2–10 and
//! maximum batch sizes 1 / 2 / 4 (Qwen2.5-7B @ 2×V100 profile).
//!
//! Exhaustive rows beyond the paper's feasibility cut (n > 10 at bs 1,
//! n > 6 at bs 2/4) are skipped, mirroring Fig. 7's missing bars.

use slo_serve::bench::{run_scenario, BenchRun};
use slo_serve::config::RunConfig;
use slo_serve::metrics::{fmt, Table};

fn cfg(policy: &str, n: usize, bs: usize, seed: u64) -> RunConfig {
    RunConfig {
        policy: policy.into(),
        n_requests: n,
        max_batch: bs,
        seed,
        // strict-enough SLOs that ordering matters at this scale
        slos: slo_serve::config::SloTargets::default().scaled(0.4),
        ..Default::default()
    }
}

fn avg_runs(policy: &str, n: usize, bs: usize, seeds: &[u64]) -> BenchRun {
    let mut runs: Vec<BenchRun> = seeds
        .iter()
        .map(|&s| run_scenario(&cfg(policy, n, bs, s)).unwrap())
        .collect();
    // aggregate by averaging the scalar metrics (keep last run's summaries)
    let k = runs.len() as f64;
    let mut out = runs.pop().unwrap();
    let mut g = out.metrics.g_req_per_s;
    let mut met = out.metrics.met as f64;
    let mut tot = out.metrics.total_e2e_ms;
    for r in &runs {
        g += r.metrics.g_req_per_s;
        met += r.metrics.met as f64;
        tot += r.metrics.total_e2e_ms;
    }
    out.metrics.g_req_per_s = g / k;
    out.metrics.met = (met / k).round() as usize;
    out.metrics.total_e2e_ms = tot / k;
    out
}

fn main() {
    println!("== Fig. 7: overall performance (SA vs exhaustive vs vLLM-FCFS) ==");
    println!("profile=qwen7b-v100x2-vllm, mixed ShareGPT-chat + Python-code wave, SLO scale 0.4\n");
    let seeds: Vec<u64> = (0..3).collect();
    for &bs in &[1usize, 2, 4] {
        println!("-- Fig. 7({}) max batch size {bs}",
                 ["A", "B", "C"][bs.trailing_zeros() as usize]);
        let mut t = Table::new(&[
            "req#", "policy", "attainment", "avg_latency_ms", "G(req/s)",
            "ΔG vs fcfs",
        ]);
        for &n in &[2usize, 4, 6, 8, 10] {
            let fcfs = avg_runs("fcfs", n, bs, &seeds);
            let base_g = fcfs.metrics.g_req_per_s;
            let mut rows = vec![("vllm-fcfs", fcfs)];
            rows.push(("sa", avg_runs("slo-aware-sa", n, bs, &seeds)));
            let exhaustive_ok = (bs == 1 && n <= 10) || n <= 6;
            if exhaustive_ok {
                rows.push((
                    "exhaustive",
                    avg_runs("slo-aware-exhaustive", n, bs, &seeds),
                ));
            }
            for (name, run) in rows {
                let m = &run.metrics;
                let delta = if base_g > 0.0 {
                    format!("{:+.1}%", (m.g_req_per_s / base_g - 1.0) * 100.0)
                } else {
                    "-".into()
                };
                t.row(vec![
                    n.to_string(),
                    name.into(),
                    format!("{}/{} ({:.0}%)", m.met, m.n, m.attainment() * 100.0),
                    fmt(m.avg_latency_ms()),
                    format!("{:.4}", m.g_req_per_s),
                    if name == "vllm-fcfs" { "-".into() } else { delta },
                ]);
            }
        }
        print!("{}", t.render());
        println!();
    }
    println!("paper shape: SA ≥ FCFS on G in most cells (0.3%–46.5% gains; occasional");
    println!("small regressions from execution-time noise); exhaustive ≈ SA (≤1% apart).");
}
