//! Real engine: TinyLM on the PJRT CPU client (the end-to-end truth path).
//!
//! Wraps [`crate::runtime::ModelRuntime`] in the [`Engine`] interface:
//! prompts → byte tokens → bucketed prefill → per-step decode with the KV
//! cache round-tripping as literals → sampled tokens → bytes. All timing is
//! wall clock. Used by the examples and integration tests to prove the full
//! three-layer stack composes; paper-scale benchmarks use the simulated
//! engine (DESIGN.md §2).

use anyhow::{anyhow, Result};

use crate::engine::kv_cache::{BlockAllocator, KvCacheConfig};
use crate::engine::sampling::Sampler;
use crate::engine::tokenizer::ByteTokenizer;
use crate::engine::{validate_batch, Engine, EngineRequest, ItemResult};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// PJRT-backed engine over AOT artifacts.
pub struct RealEngine {
    rt: ModelRuntime,
    tokenizer: ByteTokenizer,
    sampler: Sampler,
    rng: Rng,
    kv: BlockAllocator,
    epoch_ms: f64,
    /// Prefill + decode batch cap (min over bucket grids).
    max_batch: usize,
    /// Decode iterations executed (diagnostics / perf accounting).
    pub decode_steps: usize,
    /// Total wall ms inside PJRT execute calls (perf accounting).
    pub execute_ms: f64,
}

// SAFETY: the `xla` crate's handles (PjRtClient is an `Rc` over the C
// client; literals/executables are raw pointers) are not `Send` because
// `Rc` clones could be split across threads. RealEngine owns *every* clone
// (client, executables, weight literals) inside one struct and the engine
// is only ever moved wholesale onto a single instance worker thread
// (engine/instance.rs); no handle is shared across threads concurrently.
unsafe impl Send for RealEngine {}

impl RealEngine {
    /// Load artifacts from a directory (`make artifacts` output).
    pub fn load(dir: &str) -> Result<RealEngine> {
        let rt = ModelRuntime::load(dir)?;
        let spec = rt.spec().clone();
        let max_batch = rt
            .manifest
            .max_prefill_batch()
            .min(rt.manifest.decode_buckets.iter().map(|(b, _)| *b).max().unwrap_or(1));
        // KV accounting: f32 K+V per token = 2 · L · H · Dh · 4 bytes.
        let mb_per_token = (2 * spec.n_layers * spec.n_heads * spec.head_dim * 4)
            as f64
            / 1e6;
        let pool_mb =
            mb_per_token * (spec.max_seq * max_batch * 4) as f64; // 4 waves
        let kv = BlockAllocator::new(KvCacheConfig::from_memory(
            pool_mb,
            mb_per_token,
            16,
        ));
        Ok(RealEngine {
            rt,
            tokenizer: ByteTokenizer::new(spec.bos, spec.eos),
            sampler: Sampler::Greedy,
            rng: Rng::new(0xEA1),
            kv,
            epoch_ms: crate::util::now_ms(),
            max_batch,
            decode_steps: 0,
            execute_ms: 0.0,
        })
    }

    pub fn set_sampler(&mut self, sampler: Sampler) {
        self.sampler = sampler;
    }

    pub fn spec(&self) -> &crate::runtime::ModelSpec {
        self.rt.spec()
    }

    pub fn runtime_mut(&mut self) -> &mut ModelRuntime {
        &mut self.rt
    }

    /// Eagerly compile the executables for a batch size (avoids paying
    /// compile time inside the first measured request).
    pub fn warmup(&mut self, batch: usize) -> Result<()> {
        let seqs: Vec<usize> = self
            .rt
            .manifest
            .prefill_buckets
            .iter()
            .filter(|(b, _)| b.batch >= batch)
            .map(|(b, _)| b.seq)
            .collect();
        for s in seqs {
            if let Some(bucket) = self.rt.manifest.pick_prefill(batch, s) {
                self.rt.ensure_prefill(bucket)?;
            }
        }
        if let Some(db) = self.rt.manifest.pick_decode(batch) {
            self.rt.ensure_decode(db)?;
        }
        Ok(())
    }

    fn rows_for(&mut self, batch: &[EngineRequest]) -> Vec<Vec<i32>> {
        batch
            .iter()
            .map(|r| match &r.prompt {
                Some(p) => self.tokenizer.encode(p),
                None => {
                    let synth = self
                        .tokenizer
                        .synthetic_prompt(r.id, r.input_len.max(1));
                    self.tokenizer.encode(&synth)
                }
            })
            .collect()
    }
}

impl Engine for RealEngine {
    fn name(&self) -> String {
        "real:tinylm-pjrt-cpu".into()
    }

    fn now_ms(&self) -> f64 {
        crate::util::now_ms() - self.epoch_ms
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_total_tokens(&self) -> usize {
        // one slot is reserved: the last generated token occupies pos
        // max_seq-1 at most
        self.rt.spec().max_seq - 1
    }

    fn run_batch(&mut self, batch: &[EngineRequest]) -> Result<Vec<ItemResult>> {
        validate_batch(self, batch)?;
        let rows = self.rows_for(batch);
        let b = batch.len();
        for (r, row) in batch.iter().zip(&rows) {
            self.kv.alloc_seq(r.id, row.len() + r.max_new_tokens)?;
        }
        let start_ms = self.now_ms();

        // ---- prefill
        let t0 = crate::util::now_ms();
        let prefill = self.rt.prefill(&rows)?;
        self.execute_ms += crate::util::now_ms() - t0;
        let first_token_ms = self.now_ms();

        // sample the first generated token per row
        let mut tokens_out: Vec<Vec<i32>> = Vec::with_capacity(b);
        for logits in &prefill.last_logits {
            tokens_out.push(vec![self.sampler.sample(logits, &mut self.rng)]);
        }

        // ---- decode loop at the decode bucket size
        let db = self
            .rt
            .manifest
            .pick_decode(b)
            .ok_or_else(|| anyhow!("no decode bucket for batch {b}"))?;
        let mut k = self.rt.pad_cache_batch(
            &prefill.k_caches,
            prefill.bucket.batch,
            db,
        )?;
        let mut v = self.rt.pad_cache_batch(
            &prefill.v_caches,
            prefill.bucket.batch,
            db,
        )?;

        let eos = self.rt.spec().eos;
        let mut done: Vec<bool> = batch
            .iter()
            .enumerate()
            .map(|(i, r)| {
                r.max_new_tokens <= 1 || tokens_out[i][0] == eos
            })
            .collect();
        let mut finish = vec![first_token_ms; b];
        let mut generated: Vec<usize> = vec![1; b];

        while done.iter().any(|d| !d) {
            let mut feed = vec![0i32; db];
            let mut pos = vec![0i32; db];
            for i in 0..b {
                // feed every live row its latest token at its current slot;
                // finished rows re-feed their last token at the same pos
                // (harmless rewrite of an already-final cache slot)
                let cur_len = rows[i].len() + generated[i] - 1;
                feed[i] = *tokens_out[i].last().unwrap();
                pos[i] = cur_len as i32;
            }
            let t0 = crate::util::now_ms();
            let step = self.rt.decode_step(db, &k, &v, &feed, &pos)?;
            self.execute_ms += crate::util::now_ms() - t0;
            self.decode_steps += 1;
            k = step.k_caches;
            v = step.v_caches;
            let now = self.now_ms();
            for i in 0..b {
                if done[i] {
                    continue;
                }
                let tok = self.sampler.sample(&step.logits[i], &mut self.rng);
                tokens_out[i].push(tok);
                generated[i] += 1;
                finish[i] = now;
                if tok == eos || generated[i] >= batch[i].max_new_tokens {
                    done[i] = true;
                }
            }
        }

        let results = batch
            .iter()
            .enumerate()
            .map(|(i, r)| ItemResult {
                id: r.id,
                start_ms,
                first_token_ms,
                finish_ms: finish[i],
                generated: generated[i],
                batch_size: b,
                text: Some(self.tokenizer.decode(&tokens_out[i])),
            })
            .collect();
        for r in batch {
            self.kv.free_seq(r.id)?;
        }
        Ok(results)
    }

    fn advance_to(&mut self, _target_ms: f64) {
        // wall clock advances on its own
    }
}
