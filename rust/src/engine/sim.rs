//! Simulated engine: virtual-clock execution from an analytic latency model.
//!
//! Substitutes for the paper's GPU testbeds (DESIGN.md §2): per-batch
//! latencies follow the paper's own cost structure (Eqs. 14–16) with the
//! profile's ground-truth coefficients plus seeded multiplicative noise.
//! The scheduler never sees these coefficients — it must fit its predictor
//! from profiling runs, exactly as on real hardware.
//!
//! Two execution modes:
//!
//! * **planned** ([`Engine::run_batch`]) — the SLO-aware path: batches
//!   arrive pre-formed and run to completion.
//! * **continuous** ([`SimEngine::run_continuous`]) — the vLLM-FCFS
//!   baseline: arrival-ordered admission into a continuously-batched decode
//!   loop, bounded by `max_batch` and KV-cache capacity; new requests
//!   prefill into freed slots (hybrid batches à la chunked-prefill).

use std::collections::HashMap;

use anyhow::Result;

use crate::config::profiles::HardwareProfile;
use crate::coordinator::kv::{phased_peak_blocks, KvPhaseModel};
use crate::coordinator::policies::slack_key;
use crate::engine::kv_cache::{BlockAllocator, KvCacheConfig};
use crate::engine::{
    validate_batch, Engine, EngineRequest, ItemResult, PreemptionStats,
    StepEvent,
};
use crate::util::rng::Rng;
use crate::util::stats::normal_quantile;

/// How each request's **true** decode length diverges from the nominal
/// (predicted) length the engine is handed in
/// [`EngineRequest::max_new_tokens`].
///
/// The scheduler plans on predicted output lengths; a real serving stack
/// then watches requests hit EOS earlier or later than predicted. With a
/// divergence model on, the engine re-interprets `max_new_tokens` as the
/// *prediction* and samples the true decode length around it — finishing
/// each member at its true EOS step, releasing its KV then (short
/// outputs free memory early, overruns hold it and keep growing). The
/// sampled lengths come from a dedicated divergence RNG stream, so the
/// timing-noise stream — and therefore every [`DivergenceModel::Off`]
/// run — is byte-identical to the pre-divergence engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DivergenceModel {
    /// No divergence: true length == nominal length, execution takes the
    /// legacy code path bit for bit (the escape hatch pinned by
    /// `tests/divergence_robustness.rs`).
    Off,
    /// `actual = round(nominal · exp(σ·z))`, `z ~ N(0,1)` drawn per
    /// request (in batch/admission order) from the divergence stream.
    Lognormal { sigma: f64 },
    /// Same lognormal family, but the multiplier is a pure function of
    /// the request **id** — a reproducible divergence *trace* that stays
    /// identical across policies, schedulers, engines, and execution
    /// orders (the apples-to-apples setting for baseline comparisons).
    QuantileTrace { sigma: f64 },
}

impl DivergenceModel {
    #[inline]
    pub fn is_off(&self) -> bool {
        matches!(self, DivergenceModel::Off)
    }

    /// The model's lognormal σ (0 for [`DivergenceModel::Off`]).
    pub fn sigma(&self) -> f64 {
        match *self {
            DivergenceModel::Off => 0.0,
            DivergenceModel::Lognormal { sigma }
            | DivergenceModel::QuantileTrace { sigma } => sigma,
        }
    }

    /// The CLI/JSON spec string this model parses back from
    /// ([`DivergenceModel::parse`] roundtrip).
    pub fn spec(&self) -> String {
        match *self {
            DivergenceModel::Off => "off".into(),
            DivergenceModel::Lognormal { sigma } => format!("lognormal:{sigma}"),
            DivergenceModel::QuantileTrace { sigma } => {
                format!("quantile-trace:{sigma}")
            }
        }
    }

    /// Parse a CLI spec: `off | lognormal:<σ> | quantile-trace:<σ>`.
    pub fn parse(spec: &str) -> Result<DivergenceModel, String> {
        fn sigma_of(s: &str, spec: &str) -> Result<f64, String> {
            let sigma: f64 = s
                .parse()
                .map_err(|_| format!("bad σ in divergence spec '{spec}'"))?;
            if !sigma.is_finite() || sigma < 0.0 {
                return Err(format!(
                    "divergence σ must be finite and ≥ 0, got {sigma}"
                ));
            }
            Ok(sigma)
        }
        if spec == "off" {
            Ok(DivergenceModel::Off)
        } else if let Some(s) = spec.strip_prefix("lognormal:") {
            Ok(DivergenceModel::Lognormal { sigma: sigma_of(s, spec)? })
        } else if let Some(s) = spec.strip_prefix("quantile-trace:") {
            Ok(DivergenceModel::QuantileTrace { sigma: sigma_of(s, spec)? })
        } else {
            Err(format!(
                "bad divergence spec '{spec}' \
                 (off | lognormal:<σ> | quantile-trace:<σ>)"
            ))
        }
    }

    /// Sample the true decode length for a request predicted at `nominal`
    /// tokens. Draw discipline: [`DivergenceModel::Lognormal`] consumes
    /// exactly one normal variate per call (even for `nominal == 0`, so
    /// the stream position is independent of request content);
    /// [`DivergenceModel::QuantileTrace`] consumes nothing — its
    /// multiplier is derived from the request id alone.
    pub fn actual_lo(&self, id: u64, nominal: usize, rng: &mut Rng) -> usize {
        match *self {
            DivergenceModel::Off => nominal,
            DivergenceModel::Lognormal { sigma } => {
                let mult = (sigma * rng.normal()).exp();
                scale_lo(nominal, mult)
            }
            DivergenceModel::QuantileTrace { sigma } => {
                let u = Rng::new(id ^ 0xD1_5C0D_E5)
                    .f64()
                    .clamp(1e-9, 1.0 - 1e-9);
                scale_lo(nominal, (sigma * normal_quantile(u)).exp())
            }
        }
    }
}

/// Scale a nominal output length by a divergence multiplier: rounded,
/// never below one token (prefill always emits one) — except that a
/// zero-token nominal stays zero, mirroring the engine's legacy
/// zero-budget handling.
#[inline]
fn scale_lo(nominal: usize, mult: f64) -> usize {
    if nominal == 0 {
        return 0;
    }
    ((nominal as f64 * mult).round() as usize).max(1)
}

/// What happens to a victim's KV when pool exhaustion forces a
/// mid-decode suspension (see [`PreemptConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PreemptMode {
    /// No preemption: an overrunning member is force-stopped at its
    /// current length (the legacy EOS-on-OOM truncation, PR 5) — the
    /// escape hatch replaying the pre-preemption engine byte for byte.
    #[default]
    Off,
    /// Drop the victim's KV; resuming re-prefills the whole context
    /// (one noiseless `prefill_ms(1, context)` charge on the clock).
    Recompute,
    /// Move the victim's KV to a modeled host buffer over a PCIe-class
    /// link; resuming copies it back. Each direction charges
    /// `blocks × block_mb / swap_gbps` ms. When the host buffer is
    /// full the suspension degrades to [`PreemptMode::Recompute`].
    Swap,
}

/// Preemption policy for [`SimEngine`]: replaces EOS-on-OOM truncation
/// with suspend/resume of the SLO-slackest member. Victims are chosen by
/// descending [`slack_key`] (the `SlackIndex` ordering from
/// `policies.rs`): the member with the most deadline slack — or no known
/// deadline at all — yields first. Resume order is the reverse: the most
/// urgent suspended member re-enters first, as soon as its context (plus
/// one block of growth headroom) fits the pool again. All preemption
/// costs are noiseless functions of the profile, so the timing RNG
/// stream — and therefore every [`PreemptMode::Off`] run — is untouched.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptConfig {
    pub mode: PreemptMode,
    /// Host swap-buffer capacity in KV blocks ([`PreemptMode::Swap`]).
    pub host_blocks: u64,
    /// Modeled host↔device link bandwidth in GB/s
    /// ([`PreemptMode::Swap`]; 1 GB/s = 1 MB/ms).
    pub swap_gbps: f64,
}

impl PreemptConfig {
    /// Preemption disabled — the legacy truncation engine, bit for bit.
    pub const OFF: PreemptConfig =
        PreemptConfig { mode: PreemptMode::Off, host_blocks: 0, swap_gbps: 0.0 };

    /// Recompute-on-resume preemption (no host buffer).
    pub fn recompute() -> PreemptConfig {
        PreemptConfig { mode: PreemptMode::Recompute, ..PreemptConfig::OFF }
    }

    /// Swap preemption over a `gbps` link into a `host_blocks`-block
    /// host buffer.
    pub fn swap(gbps: f64, host_blocks: u64) -> PreemptConfig {
        PreemptConfig { mode: PreemptMode::Swap, host_blocks, swap_gbps: gbps }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        !matches!(self.mode, PreemptMode::Off)
    }

    /// Parse a CLI spec: `off | recompute | swap`.
    pub fn parse(
        spec: &str,
        swap_gbps: f64,
        host_blocks: u64,
    ) -> Result<PreemptConfig, String> {
        match spec {
            "off" => Ok(PreemptConfig::OFF),
            "recompute" => Ok(PreemptConfig::recompute()),
            "swap" => {
                if !swap_gbps.is_finite() || swap_gbps <= 0.0 {
                    return Err(format!(
                        "swap preemption needs a positive link bandwidth, \
                         got {swap_gbps} GB/s"
                    ));
                }
                Ok(PreemptConfig::swap(swap_gbps, host_blocks))
            }
            other => {
                Err(format!("bad preempt spec '{other}' (off|recompute|swap)"))
            }
        }
    }
}

/// Virtual-clock engine over a hardware profile.
pub struct SimEngine {
    profile: HardwareProfile,
    max_batch: usize,
    clock_ms: f64,
    rng: Rng,
    /// Noise seed this engine was (re)initialized with — recorded so a
    /// run's timing can be reproduced exactly (online/bench provenance).
    seed: u64,
    kv: BlockAllocator,
    /// Planned-batch KV accounting mode: `Reserve` (default) allocates
    /// every member's full footprint up front — the legacy behaviour bit
    /// for bit; `Phased` allocates prompt KV at prefill, grows one block
    /// boundary at a time during decode, and frees each member the step
    /// it completes, admitting any batch whose *occupancy peak* fits.
    kv_phase: KvPhaseModel,
    /// Actual-vs-predicted output-length divergence (see
    /// [`DivergenceModel`]); `Off` replays the legacy engine byte for
    /// byte — same RNG stream, same KV behaviour, same completions.
    divergence: DivergenceModel,
    /// Dedicated RNG stream for divergence sampling, separate from the
    /// timing-noise stream so enabling divergence never perturbs timing.
    div_rng: Rng,
    /// Members whose decode was force-stopped by KV-pool exhaustion under
    /// divergence (EOS-on-OOM; diagnostics — see
    /// [`SimEngine::kv_truncations`]).
    kv_truncations: usize,
    /// Preemption policy for planned-batch pool exhaustion (see
    /// [`PreemptConfig`]); `Off` keeps the truncation path byte for byte.
    preempt: PreemptConfig,
    /// Absolute SLO deadlines (engine-clock ms) by request id, handed in
    /// by the controller via [`Engine::set_deadlines`]; consulted only
    /// for slack-ordered victim/resume selection (lookup by id, never
    /// iterated — determinism does not depend on map order).
    deadlines: HashMap<u64, f64>,
    /// Suspend/resume/swap counters (see [`PreemptionStats`];
    /// `kv_truncations` is merged in by [`Engine::preemption_stats`]).
    pstats: PreemptionStats,
    /// Host swap-buffer occupancy in blocks (Swap mode).
    host_blocks_used: u64,
    /// High-water mark of [`SimEngine::host_blocks_used`].
    host_blocks_peak: u64,
    /// Batches executed (diagnostics).
    pub batches_run: usize,
    /// Decode iterations executed (diagnostics).
    pub decode_steps: usize,
    /// High-water mark of KV-block occupancy (diagnostics: a KV-aware
    /// scheduler must keep this at or below the pool by construction).
    peak_used_blocks: usize,
    /// Chunked-prefill chunk size in tokens. `0` (the default) runs the
    /// legacy whole-prompt prefill byte for byte — same RNG stream, same
    /// KV behaviour, same completions (invariant 15). Positive: each
    /// prompt is split into `chunk_tokens`-sized chunks executed
    /// sequentially in batch order as batch-of-1 prefill calls, one
    /// noise draw per chunk, with the member's first token emitted at
    /// its *final* chunk completion and phased/divergent KV allocated
    /// progressively per chunk.
    chunk_tokens: usize,
    /// Per-decode-step token tracing ([`Engine::enable_step_trace`]).
    /// Off by default: recording consumes no RNG and touches no timing,
    /// so the disabled engine is the pre-trace engine bit for bit.
    record_steps: bool,
    /// Step events recorded since the last [`Engine::take_step_events`]
    /// (planned-batch paths only; `run_continuous` does not trace).
    step_events: Vec<StepEvent>,
}

impl SimEngine {
    pub fn new(profile: HardwareProfile, max_batch: usize, seed: u64) -> Self {
        let kv_cfg = KvCacheConfig::from_memory(
            profile.kv_pool_mb,
            profile.mem.mb_per_token,
            16,
        );
        SimEngine {
            profile,
            max_batch,
            clock_ms: 0.0,
            rng: Rng::new(seed ^ 0x51_E2_61_4E),
            seed,
            kv: BlockAllocator::new(kv_cfg),
            kv_phase: KvPhaseModel::Reserve,
            divergence: DivergenceModel::Off,
            div_rng: Rng::new(seed ^ 0xD117_E26E),
            kv_truncations: 0,
            preempt: PreemptConfig::OFF,
            deadlines: HashMap::new(),
            pstats: PreemptionStats::default(),
            host_blocks_used: 0,
            host_blocks_peak: 0,
            batches_run: 0,
            decode_steps: 0,
            peak_used_blocks: 0,
            chunk_tokens: 0,
            record_steps: false,
            step_events: Vec::new(),
        }
    }

    /// This engine with chunked prefill at `chunk_tokens` tokens per
    /// chunk (see the `chunk_tokens` field docs). `0` (the default) is
    /// the whole-prompt engine bit for bit — invariant 15's escape hatch.
    pub fn with_chunk_tokens(mut self, chunk_tokens: usize) -> Self {
        self.chunk_tokens = chunk_tokens;
        self
    }

    /// The configured chunked-prefill chunk size (0 = off).
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// This engine with per-decode-step token tracing enabled from the
    /// start (builder form of [`Engine::enable_step_trace`]).
    pub fn with_step_trace(mut self) -> Self {
        self.record_steps = true;
        self
    }

    /// This engine with an output-length divergence model (see
    /// [`DivergenceModel`]). [`DivergenceModel::Off`] (the default) is a
    /// no-op — the constructor's engine, bit for bit.
    pub fn with_divergence(mut self, divergence: DivergenceModel) -> Self {
        self.divergence = divergence;
        self
    }

    /// The configured output-length divergence model.
    pub fn divergence(&self) -> DivergenceModel {
        self.divergence
    }

    /// Members force-stopped at EOS by KV-pool exhaustion under
    /// divergence (always 0 with divergence off: planned batches are
    /// pre-checked and static; with preemption on, truncation remains
    /// only as the physical-limit fallback for a context no pool state
    /// can ever host).
    pub fn kv_truncations(&self) -> usize {
        self.kv_truncations
    }

    /// This engine with a preemption policy for planned-batch pool
    /// exhaustion (see [`PreemptConfig`]). [`PreemptConfig::OFF`] (the
    /// default) keeps the EOS-on-OOM truncation path bit for bit.
    pub fn with_preemption(mut self, preempt: PreemptConfig) -> Self {
        self.preempt = preempt;
        self
    }

    /// The configured preemption policy.
    pub fn preempt(&self) -> PreemptConfig {
        self.preempt
    }

    /// Host swap-buffer occupancy high-water mark (blocks, Swap mode).
    pub fn host_blocks_peak(&self) -> u64 {
        self.host_blocks_peak
    }

    /// Swap transfer time per KV block (ms): `block_mb / swap_gbps`
    /// (1 GB/s moves 1 MB per ms). 0 outside Swap mode.
    pub fn swap_ms_per_block(&self) -> f64 {
        if !matches!(self.preempt.mode, PreemptMode::Swap)
            || self.preempt.swap_gbps <= 0.0
        {
            return 0.0;
        }
        let block_mb = self.kv.config().block_tokens as f64
            * self.profile.mem.mb_per_token;
        block_mb / self.preempt.swap_gbps
    }

    /// This engine with phase-aware planned-batch KV accounting (see the
    /// `kv_phase` field docs). Timing is unaffected — only admission and
    /// the occupancy profile change.
    pub fn with_kv_phase(mut self, phase: KvPhaseModel) -> Self {
        self.kv_phase = phase;
        self
    }

    /// The planned-batch KV accounting mode.
    pub fn kv_phase(&self) -> KvPhaseModel {
        self.kv_phase
    }

    pub fn profile(&self) -> &HardwareProfile {
        &self.profile
    }

    /// The noise seed of the current run (set by `new`/`reset`).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn kv(&self) -> &BlockAllocator {
        &self.kv
    }

    /// High-water mark of KV-block occupancy across the run.
    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used_blocks
    }

    /// Multiplicative execution noise ~ N(1, σ), clamped positive.
    fn noise(&mut self) -> f64 {
        self.rng.gaussian(1.0, self.profile.noise_std).max(0.05)
    }

    /// Reset clock + KV state (between experiment repetitions).
    pub fn reset(&mut self, seed: u64) {
        self.clock_ms = 0.0;
        self.rng = Rng::new(seed ^ 0x51_E2_61_4E);
        self.div_rng = Rng::new(seed ^ 0xD117_E26E);
        self.seed = seed;
        self.kv.reset();
        self.batches_run = 0;
        self.decode_steps = 0;
        self.peak_used_blocks = 0;
        self.kv_truncations = 0;
        self.deadlines.clear();
        self.pstats = PreemptionStats::default();
        self.host_blocks_used = 0;
        self.host_blocks_peak = 0;
        self.step_events.clear();
    }

    /// Continuous-batching FCFS execution (the vLLM baseline).
    ///
    /// `arrivals` must be sorted by arrival time (ms). Admission: requests
    /// join in arrival order whenever a slot (max_batch) and KV memory are
    /// available; each admission wave prefills as one sub-batch, then the
    /// whole active set decodes one token per iteration.
    pub fn run_continuous(
        &mut self,
        arrivals: &[(f64, EngineRequest)],
    ) -> Result<Vec<ItemResult>> {
        // True decode lengths under the divergence model, sampled once per
        // request in input order (a single draw each, independent of the
        // admission dynamics below). With divergence off this is the
        // nominal budget verbatim and no RNG is consumed.
        let actuals: Vec<usize> = arrivals
            .iter()
            .map(|(_, r)| {
                self.divergence
                    .actual_lo(r.id, r.max_new_tokens, &mut self.div_rng)
                    .min(
                        self.profile
                            .max_total_tokens
                            .saturating_sub(r.input_len),
                    )
            })
            .collect();
        let mut pending: std::collections::VecDeque<usize> =
            (0..arrivals.len()).collect();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<ItemResult> = Vec::new();

        while !pending.is_empty() || !active.is_empty() {
            // jump to the next arrival if idle
            if active.is_empty() {
                if let Some(&idx) = pending.front() {
                    let t = arrivals[idx].0;
                    if t > self.clock_ms {
                        self.clock_ms = t;
                    }
                }
            }
            // admit: arrival time passed + slot free + KV fits. Admission
            // always prices the NOMINAL budget — under divergence the
            // true length is unknown until EOS, so the baseline gets no
            // oracle knowledge; overruns extend (or truncate) below,
            // exactly like the planned-batch path.
            let mut admitted: Vec<usize> = Vec::new();
            while let Some(&idx) = pending.front() {
                let (t, req) = &arrivals[idx];
                if *t > self.clock_ms
                    || active.len() + admitted.len() >= self.max_batch
                {
                    break;
                }
                let total = req.input_len + req.max_new_tokens;
                if !self.kv.fits(total) {
                    break; // head-of-line blocks on memory (FCFS)
                }
                self.kv.alloc_seq(req.id, total)?;
                self.peak_used_blocks =
                    self.peak_used_blocks.max(self.kv.used_blocks());
                admitted.push(idx);
                pending.pop_front();
            }
            if !admitted.is_empty() {
                // prefill the admission wave as one sub-batch
                let b = admitted.len();
                let max_in = admitted
                    .iter()
                    .map(|&i| arrivals[i].1.input_len)
                    .max()
                    .unwrap_or(1);
                let start = self.clock_ms;
                let t_prefill = self.profile.truth.prefill_ms(b, max_in)
                    * self.noise();
                self.clock_ms += t_prefill;
                self.batches_run += 1;
                for &idx in &admitted {
                    let req = &arrivals[idx].1;
                    active.push(Active {
                        id: req.id,
                        // prefill emits the first token; the true length
                        // (== nominal when divergence is off) drives EOS
                        remaining: actuals[idx].max(1) - 1,
                        accumulated: req.input_len + 1,
                        // tokens the admission reservation covers; decode
                        // growth beyond it must extend the allocation
                        alloc_tokens: req.input_len + req.max_new_tokens,
                        start_ms: start,
                        first_token_ms: self.clock_ms,
                        generated: 1,
                        batch_at_prefill: b,
                    });
                }
                // first token may already complete a 1-token request
                let batch_now = active.len();
                Self::retire(
                    &mut active,
                    &mut done,
                    &mut self.kv,
                    self.clock_ms,
                    batch_now,
                );
                continue;
            }
            if active.is_empty() {
                continue; // waiting for arrivals
            }
            // one decode iteration over the active set
            let b = active.len();
            let max_acc =
                active.iter().map(|a| a.accumulated).max().unwrap_or(1);
            let step = self.profile.truth.tpot_at(b, max_acc) * self.noise();
            self.clock_ms += step;
            self.decode_steps += 1;
            let diverging = !self.divergence.is_off();
            for a in active.iter_mut() {
                if diverging && a.accumulated + 1 > a.alloc_tokens {
                    // overrun past the nominal reservation: grow the
                    // allocation, or force EOS leak-free if the pool is
                    // exhausted (the member retires this iteration)
                    if self.kv.extend_seq(a.id, 1).is_err() {
                        a.remaining = 0;
                        self.kv_truncations += 1;
                        continue;
                    }
                    a.alloc_tokens += 1;
                }
                a.accumulated += 1;
                a.generated += 1;
                a.remaining = a.remaining.saturating_sub(1);
            }
            if diverging {
                self.peak_used_blocks =
                    self.peak_used_blocks.max(self.kv.used_blocks());
            }
            Self::retire(&mut active, &mut done, &mut self.kv, self.clock_ms, b);
        }
        done.sort_by_key(|r| r.id);
        Ok(done)
    }

    /// Planned-batch KV demand (blocks) under the configured phase model,
    /// over the **nominal** budgets — the quantity the scheduler's
    /// feasibility proof speaks about, shared by both execution paths.
    fn planned_demand_blocks(&self, batch: &[EngineRequest]) -> usize {
        if matches!(self.kv_phase, KvPhaseModel::Phased) {
            let members: Vec<(usize, usize)> = batch
                .iter()
                .map(|r| (r.input_len, r.max_new_tokens))
                .collect();
            phased_peak_blocks(&members, self.kv.config().block_tokens) as usize
        } else {
            batch
                .iter()
                .map(|r| self.kv.blocks_needed(r.input_len + r.max_new_tokens))
                .sum()
        }
    }

    /// Chunked prefill phase shared by every planned-batch path when
    /// `chunk_tokens > 0`: members prefill **sequentially in batch
    /// order**, each prompt split into `chunk_tokens`-sized chunks
    /// charged as batch-of-1 prefill calls with one noise draw per chunk
    /// (`prefill_ms(1, chunk_len) · noise`). A member's first token is
    /// emitted at its *final* chunk completion, so short-prompt members
    /// no longer wait on the batch's longest prompt — the TTFT win the
    /// sliding-window scheduler prices. Returns per-member first-token
    /// times (engine clock, batch order).
    ///
    /// KV handling: with `kv_first_tok = Some(ft)` the allocation is
    /// progressive — `alloc_seq` on the member's first chunk,
    /// `extend_seq` per subsequent chunk, plus `ft[i]` extra tokens on
    /// the final chunk (the prefill-emitted first token) — ending in
    /// exactly the post-prefill state the upfront loops produce. `None`
    /// performs no KV ops (reserve mode pinned full footprints before
    /// the call). Chunk completions are tagged in the step trace via
    /// [`StepEvent::chunked`]; the final chunk also carries the
    /// member's id in `emitted` (its first token).
    fn chunked_prefill_phase(
        &mut self,
        batch: &[EngineRequest],
        kv_first_tok: Option<&[usize]>,
    ) -> Result<Vec<f64>> {
        let chunk = self.chunk_tokens;
        debug_assert!(chunk > 0);
        let mut first_token = Vec::with_capacity(batch.len());
        for (i, r) in batch.iter().enumerate() {
            let mut done = 0usize;
            while done < r.input_len {
                let len = chunk.min(r.input_len - done);
                let is_first = done == 0;
                done += len;
                let is_last = done == r.input_len;
                if let Some(ft) = kv_first_tok {
                    let tokens = len + if is_last { ft[i] } else { 0 };
                    if is_first {
                        if let Err(e) = self.kv.alloc_seq(r.id, tokens) {
                            // e.g. duplicate ids within one batch: release
                            // the finished members so the refusal leaks
                            // nothing (this member holds no blocks yet).
                            for prev in &batch[..i] {
                                let _ = self.kv.free_seq(prev.id);
                            }
                            return Err(e.into());
                        }
                    } else {
                        // pre-checked demand: a failure here means the
                        // scheduler planned an infeasible batch.
                        self.kv.extend_seq(r.id, tokens)?;
                    }
                    self.peak_used_blocks =
                        self.peak_used_blocks.max(self.kv.used_blocks());
                }
                let t = self.profile.truth.prefill_ms(1, len) * self.noise();
                self.clock_ms += t;
                if self.record_steps {
                    self.step_events.push(StepEvent {
                        t_ms: self.clock_ms,
                        emitted: if is_last { vec![r.id] } else { Vec::new() },
                        chunked: vec![r.id],
                        ..StepEvent::default()
                    });
                }
            }
            first_token.push(self.clock_ms);
        }
        self.batches_run += 1;
        Ok(first_token)
    }

    /// Planned-batch execution under an active [`DivergenceModel`]: each
    /// member's true decode length is sampled around its nominal budget,
    /// and the member finishes (and frees its KV) at its true EOS step.
    ///
    /// KV discipline: admission is pre-checked against the *nominal*
    /// demand under the configured phase model — the scheduler's
    /// feasibility contract — then execution tracks occupancy exactly
    /// (prompt + first token at prefill, one-token growth per decode
    /// step, release at EOS), because divergence invalidates both static
    /// reservation models. A member whose growth hits an exhausted pool
    /// is force-stopped at its current length (EOS-on-OOM, counted in
    /// [`SimEngine::kv_truncations`]) rather than overcommitting,
    /// erroring, or leaking.
    fn run_batch_divergent(
        &mut self,
        batch: &[EngineRequest],
    ) -> Result<Vec<ItemResult>> {
        let b = batch.len();
        // One divergence draw per member, batch order (see
        // `DivergenceModel::actual_lo` for the draw discipline).
        let actual: Vec<usize> = batch
            .iter()
            .map(|r| {
                self.divergence
                    .actual_lo(r.id, r.max_new_tokens, &mut self.div_rng)
                    .min(
                        self.profile
                            .max_total_tokens
                            .saturating_sub(r.input_len),
                    )
            })
            .collect();
        let need_blocks = self.planned_demand_blocks(batch);
        if need_blocks > self.kv.free_blocks() {
            anyhow::bail!(
                "planned batch of {b} requests overcommits the KV pool: \
                 needs {need_blocks} blocks ({:?} demand), {} free of {} \
                 total — the scheduler planned an infeasible batch",
                self.kv_phase,
                self.kv.free_blocks(),
                self.kv.config().total_blocks,
            );
        }
        let start = self.clock_ms;
        let first_token: Vec<f64> = if self.chunk_tokens > 0 {
            // progressive per-chunk allocation ends in the same
            // post-prefill state as the upfront loop below: prompt + the
            // prefill token per member.
            let ft: Vec<usize> = actual.iter().map(|&a| a.min(1)).collect();
            self.chunked_prefill_phase(batch, Some(&ft))?
        } else {
            for (i, r) in batch.iter().enumerate() {
                // prompt + the prefill token (zero-output members pin only
                // their prompt, mirroring the phased path's clamp)
                let tokens = r.input_len + actual[i].min(1);
                if let Err(e) = self.kv.alloc_seq(r.id, tokens) {
                    for done in &batch[..i] {
                        let _ = self.kv.free_seq(done.id);
                    }
                    return Err(e.into());
                }
            }
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.kv.used_blocks());
            let max_in = batch.iter().map(|r| r.input_len).max().unwrap();
            let t_prefill =
                self.profile.truth.prefill_ms(b, max_in) * self.noise();
            self.clock_ms += t_prefill;
            self.batches_run += 1;
            let first_token_ms = self.clock_ms;
            if self.record_steps {
                self.step_events.push(StepEvent {
                    t_ms: first_token_ms,
                    emitted: batch.iter().map(|r| r.id).collect(),
                    ..StepEvent::default()
                });
            }
            vec![first_token_ms; b]
        };

        let mut remaining: Vec<usize> =
            actual.iter().map(|&a| a.max(1) - 1).collect();
        let mut accumulated: Vec<usize> =
            batch.iter().map(|r| r.input_len + 1).collect();
        let mut generated = vec![1usize; b];
        let mut finish = first_token.clone();
        let mut truncated = vec![false; b];
        let mut live = remaining.iter().filter(|&&r| r > 0).count();
        // members whose single token came out of prefill free immediately
        for (i, r) in batch.iter().enumerate() {
            if remaining[i] == 0 {
                self.kv.free_seq(r.id)?;
            }
        }
        while live > 0 {
            let max_acc = accumulated
                .iter()
                .zip(&remaining)
                .filter(|(_, rem)| **rem > 0)
                .map(|(a, _)| *a)
                .max()
                .unwrap_or(0);
            let step = self.profile.truth.tpot_at(b, max_acc) * self.noise();
            self.clock_ms += step;
            self.decode_steps += 1;
            // grow every live member by the token it is about to emit,
            // recording the true within-step peak before any release
            for (i, r) in batch.iter().enumerate() {
                if remaining[i] > 0 && self.kv.extend_seq(r.id, 1).is_err() {
                    truncated[i] = true;
                }
            }
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.kv.used_blocks());
            let mut emitted: Vec<u64> = Vec::new();
            for i in 0..b {
                if remaining[i] == 0 {
                    continue;
                }
                if truncated[i] {
                    // EOS-on-OOM: stop at the current length, free now
                    truncated[i] = false;
                    remaining[i] = 0;
                    live -= 1;
                    self.kv_truncations += 1;
                    self.kv.free_seq(batch[i].id)?;
                    continue;
                }
                remaining[i] -= 1;
                accumulated[i] += 1;
                generated[i] += 1;
                finish[i] = self.clock_ms;
                if self.record_steps {
                    emitted.push(batch[i].id);
                }
                if remaining[i] == 0 {
                    live -= 1;
                    self.kv.free_seq(batch[i].id)?;
                }
            }
            if self.record_steps && !emitted.is_empty() {
                self.step_events.push(StepEvent {
                    t_ms: self.clock_ms,
                    emitted,
                    ..StepEvent::default()
                });
            }
        }
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, r)| ItemResult {
                id: r.id,
                start_ms: start,
                first_token_ms: first_token[i],
                finish_ms: finish[i],
                generated: generated[i],
                batch_size: b,
                text: None,
            })
            .collect())
    }

    /// Planned-batch execution under divergence **with preemption** — the
    /// resumable-member variant of [`SimEngine::run_batch_divergent`].
    ///
    /// The prefill phase and the happy decode path are arithmetic- and
    /// RNG-identical to the truncating body, so a run in which the pool
    /// never exhausts is bit-identical across the two paths (σ = 0 can
    /// therefore never observe preemption). On an `extend_seq` failure
    /// the engine suspends the *active member with the most SLO slack*
    /// (descending [`slack_key`] — the `SlackIndex` ordering; unknown
    /// deadlines sort as +∞ slack and yield first) instead of
    /// force-stopping anyone:
    ///
    /// * [`PreemptMode::Recompute`] drops the victim's KV; resuming
    ///   charges a noiseless `prefill_ms(1, context)` on the clock.
    /// * [`PreemptMode::Swap`] moves the victim's blocks to the modeled
    ///   host buffer (capacity permitting — otherwise the suspension
    ///   degrades to recompute) and charges
    ///   `blocks × block_mb / swap_gbps` ms in each direction.
    ///
    /// Suspended members resume most-urgent-first (ascending slack) as
    /// soon as their context plus one block of growth headroom fits the
    /// pool; the headroom requirement is waived when nothing is active,
    /// so the batch cannot deadlock on an empty pool. All preemption
    /// costs are deterministic functions of the profile — no RNG draw —
    /// so the timing stream stays aligned with the truncating path.
    /// Truncation survives only as the physical-limit fallback: a lone
    /// context that cannot fit even an otherwise-empty pool is stopped
    /// at its current length, exactly like the legacy path. Suspend and
    /// resume ids are attached to the step trace
    /// ([`StepEvent::suspended`] / [`StepEvent::resumed`]).
    fn run_batch_divergent_preempt(
        &mut self,
        batch: &[EngineRequest],
    ) -> Result<Vec<ItemResult>> {
        let b = batch.len();
        let actual: Vec<usize> = batch
            .iter()
            .map(|r| {
                self.divergence
                    .actual_lo(r.id, r.max_new_tokens, &mut self.div_rng)
                    .min(
                        self.profile
                            .max_total_tokens
                            .saturating_sub(r.input_len),
                    )
            })
            .collect();
        let need_blocks = self.planned_demand_blocks(batch);
        if need_blocks > self.kv.free_blocks() {
            anyhow::bail!(
                "planned batch of {b} requests overcommits the KV pool: \
                 needs {need_blocks} blocks ({:?} demand), {} free of {} \
                 total — the scheduler planned an infeasible batch",
                self.kv_phase,
                self.kv.free_blocks(),
                self.kv.config().total_blocks,
            );
        }
        let start = self.clock_ms;
        let first_token: Vec<f64> = if self.chunk_tokens > 0 {
            let ft: Vec<usize> = actual.iter().map(|&a| a.min(1)).collect();
            self.chunked_prefill_phase(batch, Some(&ft))?
        } else {
            for (i, r) in batch.iter().enumerate() {
                let tokens = r.input_len + actual[i].min(1);
                if let Err(e) = self.kv.alloc_seq(r.id, tokens) {
                    for done in &batch[..i] {
                        let _ = self.kv.free_seq(done.id);
                    }
                    return Err(e.into());
                }
            }
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.kv.used_blocks());
            let max_in = batch.iter().map(|r| r.input_len).max().unwrap();
            let t_prefill =
                self.profile.truth.prefill_ms(b, max_in) * self.noise();
            self.clock_ms += t_prefill;
            self.batches_run += 1;
            let first_token_ms = self.clock_ms;
            if self.record_steps {
                self.step_events.push(StepEvent {
                    t_ms: first_token_ms,
                    emitted: batch.iter().map(|r| r.id).collect(),
                    ..StepEvent::default()
                });
            }
            vec![first_token_ms; b]
        };

        let truth = self.profile.truth;
        let block_tokens = self.kv.config().block_tokens;
        let swap_ms_per_block = self.swap_ms_per_block();
        // Absolute deadlines for slack ordering (missing ⇒ +∞: such a
        // member has "infinite slack" — the preferred victim, the last
        // resume candidate).
        let ddl: Vec<f64> = batch
            .iter()
            .map(|r| {
                self.deadlines.get(&r.id).copied().unwrap_or(f64::INFINITY)
            })
            .collect();
        let mut remaining: Vec<usize> =
            actual.iter().map(|&a| a.max(1) - 1).collect();
        let mut accumulated: Vec<usize> =
            batch.iter().map(|r| r.input_len + 1).collect();
        let mut generated = vec![1usize; b];
        let mut finish = first_token.clone();
        // A member holds device KV iff it is unfinished and not
        // suspended; `swapped_blocks[i] > 0` records host-buffer
        // occupancy while suspended in Swap mode (0 ⇒ recompute resume).
        let mut suspended = vec![false; b];
        let mut swapped_blocks = vec![0u64; b];
        let mut live = remaining.iter().filter(|&&r| r > 0).count();
        for (i, r) in batch.iter().enumerate() {
            if remaining[i] == 0 {
                self.kv.free_seq(r.id)?;
            }
        }
        while live > 0 {
            // Remaining-work slack of member `i` at the current clock
            // (recomputed as the clock moves; pure arithmetic, no RNG).
            let slack = |i: usize,
                         clock: f64,
                         accumulated: &[usize],
                         remaining: &[usize]| {
                let exec = (remaining[i].max(1) as f64
                    * truth.tpot_at(b, accumulated[i]))
                .max(1e-9);
                slack_key(ddl[i] - clock, exec)
            };
            let mut resumed_ids: Vec<u64> = Vec::new();
            let mut suspended_ids: Vec<u64> = Vec::new();
            // ---- resume pass: most urgent first, while the context plus
            // one block of growth headroom fits. With nothing active the
            // headroom is waived; a context that cannot fit even the
            // empty pool is truncated (the physical limit).
            loop {
                let any_active =
                    (0..b).any(|i| remaining[i] > 0 && !suspended[i]);
                let mut cand: Option<(f64, usize)> = None;
                for i in 0..b {
                    if remaining[i] == 0 || !suspended[i] {
                        continue;
                    }
                    let s = slack(i, self.clock_ms, &accumulated, &remaining);
                    let more_urgent = match cand {
                        Some((cs, _)) => s < cs,
                        None => true,
                    };
                    if more_urgent {
                        cand = Some((s, i));
                    }
                }
                let Some((_, i)) = cand else { break };
                let need = if any_active {
                    accumulated[i] + block_tokens
                } else {
                    accumulated[i]
                };
                if self.kv.fits(need) {
                    self.kv.alloc_seq(batch[i].id, accumulated[i])?;
                    if swapped_blocks[i] > 0 {
                        let cost =
                            swapped_blocks[i] as f64 * swap_ms_per_block;
                        self.clock_ms += cost;
                        self.pstats.swap_ins += 1;
                        self.pstats.swap_blocks += swapped_blocks[i];
                        self.pstats.swap_ms += cost;
                        self.host_blocks_used -= swapped_blocks[i];
                        swapped_blocks[i] = 0;
                    } else {
                        let cost = truth.prefill_ms(1, accumulated[i]);
                        self.clock_ms += cost;
                        self.pstats.recompute_resumes += 1;
                        self.pstats.recompute_ms += cost;
                    }
                    suspended[i] = false;
                    resumed_ids.push(batch[i].id);
                    self.peak_used_blocks =
                        self.peak_used_blocks.max(self.kv.used_blocks());
                } else if !any_active {
                    // EOS-on-OOM at the resume boundary: finish stays at
                    // the last emitted token, like the legacy truncation.
                    if swapped_blocks[i] > 0 {
                        self.host_blocks_used -= swapped_blocks[i];
                        swapped_blocks[i] = 0;
                    }
                    suspended[i] = false;
                    remaining[i] = 0;
                    live -= 1;
                    self.kv_truncations += 1;
                } else {
                    break; // wait for active members to release KV
                }
            }
            if live == 0 {
                if self.record_steps
                    && (!resumed_ids.is_empty() || !suspended_ids.is_empty())
                {
                    self.step_events.push(StepEvent {
                        t_ms: self.clock_ms,
                        suspended: suspended_ids,
                        resumed: resumed_ids,
                        ..StepEvent::default()
                    });
                }
                break;
            }
            // ---- one decode iteration over the active set (batch-size
            // term stays b: static batch semantics, as in the legacy
            // paths).
            let max_acc = accumulated
                .iter()
                .enumerate()
                .filter(|&(i, _)| remaining[i] > 0 && !suspended[i])
                .map(|(_, a)| *a)
                .max()
                .unwrap_or(0);
            let step = self.profile.truth.tpot_at(b, max_acc) * self.noise();
            self.clock_ms += step;
            self.decode_steps += 1;
            // ---- growth: extend every active member by the token it is
            // about to emit; on pool exhaustion suspend the slackest
            // active member (possibly the grower itself) and retry.
            for i in 0..b {
                if remaining[i] == 0 || suspended[i] {
                    continue;
                }
                loop {
                    if self.kv.extend_seq(batch[i].id, 1).is_ok() {
                        break;
                    }
                    if self.kv.blocks_needed(accumulated[i] + 1)
                        > self.kv.config().total_blocks
                    {
                        // Physical limit: this context plus one token
                        // exceeds the entire pool — no victim set can
                        // help, and suspending would only livelock the
                        // batch in suspend/resume cycles. Legacy
                        // EOS-on-OOM, exactly like the truncating path
                        // (finish stays at the last emitted token).
                        remaining[i] = 0;
                        live -= 1;
                        self.kv_truncations += 1;
                        self.kv.free_seq(batch[i].id)?;
                        break;
                    }
                    let mut victim: Option<(f64, usize)> = None;
                    for j in 0..b {
                        if remaining[j] == 0 || suspended[j] {
                            continue;
                        }
                        let s =
                            slack(j, self.clock_ms, &accumulated, &remaining);
                        // max slack wins; ties go to the higher index
                        let slacker = match victim {
                            Some((vs, _)) => s >= vs,
                            None => true,
                        };
                        if slacker {
                            victim = Some((s, j));
                        }
                    }
                    // `i` itself is active, so a victim always exists.
                    let Some((_, v)) = victim else { break };
                    suspended[v] = true;
                    self.pstats.preemptions += 1;
                    suspended_ids.push(batch[v].id);
                    let ctx_blocks =
                        self.kv.blocks_needed(accumulated[v]) as u64;
                    if matches!(self.preempt.mode, PreemptMode::Swap)
                        && self.host_blocks_used + ctx_blocks
                            <= self.preempt.host_blocks
                    {
                        let cost = ctx_blocks as f64 * swap_ms_per_block;
                        self.clock_ms += cost;
                        self.pstats.swap_outs += 1;
                        self.pstats.swap_blocks += ctx_blocks;
                        self.pstats.swap_ms += cost;
                        self.host_blocks_used += ctx_blocks;
                        self.host_blocks_peak =
                            self.host_blocks_peak.max(self.host_blocks_used);
                        swapped_blocks[v] = ctx_blocks;
                    }
                    self.kv.free_seq(batch[v].id)?;
                    if v == i {
                        break; // the grower yielded: no token this step
                    }
                }
            }
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.kv.used_blocks());
            // ---- emission over the members that grew
            let mut emitted: Vec<u64> = Vec::new();
            for i in 0..b {
                if remaining[i] == 0 || suspended[i] {
                    continue;
                }
                remaining[i] -= 1;
                accumulated[i] += 1;
                generated[i] += 1;
                finish[i] = self.clock_ms;
                if self.record_steps {
                    emitted.push(batch[i].id);
                }
                if remaining[i] == 0 {
                    live -= 1;
                    self.kv.free_seq(batch[i].id)?;
                }
            }
            if self.record_steps
                && (!emitted.is_empty()
                    || !suspended_ids.is_empty()
                    || !resumed_ids.is_empty())
            {
                self.step_events.push(StepEvent {
                    t_ms: self.clock_ms,
                    emitted,
                    suspended: suspended_ids,
                    resumed: resumed_ids,
                    ..StepEvent::default()
                });
            }
        }
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, r)| ItemResult {
                id: r.id,
                start_ms: start,
                first_token_ms: first_token[i],
                finish_ms: finish[i],
                generated: generated[i],
                batch_size: b,
                text: None,
            })
            .collect())
    }

    fn retire(
        active: &mut Vec<Active>,
        done: &mut Vec<ItemResult>,
        kv: &mut BlockAllocator,
        now_ms: f64,
        batch_size: usize,
    ) {
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining == 0 {
                let a = active.swap_remove(i);
                let _ = kv.free_seq(a.id);
                done.push(ItemResult {
                    id: a.id,
                    start_ms: a.start_ms,
                    first_token_ms: a.first_token_ms,
                    finish_ms: now_ms,
                    generated: a.generated,
                    batch_size: batch_size.max(a.batch_at_prefill),
                    text: None,
                });
            } else {
                i += 1;
            }
        }
    }
}

/// Continuous-mode in-flight sequence state.
struct Active {
    id: u64,
    remaining: usize,
    accumulated: usize,
    /// Tokens covered by the admission-time KV reservation (prompt +
    /// nominal budget); only consulted under divergence, where decode
    /// may overrun it and must extend the allocation.
    alloc_tokens: usize,
    start_ms: f64,
    first_token_ms: f64,
    generated: usize,
    batch_at_prefill: usize,
}

impl Engine for SimEngine {
    fn name(&self) -> String {
        format!("sim:{}", self.profile.name)
    }

    fn now_ms(&self) -> f64 {
        self.clock_ms
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_total_tokens(&self) -> usize {
        self.profile.max_total_tokens
    }

    fn enable_step_trace(&mut self) {
        self.record_steps = true;
    }

    fn take_step_events(&mut self) -> Vec<StepEvent> {
        std::mem::take(&mut self.step_events)
    }

    fn set_deadlines(&mut self, deadlines: &[(u64, f64)]) {
        // Later submissions for the same id win (an online controller may
        // re-submit after a deferral with the same absolute deadline).
        for &(id, ddl) in deadlines {
            self.deadlines.insert(id, ddl);
        }
    }

    fn preemption_stats(&self) -> PreemptionStats {
        PreemptionStats {
            kv_truncations: self.kv_truncations,
            ..self.pstats
        }
    }

    fn run_batch(&mut self, batch: &[EngineRequest]) -> Result<Vec<ItemResult>> {
        validate_batch(self, batch)?;
        if !self.divergence.is_off() {
            // Divergent execution is a separate path so that `Off` keeps
            // this legacy body — RNG stream, KV behaviour, completions —
            // byte for byte. Preemption only changes behaviour where
            // divergence can exhaust the pool mid-decode; its path is
            // split again so `PreemptConfig::OFF` keeps the truncating
            // divergent body untouched.
            if self.preempt.enabled() {
                return self.run_batch_divergent_preempt(batch);
            }
            return self.run_batch_divergent(batch);
        }
        let b = batch.len();
        let phased = matches!(self.kv_phase, KvPhaseModel::Phased);
        // KV admission for the whole batch, checked up front: a planned
        // batch that does not fit the pool is a scheduler bug (the
        // KV-aware search guarantees feasibility), and failing before any
        // allocation keeps the allocator consistent — no partial batch
        // ever holds blocks. Reserve mode checks (and then pins) the sum
        // of full footprints; phased mode checks the exact occupancy peak
        // of the lockstep profile it is about to execute, then allocates
        // prompt KV only.
        let need_blocks = self.planned_demand_blocks(batch);
        if need_blocks > self.kv.free_blocks() {
            anyhow::bail!(
                "planned batch of {b} requests overcommits the KV pool: \
                 needs {need_blocks} blocks ({:?} demand), {} free of {} \
                 total — the scheduler planned an infeasible batch",
                self.kv_phase,
                self.kv.free_blocks(),
                self.kv.config().total_blocks,
            );
        }
        let chunked = self.chunk_tokens > 0;
        if !(chunked && phased) {
            // Upfront allocation: reserve mode always (full footprints
            // pinned before any timing); phased mode only when chunking
            // is off — chunked phased allocates progressively per chunk.
            for (i, r) in batch.iter().enumerate() {
                // phased: prompt + the first token prefill emits (clamped
                // to the token budget, so a zero-output request never pins
                // more than its reserve footprint); reserve: the full
                // input + output footprint, pinned until batch end.
                let tokens = if phased {
                    r.input_len + r.max_new_tokens.min(1)
                } else {
                    r.input_len + r.max_new_tokens
                };
                if let Err(e) = self.kv.alloc_seq(r.id, tokens) {
                    // e.g. duplicate request ids within one batch: release
                    // the already-allocated prefix so the refusal leaks
                    // nothing.
                    for done in &batch[..i] {
                        let _ = self.kv.free_seq(done.id);
                    }
                    return Err(e.into());
                }
            }
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.kv.used_blocks());
        }
        let start = self.clock_ms;
        let first_token: Vec<f64> = if chunked {
            let ft: Vec<usize> =
                batch.iter().map(|r| r.max_new_tokens.min(1)).collect();
            let kv_arg = if phased { Some(ft.as_slice()) } else { None };
            self.chunked_prefill_phase(batch, kv_arg)?
        } else {
            let max_in = batch.iter().map(|r| r.input_len).max().unwrap();
            let t_prefill =
                self.profile.truth.prefill_ms(b, max_in) * self.noise();
            self.clock_ms += t_prefill;
            self.batches_run += 1;
            let first_token_ms = self.clock_ms;
            if self.record_steps {
                // prefill emits every member's first token at once
                self.step_events.push(StepEvent {
                    t_ms: first_token_ms,
                    emitted: batch.iter().map(|r| r.id).collect(),
                    ..StepEvent::default()
                });
            }
            vec![first_token_ms; b]
        };

        // decode: every member advances one token per iteration until all
        // reach their budget; the batch-size term stays b for stragglers
        // (static batch semantics: slots are not refilled).
        let mut remaining: Vec<usize> =
            batch.iter().map(|r| r.max_new_tokens.saturating_sub(1)).collect();
        let mut accumulated: Vec<usize> =
            batch.iter().map(|r| r.input_len + 1).collect();
        let mut finish = first_token.clone();
        let mut live = remaining.iter().filter(|&&r| r > 0).count();
        if phased {
            // members whose single token came out of prefill are done:
            // release their blocks before any decode occupancy grows.
            for (i, r) in batch.iter().enumerate() {
                if remaining[i] == 0 {
                    self.kv.free_seq(r.id)?;
                }
            }
        }
        while live > 0 {
            let max_acc = accumulated
                .iter()
                .zip(&remaining)
                .filter(|(_, rem)| **rem > 0)
                .map(|(a, _)| *a)
                .max()
                .unwrap_or(0);
            let step = self.profile.truth.tpot_at(b, max_acc) * self.noise();
            self.clock_ms += step;
            self.decode_steps += 1;
            if phased {
                // grow every live member by the token it is about to
                // emit (the pre-checked peak covers this by construction),
                // record the occupancy high-water mark, then let
                // completing members release below.
                for (i, r) in batch.iter().enumerate() {
                    if remaining[i] > 0 {
                        self.kv.extend_seq(r.id, 1)?;
                    }
                }
                self.peak_used_blocks =
                    self.peak_used_blocks.max(self.kv.used_blocks());
            }
            let mut emitted: Vec<u64> = Vec::new();
            for i in 0..b {
                if remaining[i] > 0 {
                    remaining[i] -= 1;
                    accumulated[i] += 1;
                    finish[i] = self.clock_ms;
                    if self.record_steps {
                        emitted.push(batch[i].id);
                    }
                    if remaining[i] == 0 {
                        live -= 1;
                        if phased {
                            self.kv.free_seq(batch[i].id)?;
                        }
                    }
                }
            }
            if self.record_steps && !emitted.is_empty() {
                self.step_events.push(StepEvent {
                    t_ms: self.clock_ms,
                    emitted,
                    ..StepEvent::default()
                });
            }
        }
        let results = batch
            .iter()
            .enumerate()
            .map(|(i, r)| ItemResult {
                id: r.id,
                start_ms: start,
                first_token_ms: first_token[i],
                finish_ms: finish[i],
                generated: r.max_new_tokens.max(1),
                batch_size: b,
                text: None,
            })
            .collect();
        if !phased {
            // reserve mode pinned full footprints; phased mode already
            // released every member at its completion.
            for r in batch {
                self.kv.free_seq(r.id)?;
            }
        }
        Ok(results)
    }

    fn advance_to(&mut self, target_ms: f64) {
        if target_ms > self.clock_ms {
            self.clock_ms = target_ms;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::by_name;

    fn quiet_profile() -> HardwareProfile {
        let mut p = by_name("qwen7b-v100x2-vllm").unwrap();
        p.noise_std = 0.0; // deterministic timing for assertions
        p
    }

    fn req(id: u64, input: usize, output: usize) -> EngineRequest {
        EngineRequest { id, input_len: input, max_new_tokens: output, prompt: None }
    }

    #[test]
    fn planned_batch_timing_matches_model() {
        let p = quiet_profile();
        let truth = p.truth;
        let mut e = SimEngine::new(p, 4, 0);
        let batch = vec![req(1, 500, 10), req(2, 300, 5)];
        let out = e.run_batch(&batch).unwrap();
        // prefill at b=2, max input 500
        let t_prefill = truth.prefill_ms(2, 500);
        assert!((out[0].first_token_ms - t_prefill).abs() < 1e-6);
        // request 1 decodes 9 more tokens, request 2 decodes 4 more; the
        // batch runs 9 iterations; finish of request 2 is at iteration 4.
        assert!(out[0].finish_ms > out[1].finish_ms);
        assert_eq!(out[0].generated, 10);
        assert_eq!(out[1].generated, 5);
        assert_eq!(e.decode_steps, 9);
        // KV fully released
        assert_eq!(e.kv().active_seqs(), 0);
    }

    #[test]
    fn batch_exceeding_max_rejected() {
        let mut e = SimEngine::new(quiet_profile(), 2, 0);
        let batch: Vec<EngineRequest> =
            (0..3).map(|i| req(i, 10, 2)).collect();
        assert!(e.run_batch(&batch).is_err());
    }

    #[test]
    fn overlong_request_rejected() {
        let mut e = SimEngine::new(quiet_profile(), 2, 0);
        let batch = vec![req(1, 2000, 100)]; // > 2048 total
        assert!(e.run_batch(&batch).is_err());
    }

    #[test]
    fn clock_accumulates_across_batches() {
        let mut e = SimEngine::new(quiet_profile(), 4, 0);
        e.run_batch(&[req(1, 100, 5)]).unwrap();
        let t1 = e.now_ms();
        e.run_batch(&[req(2, 100, 5)]).unwrap();
        assert!(e.now_ms() > t1);
        e.advance_to(1e9);
        assert_eq!(e.now_ms(), 1e9);
        e.advance_to(5.0); // never goes backward
        assert_eq!(e.now_ms(), 1e9);
    }

    #[test]
    fn continuous_respects_arrival_times() {
        let p = quiet_profile();
        let truth = p.truth;
        let mut e = SimEngine::new(p, 4, 0);
        let arrivals = vec![
            (0.0, req(1, 100, 3)),
            (100_000.0, req(2, 100, 3)), // arrives long after 1 finishes
        ];
        let out = e.run_continuous(&arrivals).unwrap();
        assert_eq!(out.len(), 2);
        let r2 = out.iter().find(|r| r.id == 2).unwrap();
        assert!(r2.start_ms >= 100_000.0);
        let expected_first =
            100_000.0 + truth.prefill_ms(1, 100);
        assert!((r2.first_token_ms - expected_first).abs() < 1e-6);
    }

    #[test]
    fn continuous_batches_concurrent_arrivals() {
        let mut e = SimEngine::new(quiet_profile(), 4, 0);
        let arrivals: Vec<(f64, EngineRequest)> =
            (0..4).map(|i| (0.0, req(i, 100, 10))).collect();
        let out = e.run_continuous(&arrivals).unwrap();
        // all four prefill together
        assert!(out.iter().all(|r| r.batch_size == 4));
        // TPOT reflects batch-4 decode
        assert!(out[0].tpot_ms() > 0.0);
    }

    #[test]
    fn continuous_respects_max_batch() {
        let mut e = SimEngine::new(quiet_profile(), 2, 0);
        let arrivals: Vec<(f64, EngineRequest)> =
            (0..5).map(|i| (0.0, req(i, 100, 50))).collect();
        let out = e.run_continuous(&arrivals).unwrap();
        assert_eq!(out.len(), 5);
        // later arrivals waited: first-token times are staggered
        let mut fts: Vec<f64> = out.iter().map(|r| r.first_token_ms).collect();
        fts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(fts[4] > fts[0]);
        assert_eq!(e.kv().active_seqs(), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut e = SimEngine::new(
                by_name("qwen7b-v100x2-vllm").unwrap(),
                4,
                seed,
            );
            e.run_batch(&[req(1, 500, 20), req(2, 400, 10)])
                .unwrap()
                .iter()
                .map(|r| r.finish_ms)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4)); // noise differs across seeds
    }

    #[test]
    fn seed_is_recorded_across_reset() {
        let mut e = SimEngine::new(quiet_profile(), 2, 41);
        assert_eq!(e.seed(), 41);
        e.reset(99);
        assert_eq!(e.seed(), 99);
        assert_eq!(e.now_ms(), 0.0);
    }

    #[test]
    fn planned_batches_interleave_with_arrival_jumps() {
        // The online event loop alternates run_batch with advance_to the
        // next arrival; the virtual clock must honor both directions of
        // progress (batch execution and idle jumps) without going back.
        let p = quiet_profile();
        let truth = p.truth;
        let mut e = SimEngine::new(p, 2, 0);
        e.run_batch(&[req(1, 200, 5)]).unwrap();
        let after_first = e.now_ms();
        assert!(after_first > 0.0);
        // idle until an arrival far in the future
        e.advance_to(after_first + 5_000.0);
        let t_arrival = e.now_ms();
        assert_eq!(t_arrival, after_first + 5_000.0);
        let out = e.run_batch(&[req(2, 100, 3)]).unwrap();
        // the second batch starts at the arrival jump, not before
        assert!((out[0].start_ms - t_arrival).abs() < 1e-9);
        let expected_first = t_arrival + truth.prefill_ms(1, 100);
        assert!((out[0].first_token_ms - expected_first).abs() < 1e-6);
        // an arrival in the past never rewinds the clock
        e.advance_to(1.0);
        assert!(e.now_ms() >= expected_first);
    }

    #[test]
    fn overcommitted_planned_batch_fails_cleanly() {
        let mut p = quiet_profile();
        p.kv_pool_mb = 100.0; // 200 tokens at 0.5 MB/token -> 12 blocks
        let mut e = SimEngine::new(p, 4, 0);
        assert_eq!(e.kv().config().total_blocks, 12);
        // two requests of 110 tokens = 7 blocks each -> 14 > 12
        let batch = vec![req(1, 100, 10), req(2, 100, 10)];
        let err = e.run_batch(&batch).unwrap_err();
        assert!(
            format!("{err}").contains("overcommits the KV pool"),
            "unhelpful error: {err}"
        );
        // the refused batch must not leak blocks (no partial allocation)
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), 12);
        // a feasible singleton still runs, and peak occupancy is recorded
        e.run_batch(&[req(3, 100, 10)]).unwrap();
        assert_eq!(e.peak_used_blocks(), 7);
        assert_eq!(e.kv().active_seqs(), 0);
    }

    #[test]
    fn phased_engine_admits_peak_fitting_batch_reserve_refuses() {
        use crate::coordinator::kv::KvPhaseModel;
        let mut p = quiet_profile();
        // 200 MB at 0.5 MB/token -> 400 tokens -> 25 blocks
        p.kv_pool_mb = 200.0;
        // job A: 160 in / 4 out (11 blocks full); job B: 160 in / 160 out
        // (20 blocks full). Reserve sum 31 > 25; phased peak 22 <= 25.
        let batch = vec![req(1, 160, 4), req(2, 160, 160)];

        let mut reserve = SimEngine::new(p.clone(), 4, 0);
        assert_eq!(reserve.kv().config().total_blocks, 25);
        let err = reserve.run_batch(&batch).unwrap_err();
        assert!(format!("{err}").contains("overcommits the KV pool"), "{err}");
        assert_eq!(reserve.kv().active_seqs(), 0);

        let mut phased = SimEngine::new(p, 4, 0)
            .with_kv_phase(KvPhaseModel::Phased);
        assert_eq!(phased.kv_phase(), KvPhaseModel::Phased);
        let out = phased.run_batch(&batch).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].generated, 4);
        assert_eq!(out[1].generated, 160);
        // the high-water mark is the phased peak, within the pool
        assert_eq!(phased.peak_used_blocks(), 22);
        // everything released at completion — no leaks
        assert_eq!(phased.kv().active_seqs(), 0);
        assert_eq!(phased.kv().free_blocks(), 25);
    }

    #[test]
    fn phased_timing_matches_reserve_timing() {
        use crate::coordinator::kv::KvPhaseModel;
        let p = quiet_profile();
        let batch = vec![req(1, 500, 20), req(2, 300, 7)];
        let mut a = SimEngine::new(p.clone(), 4, 3);
        let mut b =
            SimEngine::new(p, 4, 3).with_kv_phase(KvPhaseModel::Phased);
        let ra = a.run_batch(&batch).unwrap();
        let rb = b.run_batch(&batch).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.first_token_ms.to_bits(), y.first_token_ms.to_bits());
        }
        // phased never exceeds the reserve high-water mark
        assert!(b.peak_used_blocks() <= a.peak_used_blocks());
        assert_eq!(b.kv().active_seqs(), 0);
    }

    #[test]
    fn phased_one_token_member_frees_at_prefill() {
        use crate::coordinator::kv::KvPhaseModel;
        let mut e = SimEngine::new(quiet_profile(), 4, 0)
            .with_kv_phase(KvPhaseModel::Phased);
        let out = e
            .run_batch(&[req(1, 50, 1), req(2, 50, 8)])
            .unwrap();
        assert_eq!(out[0].generated, 1);
        assert!(out[1].finish_ms > out[0].finish_ms);
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
    }

    #[test]
    fn duplicate_ids_in_batch_leak_nothing() {
        // passes the pool pre-check, fails at the second alloc_seq: the
        // already-allocated prefix must be released before erroring.
        let mut e = SimEngine::new(quiet_profile(), 4, 0);
        let batch = vec![req(1, 100, 10), req(1, 100, 10)];
        assert!(e.run_batch(&batch).is_err());
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
    }

    #[test]
    fn one_token_requests_finish_at_prefill() {
        let mut e = SimEngine::new(quiet_profile(), 4, 0);
        let out = e.run_batch(&[req(1, 50, 1)]).unwrap();
        assert_eq!(out[0].generated, 1);
        assert!((out[0].finish_ms - out[0].first_token_ms).abs() < 1e-9);
        assert_eq!(out[0].tpot_ms(), 0.0);
    }

    #[test]
    fn divergence_spec_parsing() {
        assert_eq!(DivergenceModel::parse("off"), Ok(DivergenceModel::Off));
        assert_eq!(
            DivergenceModel::parse("lognormal:0.5"),
            Ok(DivergenceModel::Lognormal { sigma: 0.5 })
        );
        assert_eq!(
            DivergenceModel::parse("quantile-trace:0.2"),
            Ok(DivergenceModel::QuantileTrace { sigma: 0.2 })
        );
        assert!(DivergenceModel::parse("lognormal:x").is_err());
        assert!(DivergenceModel::parse("lognormal:-1").is_err());
        assert!(DivergenceModel::parse("gamma:0.5").is_err());
        assert_eq!(DivergenceModel::Off.sigma(), 0.0);
        assert_eq!(
            DivergenceModel::Lognormal { sigma: 0.3 }.sigma(),
            0.3
        );
    }

    #[test]
    fn divergence_off_is_bit_identical_to_default_engine() {
        // the escape hatch: `with_divergence(Off)` must replay the
        // constructor's engine byte for byte — noisy timing included.
        let profile = by_name("qwen7b-v100x2-vllm").unwrap();
        let batch = vec![req(1, 500, 20), req(2, 400, 10)];
        let mut plain = SimEngine::new(profile.clone(), 4, 7);
        let mut off = SimEngine::new(profile, 4, 7)
            .with_divergence(DivergenceModel::Off);
        let a = plain.run_batch(&batch).unwrap();
        let b = off.run_batch(&batch).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.first_token_ms.to_bits(), y.first_token_ms.to_bits());
            assert_eq!(x.generated, y.generated);
        }
        assert_eq!(off.kv_truncations(), 0);
        assert_eq!(plain.peak_used_blocks(), off.peak_used_blocks());
    }

    #[test]
    fn lognormal_sigma_zero_has_off_timing_and_lengths() {
        // σ = 0 draws from the divergence stream but scales by exactly
        // 1.0: actual == nominal, and because the divergence stream is
        // separate from the noise stream, timing matches Off bit for bit.
        let profile = by_name("qwen7b-v100x2-vllm").unwrap();
        let batch = vec![req(1, 500, 20), req(2, 300, 7)];
        let mut off = SimEngine::new(profile.clone(), 4, 5);
        let mut zero = SimEngine::new(profile, 4, 5)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.0 });
        let a = off.run_batch(&batch).unwrap();
        let b = zero.run_batch(&batch).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.generated, y.generated);
        }
        assert_eq!(zero.kv().active_seqs(), 0);
    }

    #[test]
    fn lognormal_divergence_changes_lengths_without_leaking() {
        let mut e = SimEngine::new(quiet_profile(), 4, 3)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.5 });
        let batch: Vec<EngineRequest> =
            (0..4).map(|i| req(i, 200, 40)).collect();
        let out = e.run_batch(&batch).unwrap();
        assert_eq!(out.len(), 4);
        // identical nominals, per-request divergence: lengths spread out
        assert!(
            out.iter().any(|r| r.generated != 40),
            "σ=0.5 produced no divergence: {:?}",
            out.iter().map(|r| r.generated).collect::<Vec<_>>()
        );
        // short members finish before long ones; everyone frees its KV
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), e.kv().config().total_blocks);
        // reruns with the same seed replay the same divergence
        let mut e2 = SimEngine::new(quiet_profile(), 4, 3)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.5 });
        let out2 = e2.run_batch(&batch).unwrap();
        for (x, y) in out.iter().zip(&out2) {
            assert_eq!(x.generated, y.generated);
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
        }
    }

    #[test]
    fn quantile_trace_is_a_pure_function_of_the_request_id() {
        let model = DivergenceModel::QuantileTrace { sigma: 0.4 };
        let mut rng_a = Rng::new(1);
        let mut rng_b = Rng::new(999);
        for id in 0..200u64 {
            let a = model.actual_lo(id, 100, &mut rng_a);
            let b = model.actual_lo(id, 100, &mut rng_b);
            assert_eq!(a, b, "id {id} depends on more than the id");
        }
        // the trace stream consumed nothing
        assert_eq!(rng_a.next_u64(), Rng::new(1).next_u64());
        // and the multipliers actually vary across ids
        let distinct: std::collections::BTreeSet<usize> = (0..50)
            .map(|id| model.actual_lo(id, 100, &mut rng_a))
            .collect();
        assert!(distinct.len() > 5, "degenerate trace: {distinct:?}");
    }

    #[test]
    fn divergent_overrun_on_tight_pool_truncates_without_leaking() {
        // pool of exactly 7 blocks (112 tokens): a 100-token prompt with
        // a 10-token nominal fits the pre-check, but an actual length
        // beyond 12 tokens exhausts the pool mid-decode — the member must
        // be force-stopped at EOS-on-OOM, leak-free.
        let model = DivergenceModel::QuantileTrace { sigma: 1.0 };
        let mut probe = Rng::new(0);
        let id = (0..1000u64)
            .find(|&id| model.actual_lo(id, 10, &mut probe) >= 13)
            .expect("some id must overrun");
        let mut p = quiet_profile();
        p.kv_pool_mb = 56.0; // 112 tokens at 0.5 MB/token -> 7 blocks
        let mut e = SimEngine::new(p, 4, 0).with_divergence(model);
        assert_eq!(e.kv().config().total_blocks, 7);
        let out = e.run_batch(&[req(id, 100, 10)]).unwrap();
        assert_eq!(e.kv_truncations(), 1);
        // truncated exactly at the pool's 12-token decode headroom
        assert_eq!(out[0].generated, 12);
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), 7);
        assert_eq!(e.peak_used_blocks(), 7);
    }

    /// Two-member overrun scenario on a 7-block pool: both pass the
    /// nominal pre-check, both overrun, and their combined growth
    /// exhausts the pool mid-decode while each individual context still
    /// fits — the preemption sweet spot. Returns `(requests, expected
    /// actual lengths, model)`.
    fn overrun_pair() -> (Vec<EngineRequest>, Vec<usize>, DivergenceModel) {
        let model = DivergenceModel::QuantileTrace { sigma: 1.0 };
        let mut probe = Rng::new(0);
        let id_a = (0..5000u64)
            .find(|&id| {
                (40..=60).contains(&model.actual_lo(id, 10, &mut probe))
            })
            .expect("some id must overrun into [40, 60]");
        let id_b = (0..5000u64)
            .find(|&id| {
                id != id_a
                    && (19..=25).contains(&model.actual_lo(id, 10, &mut probe))
            })
            .expect("some id must overrun into [19, 25]");
        let expect = vec![
            model.actual_lo(id_a, 10, &mut probe),
            model.actual_lo(id_b, 10, &mut probe),
        ];
        (vec![req(id_a, 30, 10), req(id_b, 30, 10)], expect, model)
    }

    #[test]
    fn preemption_recompute_completes_overruns_without_truncation() {
        let (batch, expect, model) = overrun_pair();
        let mut p = quiet_profile();
        p.kv_pool_mb = 56.0; // 7 blocks of 16 tokens
        let mut e = SimEngine::new(p, 4, 0)
            .with_divergence(model)
            .with_preemption(PreemptConfig::recompute());
        assert_eq!(e.kv().config().total_blocks, 7);
        let out = e.run_batch(&batch).unwrap();
        // no member was force-stopped: both ran to their true EOS
        assert_eq!(e.kv_truncations(), 0);
        assert_eq!(out[0].generated, expect[0]);
        assert_eq!(out[1].generated, expect[1]);
        // ...which was only possible by suspending somebody
        let ps = e.preemption_stats();
        assert!(ps.preemptions >= 1, "pool never exhausted: {ps:?}");
        assert!(ps.recompute_resumes >= 1);
        assert!(ps.recompute_ms > 0.0);
        assert_eq!(ps.swap_outs, 0);
        assert_eq!(ps.kv_truncations, 0);
        // leak-free: every block returned
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), 7);
        // deterministic: a fresh engine replays the run bit for bit
        let mut p2 = quiet_profile();
        p2.kv_pool_mb = 56.0;
        let mut e2 = SimEngine::new(p2, 4, 0)
            .with_divergence(model)
            .with_preemption(PreemptConfig::recompute());
        let out2 = e2.run_batch(&batch).unwrap();
        for (x, y) in out.iter().zip(&out2) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.generated, y.generated);
        }
        assert_eq!(e2.preemption_stats(), ps);
    }

    #[test]
    fn preemption_swap_accounting_matches_link_model() {
        let (batch, expect, model) = overrun_pair();
        let mut p = quiet_profile();
        p.kv_pool_mb = 56.0;
        // block_mb = 16 tokens × 0.5 MB = 8 MB; at 8 GB/s (1 GB/s =
        // 1 MB/ms) one block moves in exactly 1 ms
        let mut e = SimEngine::new(p, 4, 0)
            .with_divergence(model)
            .with_preemption(PreemptConfig::swap(8.0, 64));
        assert_eq!(e.swap_ms_per_block(), 1.0);
        let out = e.run_batch(&batch).unwrap();
        assert_eq!(e.kv_truncations(), 0);
        assert_eq!(out[0].generated, expect[0]);
        assert_eq!(out[1].generated, expect[1]);
        let ps = e.preemption_stats();
        assert!(ps.swap_outs >= 1, "no swap traffic: {ps:?}");
        // ample host buffer: every suspension swapped, every suspended
        // member swapped back in — nothing degraded to recompute
        assert_eq!(ps.swap_ins, ps.swap_outs);
        assert_eq!(ps.recompute_resumes, 0);
        // the clock charge is exactly the modeled link transfer
        let modeled = ps.swap_blocks as f64 * e.swap_ms_per_block();
        assert!(
            (ps.swap_ms - modeled).abs() <= 1e-9 * modeled.max(1.0),
            "swap_ms {} != blocks×per-block {}",
            ps.swap_ms,
            modeled
        );
        assert!(e.host_blocks_peak() >= 1);
        assert!(e.host_blocks_peak() <= 64);
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), 7);

        // a host buffer too small for any context degrades to recompute
        let mut p2 = quiet_profile();
        p2.kv_pool_mb = 56.0;
        let mut tiny = SimEngine::new(p2, 4, 0)
            .with_divergence(model)
            .with_preemption(PreemptConfig::swap(8.0, 1));
        let out2 = tiny.run_batch(&batch).unwrap();
        assert_eq!(out2[0].generated, expect[0]);
        assert_eq!(out2[1].generated, expect[1]);
        let ps2 = tiny.preemption_stats();
        assert_eq!(ps2.swap_outs, 0, "3-block contexts cannot fit 1 block");
        assert!(ps2.recompute_resumes >= 1);
        assert_eq!(tiny.kv().active_seqs(), 0);
    }

    /// Three-member staggered-overrun scenario on a 9-block pool: equal
    /// 30-token prompts with 10-token nominals exactly fill the reserve
    /// pre-check, and the disjoint actual-length bands (short / long /
    /// long) make the pool exhaust at *different* decode depths — once
    /// while all three are live, again after the short member has
    /// retired. The member with no deadline (+∞ slack) is the designated
    /// victim both times, so the test pins the resume-pass/headroom rule
    /// across repeated suspensions of the same member. Returns
    /// `(requests, expected actual lengths, model, victim index)`.
    fn staggered_trio(
    ) -> (Vec<EngineRequest>, Vec<usize>, DivergenceModel, usize) {
        let model = DivergenceModel::QuantileTrace { sigma: 1.0 };
        let mut probe = Rng::new(0);
        // the deadline-carrying long member must outlive the victim's
        // second block-boundary crossing (≥ 50 keeps it live past the
        // victim's catch-up window after the short member retires)
        let id_a = (0..5000u64)
            .find(|&id| {
                (50..=60).contains(&model.actual_lo(id, 10, &mut probe))
            })
            .expect("some id must overrun into [50, 60]");
        let id_b = (0..5000u64)
            .find(|&id| {
                id != id_a
                    && (40..=60).contains(&model.actual_lo(id, 10, &mut probe))
            })
            .expect("a second id must overrun into [40, 60]");
        let id_c = (0..5000u64)
            .find(|&id| {
                id != id_a
                    && id != id_b
                    && (22..=28).contains(&model.actual_lo(id, 10, &mut probe))
            })
            .expect("some id must overrun into [22, 28]");
        let expect = vec![
            model.actual_lo(id_a, 10, &mut probe),
            model.actual_lo(id_b, 10, &mut probe),
            model.actual_lo(id_c, 10, &mut probe),
        ];
        (
            vec![req(id_a, 30, 10), req(id_b, 30, 10), req(id_c, 30, 10)],
            expect,
            model,
            1,
        )
    }

    #[test]
    fn preemption_multi_member_staggered_resumes_exactly_once() {
        for swap in [false, true] {
            let (batch, expect, model, victim) = staggered_trio();
            let mut p = quiet_profile();
            p.kv_pool_mb = 72.0; // 144 tokens at 0.5 MB/token -> 9 blocks
            let pc = if swap {
                PreemptConfig::swap(8.0, 64)
            } else {
                PreemptConfig::recompute()
            };
            let mut e = SimEngine::new(p, 4, 0)
                .with_divergence(model)
                .with_preemption(pc)
                .with_step_trace();
            assert_eq!(e.kv().config().total_blocks, 9);
            // deadlines for the two non-victims only: the victim's
            // unknown deadline sorts as +∞ slack, so every exhaustion
            // suspends it and never the deadline-carrying members
            e.set_deadlines(&[
                (batch[0].id, 50_000.0),
                (batch[2].id, 20_000.0),
            ]);
            let out = e.run_batch(&batch).unwrap();
            // no starvation: every member — including the repeatedly
            // suspended one — runs to its true actual length
            assert_eq!(e.kv_truncations(), 0, "swap={swap}");
            for (i, r) in out.iter().enumerate() {
                assert_eq!(r.generated, expect[i], "swap={swap} member {i}");
            }
            let ps = e.preemption_stats();
            // staggered exhaustion: the pool runs out at least twice
            // (once with all three live, again after the short member
            // retires and the survivors grow past the freed blocks)
            assert!(ps.preemptions >= 2, "swap={swap}: {ps:?}");
            // exactly-once pairing: every suspension is matched by one
            // resume — no double-resume, no forgotten member
            let resumes = ps.recompute_resumes + ps.swap_ins;
            assert_eq!(resumes, ps.preemptions, "swap={swap}: {ps:?}");
            if swap {
                // ample host buffer: nothing degrades to recompute, and
                // the buffer drains completely
                assert_eq!(ps.recompute_resumes, 0, "{ps:?}");
                assert_eq!(ps.swap_ins, ps.swap_outs, "{ps:?}");
                assert!(e.host_blocks_peak() >= 1);
            }
            // only the designated (slackest) member was ever suspended
            let suspended: Vec<u64> = e
                .take_step_events()
                .iter()
                .flat_map(|ev| ev.suspended.iter().copied())
                .collect();
            assert!(!suspended.is_empty(), "swap={swap}");
            assert!(
                suspended.iter().all(|&id| id == batch[victim].id),
                "swap={swap}: a deadline-carrying member was suspended: \
                 {suspended:?}"
            );
            // leak-free on both the device pool and the host buffer
            assert_eq!(e.kv().active_seqs(), 0, "swap={swap}");
            assert_eq!(e.kv().free_blocks(), 9, "swap={swap}");
            // deterministic: a fresh engine replays the run bit for bit
            let mut p2 = quiet_profile();
            p2.kv_pool_mb = 72.0;
            let mut e2 = SimEngine::new(p2, 4, 0)
                .with_divergence(model)
                .with_preemption(pc);
            e2.set_deadlines(&[
                (batch[0].id, 50_000.0),
                (batch[2].id, 20_000.0),
            ]);
            let out2 = e2.run_batch(&batch).unwrap();
            for (x, y) in out.iter().zip(&out2) {
                assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
                assert_eq!(x.generated, y.generated);
            }
            assert_eq!(e2.preemption_stats(), ps);
        }
    }

    #[test]
    fn preemption_on_is_bit_identical_when_pool_never_exhausts() {
        // Ample pool: the preemptive path must replay the truncating
        // divergent path bit for bit — same RNG stream, same arithmetic.
        let batch: Vec<EngineRequest> =
            (0..4).map(|i| req(i, 200, 40)).collect();
        let mut plain = SimEngine::new(quiet_profile(), 4, 3)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.5 });
        let mut preempt = SimEngine::new(quiet_profile(), 4, 3)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.5 })
            .with_preemption(PreemptConfig::recompute());
        let a = plain.run_batch(&batch).unwrap();
        let b = preempt.run_batch(&batch).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.first_token_ms.to_bits(), y.first_token_ms.to_bits());
            assert_eq!(x.generated, y.generated);
        }
        assert_eq!(preempt.preemption_stats(), PreemptionStats::default());
        assert_eq!(plain.now_ms().to_bits(), preempt.now_ms().to_bits());
    }

    #[test]
    fn preemption_single_member_physical_limit_still_truncates() {
        // The PR 5 scenario with preemption ON: a lone context whose next
        // token exceeds the whole pool has no victim to preempt — the
        // engine must fall back to EOS-on-OOM instead of livelocking in
        // suspend/resume cycles.
        let model = DivergenceModel::QuantileTrace { sigma: 1.0 };
        let mut probe = Rng::new(0);
        let id = (0..1000u64)
            .find(|&id| model.actual_lo(id, 10, &mut probe) >= 13)
            .expect("some id must overrun");
        let mut p = quiet_profile();
        p.kv_pool_mb = 56.0;
        let mut e = SimEngine::new(p, 4, 0)
            .with_divergence(model)
            .with_preemption(PreemptConfig::recompute());
        let out = e.run_batch(&[req(id, 100, 10)]).unwrap();
        assert_eq!(e.kv_truncations(), 1);
        assert_eq!(out[0].generated, 12);
        assert_eq!(e.preemption_stats().preemptions, 0);
        assert_eq!(e.kv().active_seqs(), 0);
        assert_eq!(e.kv().free_blocks(), 7);
    }

    #[test]
    fn preempt_config_parses_and_gates() {
        assert_eq!(PreemptConfig::parse("off", 0.0, 0).unwrap(), PreemptConfig::OFF);
        assert!(!PreemptConfig::OFF.enabled());
        let r = PreemptConfig::parse("recompute", 0.0, 0).unwrap();
        assert_eq!(r.mode, PreemptMode::Recompute);
        assert!(r.enabled());
        let s = PreemptConfig::parse("swap", 16.0, 128).unwrap();
        assert_eq!(s, PreemptConfig::swap(16.0, 128));
        assert!(PreemptConfig::parse("swap", 0.0, 128).is_err());
        assert!(PreemptConfig::parse("sideways", 1.0, 0).is_err());
    }

    #[test]
    fn continuous_mode_runs_under_divergence() {
        let mut e = SimEngine::new(quiet_profile(), 4, 2)
            .with_divergence(DivergenceModel::Lognormal { sigma: 0.5 });
        let arrivals: Vec<(f64, EngineRequest)> =
            (0..8).map(|i| (50.0 * i as f64, req(i, 150, 30))).collect();
        let out = e.run_continuous(&arrivals).unwrap();
        assert_eq!(out.len(), 8);
        assert!(out.iter().any(|r| r.generated != 30));
        assert_eq!(e.kv().active_seqs(), 0);
        // divergence off replays the legacy continuous path bit for bit
        let mut a = SimEngine::new(quiet_profile(), 4, 2);
        let mut b = SimEngine::new(quiet_profile(), 4, 2)
            .with_divergence(DivergenceModel::Off);
        let ra = a.run_continuous(&arrivals).unwrap();
        let rb = b.run_continuous(&arrivals).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits());
            assert_eq!(x.generated, y.generated);
        }
    }
}
