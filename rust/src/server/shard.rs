//! Shard worker: one engine + one [`WaveController`] driven by a bounded
//! submission queue.
//!
//! The worker mirrors [`run_online_opts`]'s event loop on a live clock:
//! drain the queue, admit (or defer while the controller is saturated —
//! the KV backpressure rule), dispatch the next planned batch, execute,
//! reconcile, repeat. The engine's virtual clock is pinned to the wall
//! axis by [`Engine::advance_to`]`(now_ms())` before every admission and
//! dispatch, so wall-clock arrivals and virtual execution share one
//! timeline — exactly the unified axis the synchronous replay uses with
//! recorded arrivals.
//!
//! [`run_online_opts`]: crate::coordinator::online::run_online_opts

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use crate::coordinator::objective::Job;
use crate::coordinator::online::{OnlineOpts, OnlineStats, ReplanStrategy, WaveController};
use crate::coordinator::policies::slo_deadline_ms;
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::SaParams;
use crate::coordinator::profiler::RequestProfiler;
use crate::coordinator::request::{Completion, Request, TaskType};
use crate::coordinator::to_completion;
use crate::engine::{Engine, EngineRequest};
use crate::metrics::Histogram;
use crate::server::front::{DoorShared, StreamEvent};
use crate::util;
use crate::util::rng::Rng;

/// EWMA smoothing for the per-item drain-time estimate feeding the
/// front door's `retry_after_ms` hint.
const DRAIN_EWMA_ALPHA: f64 = 0.2;

/// One queued submission (front door → shard worker).
pub(crate) struct SubmitMsg {
    pub(crate) request: Request,
    /// Wall clock at submission (ms; the request's `arrival_ms`).
    pub(crate) submit_ms: f64,
    /// Already counted as a saturation deferral (count-once semantics).
    pub(crate) deferred: bool,
    /// Client opted into per-token events.
    pub(crate) stream: bool,
    /// Event stream back to the submitting client.
    pub(crate) events: Sender<StreamEvent>,
}

/// Mutex-guarded shard metrics (merged across shards by the door).
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Submit → admission wait (ms).
    pub admission: Histogram,
    /// Measured request e2e latency (ms).
    pub e2e: Histogram,
    /// Per task class: (task, completed, SLO-met).
    pub per_class: Vec<(TaskType, usize, usize)>,
    /// Snapshot of the controller's [`OnlineStats`] (refreshed after
    /// every batch and at worker exit).
    pub online: OnlineStats,
}

/// Lock-free counters + guarded metrics one shard exposes to the door.
#[derive(Debug, Default)]
pub struct ShardShared {
    pub admitted: AtomicU64,
    pub served: AtomicU64,
    pub met: AtomicU64,
    pub failed: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Engine preemptions so far (absolute snapshot of
    /// [`crate::engine::PreemptionStats::preemptions`], refreshed after
    /// every batch).
    pub preemptions: AtomicU64,
    /// Engine EOS-on-OOM truncations so far (absolute snapshot; stays 0
    /// whenever preemption absorbs every pool exhaustion).
    pub kv_truncations: AtomicU64,
    /// f64 bits of the per-item drain-time EWMA (ms); 0 = no measurement.
    pub drain_ewma_ms_bits: AtomicU64,
    pub metrics: Mutex<ShardMetrics>,
}

/// Immutable worker parameters (built by the front door at start).
pub(crate) struct ShardCtx {
    pub(crate) shard: usize,
    pub(crate) predictor: LatencyPredictor,
    /// `sa.seed` is already shard-resolved
    /// ([`crate::server::front::shard_seed`]).
    pub(crate) sa: SaParams,
    pub(crate) strategy: ReplanStrategy,
    pub(crate) opts: OnlineOpts,
    pub(crate) max_total_tokens: usize,
    pub(crate) stream_tokens: bool,
}

/// Slab entry for one in-flight request; the slot index doubles as the
/// controller-side `Job::req_idx`, so a `Dispatch` maps straight back.
struct Entry {
    request: Request,
    stream: bool,
    events: Sender<StreamEvent>,
    submit_ms: f64,
}

fn alloc(
    slots: &mut Vec<Option<Entry>>,
    free: &mut Vec<usize>,
    e: Entry,
) -> usize {
    match free.pop() {
        Some(i) => {
            slots[i] = Some(e);
            i
        }
        None => {
            slots.push(Some(e));
            slots.len() - 1
        }
    }
}

/// The worker thread body (module docs).
pub(crate) fn shard_loop(
    ctx: ShardCtx,
    rx: Receiver<SubmitMsg>,
    shared: Arc<ShardShared>,
    door: Arc<DoorShared>,
    mut engine: Box<dyn Engine + Send>,
) {
    // The controller borrows the predictor: declare the owned predictor
    // first so it outlives (drops after) the controller.
    let predictor = ctx.predictor;
    let mut ctl =
        WaveController::new(&predictor, ctx.sa, ctx.strategy);
    if ctx.opts.compact_dispatched {
        ctl = ctl.with_compaction();
    }
    if ctx.opts.adaptive_budget {
        ctl = ctl.with_adaptive_budget();
    }
    let mut profiler = RequestProfiler::new();
    let mut rng = Rng::new(ctx.sa.seed ^ 0x5EA2_D00E);
    // Bounded request slab: slots are freed on completion/failure, so
    // memory tracks the in-flight set, not the request history.
    let mut slots: Vec<Option<Entry>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut waiting: Vec<SubmitMsg> = Vec::new();
    let mut disconnected = false;

    loop {
        // ---- intake: saturation-deferred submissions first, then drain
        // the queue (non-blocking).
        let mut intake: Vec<SubmitMsg> = std::mem::take(&mut waiting);
        loop {
            match rx.try_recv() {
                Ok(m) => intake.push(m),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if !intake.is_empty() {
            if ctl.saturated() {
                // KV backpressure: defer admission until dispatch frees
                // planned backlog. Each arrival counts once, however
                // many retries it takes.
                let newly =
                    intake.iter().filter(|m| !m.deferred).count();
                ctl.note_deferrals(newly);
                for m in &mut intake {
                    m.deferred = true;
                }
                waiting = intake;
            } else {
                admit_intake(
                    intake, &mut ctl, &mut slots, &mut free,
                    &mut profiler, &mut rng, engine.as_mut(), &ctx,
                    &shared, &door,
                );
            }
        }

        // ---- dispatch the next planned batch (work-conserving).
        if let Some(d) = ctl.dispatch_next() {
            run_dispatch(
                d, &mut ctl, &mut slots, &mut free, &mut profiler,
                engine.as_mut(), &ctx, &shared, &door,
            );
            continue;
        }

        // ---- idle: retry deferred work, exit when told and drained,
        // else wait briefly for a submission.
        if !waiting.is_empty() {
            continue;
        }
        let stopping =
            disconnected || !door.running.load(Ordering::SeqCst);
        if stopping && ctl.drained() {
            break;
        }
        match rx.recv_timeout(std::time::Duration::from_millis(2)) {
            Ok(m) => waiting.push(m),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                disconnected = true;
            }
        }
    }
    shared.metrics.lock().unwrap().online = *ctl.stats();
}

/// Admit a non-empty intake: predict output lengths, slot the entries,
/// and replan. On admission error the whole intake fails back to its
/// clients (the controller rejects oversize-KV jobs as a unit).
#[allow(clippy::too_many_arguments)]
fn admit_intake(
    intake: Vec<SubmitMsg>,
    ctl: &mut WaveController,
    slots: &mut Vec<Option<Entry>>,
    free: &mut Vec<usize>,
    profiler: &mut RequestProfiler,
    rng: &mut Rng,
    engine: &mut dyn Engine,
    ctx: &ShardCtx,
    shared: &ShardShared,
    door: &DoorShared,
) {
    engine.advance_to(util::now_ms());
    let mut jobs: Vec<Job> = Vec::with_capacity(intake.len());
    let mut arrs: Vec<f64> = Vec::with_capacity(intake.len());
    let mut new_slots: Vec<usize> = Vec::with_capacity(intake.len());
    for m in intake {
        let predicted = profiler
            .predict_output(
                m.request.task,
                rng,
                ctx.max_total_tokens / 2,
            )
            .min(m.request.output_len.max(1));
        let slot = alloc(
            slots,
            free,
            Entry {
                request: m.request,
                stream: m.stream,
                events: m.events,
                submit_ms: m.submit_ms,
            },
        );
        let entry = slots[slot].as_ref().unwrap();
        jobs.push(Job::from_request(slot, &entry.request, predicted));
        arrs.push(m.submit_ms);
        new_slots.push(slot);
    }
    let res = if ctx.opts.arrival_aware {
        ctl.admit_at(&jobs, &arrs)
    } else {
        ctl.admit(&jobs)
    };
    match res {
        Ok(_) => {
            let now = util::now_ms();
            let mut m = shared.metrics.lock().unwrap();
            for &slot in &new_slots {
                let entry = slots[slot].as_ref().unwrap();
                m.admission.record(now - entry.submit_ms);
                let _ = entry.events.send(StreamEvent::Admitted {
                    id: entry.request.id,
                    shard: ctx.shard,
                    queue_ms: now - entry.submit_ms,
                });
            }
            shared
                .admitted
                .fetch_add(new_slots.len() as u64, Ordering::SeqCst);
        }
        Err(e) => {
            // Admission failed as a unit: fail every member back to its
            // client rather than planning a fiction.
            for &slot in &new_slots {
                let entry = slots[slot].take().unwrap();
                free.push(slot);
                let _ = entry.events.send(StreamEvent::Failed {
                    id: entry.request.id,
                    error: e.to_string(),
                });
                shared.failed.fetch_add(1, Ordering::SeqCst);
                door.inflight.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// Execute one dispatched batch: run it, relay step-trace token events
/// to streaming subscribers, complete the members, reconcile drift.
#[allow(clippy::too_many_arguments)]
fn run_dispatch(
    d: crate::coordinator::online::Dispatch,
    ctl: &mut WaveController,
    slots: &mut [Option<Entry>],
    free: &mut Vec<usize>,
    profiler: &mut RequestProfiler,
    engine: &mut dyn Engine,
    ctx: &ShardCtx,
    shared: &ShardShared,
    door: &DoorShared,
) {
    engine.advance_to(util::now_ms());
    let batch: Vec<EngineRequest> = d
        .jobs
        .iter()
        .map(|job| {
            let r = &slots[job.req_idx].as_ref().unwrap().request;
            EngineRequest {
                id: r.id,
                input_len: r.input_len,
                max_new_tokens: r.output_len,
                prompt: r.prompt.clone(),
            }
        })
        .collect();
    // Absolute deadlines so pool-exhaustion victim selection runs by SLO
    // slack (same wiring as the synchronous online path).
    let deadlines: Vec<(u64, f64)> = d
        .jobs
        .iter()
        .map(|job| {
            let e = slots[job.req_idx].as_ref().unwrap();
            (
                e.request.id,
                e.submit_ms + slo_deadline_ms(&e.request.slo),
            )
        })
        .collect();
    engine.set_deadlines(&deadlines);
    let wall_start = util::now_ms();
    match engine.run_batch(&batch) {
        Ok(items) => {
            let wall_ms = util::now_ms() - wall_start;
            // Streaming: drain the engine's step trace and fan tokens
            // out to the batch members that asked for them.
            if ctx.stream_tokens {
                let mut subs: HashMap<u64, (&Sender<StreamEvent>, usize)> =
                    HashMap::new();
                for job in &d.jobs {
                    let e = slots[job.req_idx].as_ref().unwrap();
                    if e.stream {
                        subs.insert(e.request.id, (&e.events, 0));
                    }
                }
                for step in engine.take_step_events() {
                    for id in step.emitted {
                        if let Some((tx, index)) = subs.get_mut(&id) {
                            let _ = tx.send(StreamEvent::Token {
                                id,
                                index: *index,
                                t_ms: step.t_ms,
                            });
                            *index += 1;
                        }
                    }
                }
            }
            let mut completions: Vec<Completion> =
                Vec::with_capacity(items.len());
            let mut tokens = 0u64;
            let mut met_n = 0u64;
            {
                let mut m = shared.metrics.lock().unwrap();
                for (job, item) in d.jobs.iter().zip(&items) {
                    let entry = slots[job.req_idx].take().unwrap();
                    free.push(job.req_idx);
                    profiler
                        .observe_output(entry.request.task, item.generated);
                    let c =
                        to_completion(&entry.request, item, job.output_len);
                    m.e2e.record(c.e2e_ms);
                    let met = c.slo_met();
                    match m
                        .per_class
                        .iter_mut()
                        .find(|(t, _, _)| *t == c.task)
                    {
                        Some(row) => {
                            row.1 += 1;
                            row.2 += met as usize;
                        }
                        None => {
                            m.per_class.push((c.task, 1, met as usize))
                        }
                    }
                    tokens += c.generated as u64;
                    met_n += met as u64;
                    let _ = entry.events.send(StreamEvent::Done {
                        id: c.id,
                        completion: c.clone(),
                    });
                    completions.push(c);
                }
            }
            let n = items.len() as u64;
            shared.served.fetch_add(n, Ordering::SeqCst);
            shared.met.fetch_add(met_n, Ordering::SeqCst);
            shared.tokens_out.fetch_add(tokens, Ordering::SeqCst);
            // per-item drain EWMA -> the door's retry_after hint
            if n > 0 {
                let sample = (wall_ms / n as f64).max(0.0);
                let prev = f64::from_bits(
                    shared.drain_ewma_ms_bits.load(Ordering::SeqCst),
                );
                let next = if prev > 0.0 && prev.is_finite() {
                    DRAIN_EWMA_ALPHA * sample
                        + (1.0 - DRAIN_EWMA_ALPHA) * prev
                } else {
                    sample
                };
                shared
                    .drain_ewma_ms_bits
                    .store(next.to_bits(), Ordering::SeqCst);
            }
            let ps = engine.preemption_stats();
            shared
                .preemptions
                .store(ps.preemptions as u64, Ordering::SeqCst);
            shared
                .kv_truncations
                .store(ps.kv_truncations as u64, Ordering::SeqCst);
            let drift = ctl.reconcile(&completions, engine.now_ms());
            if ctx.opts.replan_drift_ms > 0.0
                && drift.abs() >= ctx.opts.replan_drift_ms
            {
                ctl.replan_from_drift();
            }
            shared.metrics.lock().unwrap().online = *ctl.stats();
            door.inflight.fetch_sub(n, Ordering::SeqCst);
        }
        Err(e) => {
            for job in &d.jobs {
                let entry = slots[job.req_idx].take().unwrap();
                free.push(job.req_idx);
                let _ = entry.events.send(StreamEvent::Failed {
                    id: entry.request.id,
                    error: e.to_string(),
                });
                shared.failed.fetch_add(1, Ordering::SeqCst);
                door.inflight.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}
