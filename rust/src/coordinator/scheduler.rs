//! Multi-instance SLO-aware scheduling (paper §4.4, Algorithm 2).
//!
//! The scheduling solution decomposes into **instance assignment** followed
//! by **per-instance priority mapping** (run independently — the paper
//! notes the mappings are parallelizable across instances, which this
//! implementation exploits with scoped threads):
//!
//! 1. predict request latencies;
//! 2. assign requests round-robin to the instance with the largest
//!    remaining memory (token capacity via Eq. 20); when the largest
//!    remaining memory cannot host the next request, remaining memories are
//!    reset — a new "iteration" of assignments begins;
//! 3. run Algorithm 1 inside each instance — one scoped thread per
//!    instance, since the searches share nothing but the immutable
//!    predictor and their own job slices;
//! 4. enqueue each instance's priority sequence for execution.
//!
//! [`ScheduleOutcome`] reports the scheduling overhead both ways: wall
//! clock (what the parallel mapping actually costs) and CPU time (the sum
//! of per-instance mapping times — the quantity comparable to the paper's
//! Fig. 11(B), whose instances are mapped sequentially on one server).

use crate::coordinator::objective::{Evaluator, Job, Schedule};
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::{
    priority_mapping, SaParams, SaResult, SearchStats,
};
use crate::coordinator::profiler::MemoryModel;
use crate::coordinator::request::Request;

/// Static description of one LLM inference instance.
#[derive(Debug, Clone, Copy)]
pub struct InstanceInfo {
    pub id: usize,
    /// KV-cache memory pool size (MB).
    pub mem_mb: f64,
}

/// Per-instance execution plan produced by the scheduler.
#[derive(Debug, Clone)]
pub struct InstancePlan {
    pub instance: usize,
    /// Scheduler's job views (with predicted output lengths); `req_idx`
    /// points into the request slice given to [`schedule`].
    pub jobs: Vec<Job>,
    /// Priority sequence + batch partition over `jobs` (local indices).
    pub schedule: Schedule,
    pub stats: SearchStats,
}

impl InstancePlan {
    /// Request indices in execution order.
    pub fn request_order(&self) -> Vec<usize> {
        self.schedule.order.iter().map(|&j| self.jobs[j].req_idx).collect()
    }
}

/// Result of Algorithm 2 over one wave of requests.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub plans: Vec<InstancePlan>,
    /// Wall-clock scheduling overhead (ms): assignment plus the *parallel*
    /// per-instance mapping section. This is what a caller actually waits.
    pub overhead_ms: f64,
    /// CPU-time scheduling overhead (ms): assignment plus the *sum* of
    /// per-instance mapping times. Comparable to the paper's Fig. 11(B)
    /// numbers, whose instances are mapped sequentially on one server —
    /// report this, not `overhead_ms`, when reproducing that figure.
    pub cpu_ms: f64,
    /// Base RNG seed the wave was planned with (each instance searches at
    /// [`instance_seed`] of it). Recorded so a plan — and the bench JSON
    /// rows derived from it — can be reproduced exactly.
    pub seed: u64,
}

/// Per-instance search seed derived from the wave's base seed: instances
/// explore independently, and the derivation is shared with the online
/// path ([`crate::coordinator::online`]) so a single-instance online run
/// with t=0 arrivals replays the closed-wave search bit for bit.
pub fn instance_seed(base: u64, inst: usize) -> u64 {
    base.wrapping_add(inst as u64).wrapping_mul(0x9E3779B9)
}

/// Instance assignment (Algorithm 2 line 4, "Instance Assignment" ¶).
///
/// Requests are considered in arrival order; each goes to the instance with
/// the largest remaining memory. A request's footprint is its total token
/// count (input + predicted output) converted through Eq. 20. If even the
/// largest-remaining instance lacks room, all remaining memories reset
/// (a maximum-capacity wave has been packed) and assignment continues.
///
/// One largest-remaining scan per request (a second scan only after a
/// reset); `total_cmp` so NaN capacities/footprints cannot panic.
pub fn assign_instances(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    mem: &MemoryModel,
) -> Vec<Vec<usize>> {
    assert_eq!(requests.len(), predicted_out.len());
    assert!(!instances.is_empty());
    let mut remaining: Vec<f64> = instances.iter().map(|i| i.mem_mb).collect();
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); instances.len()];

    fn largest(remaining: &[f64]) -> usize {
        // NaN ranks lowest (total_cmp alone would rank +NaN above +inf and
        // silently funnel every request onto a broken instance).
        fn rank(v: f64) -> f64 {
            if v.is_nan() {
                f64::NEG_INFINITY
            } else {
                v
            }
        }
        remaining
            .iter()
            .enumerate()
            .max_by(|a, b| rank(*a.1).total_cmp(&rank(*b.1)))
            .map(|(i, _)| i)
            .unwrap()
    }

    for (ri, req) in requests.iter().enumerate() {
        let tokens = req.input_len + predicted_out[ri];
        let need_mb = mem.tokens_to_mb(tokens);
        // pick instance with the largest remaining memory
        let mut best = largest(&remaining);
        if remaining[best] < need_mb {
            // reset: a full wave has been packed (§4.4); re-scan since the
            // globally-largest instance may differ from the current one
            for (slot, inst) in remaining.iter_mut().zip(instances) {
                *slot = inst.mem_mb;
            }
            best = largest(&remaining);
        }
        remaining[best] -= need_mb;
        out[best].push(ri);
    }
    out
}

/// Algorithm 2: full SLO-aware scheduling across instances.
///
/// `predicted_out[i]` is the predicted output length for `requests[i]`
/// (from the profiler or an oracle — the Fig. 9 knob). Per-instance
/// priority mappings run on scoped threads (one per non-trivial instance);
/// plan order is deterministic (by instance index) and each instance's
/// search keeps its own derived RNG seed, so results are identical to the
/// sequential execution.
pub fn schedule(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    predictor: &LatencyPredictor,
    mem: &MemoryModel,
    sa: &SaParams,
) -> ScheduleOutcome {
    let t0 = crate::util::now_ms();
    let assignment = assign_instances(requests, predicted_out, instances, mem);
    let assign_ms = crate::util::now_ms() - t0;

    // Materialize per-instance job sets first so the mapping threads borrow
    // only immutable data.
    let job_sets: Vec<Vec<Job>> = assignment
        .iter()
        .map(|req_indices| {
            req_indices
                .iter()
                .map(|&ri| {
                    Job::from_request(ri, &requests[ri], predicted_out[ri])
                })
                .collect()
        })
        .collect();
    // Derive a per-instance seed so instances explore independently.
    let params: Vec<SaParams> = (0..job_sets.len())
        .map(|inst| SaParams { seed: instance_seed(sa.seed, inst), ..*sa })
        .collect();

    let busy = job_sets.iter().filter(|jobs| !jobs.is_empty()).count();
    let results: Vec<SaResult> = if busy <= 1 {
        // Thread spawn costs more than a trivial mapping; stay inline.
        job_sets
            .iter()
            .zip(&params)
            .map(|(jobs, p)| priority_mapping(&Evaluator::new(jobs, predictor), p))
            .collect()
    } else {
        std::thread::scope(|scope| {
            // Threads only for instances with work; empty mappings return
            // immediately and are cheaper than a spawn.
            let handles: Vec<_> = job_sets
                .iter()
                .zip(&params)
                .map(|(jobs, p)| {
                    if jobs.is_empty() {
                        None
                    } else {
                        Some(scope.spawn(move || {
                            priority_mapping(&Evaluator::new(jobs, predictor), p)
                        }))
                    }
                })
                .collect();
            handles
                .into_iter()
                .zip(job_sets.iter().zip(&params))
                .map(|(h, (jobs, p))| match h {
                    Some(h) => {
                        h.join().expect("priority-mapping thread panicked")
                    }
                    None => {
                        priority_mapping(&Evaluator::new(jobs, predictor), p)
                    }
                })
                .collect()
        })
    };

    let mapping_cpu_ms: f64 =
        results.iter().map(|r| r.stats.overhead_ms).sum();
    let plans: Vec<InstancePlan> = job_sets
        .into_iter()
        .zip(results)
        .enumerate()
        .map(|(inst, (jobs, result))| InstancePlan {
            instance: inst,
            jobs,
            schedule: result.schedule,
            stats: result.stats,
        })
        .collect();

    ScheduleOutcome {
        plans,
        overhead_ms: crate::util::now_ms() - t0,
        cpu_ms: assign_ms + mapping_cpu_ms,
        seed: sa.seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Slo, TaskType};
    use crate::util::prop::check;

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request::synthetic(
            id,
            TaskType::Code,
            input,
            output,
            Slo::E2e { e2e_ms: 30_000.0 },
        )
    }

    fn instances(n: usize, mem_mb: f64) -> Vec<InstanceInfo> {
        (0..n).map(|id| InstanceInfo { id, mem_mb }).collect()
    }

    #[test]
    fn assignment_balances_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, 100, 0)).collect();
        let outs = vec![0usize; 6];
        let asg = assign_instances(&reqs, &outs, &instances(2, 10_000.0), &mem);
        // equal-size requests alternate between equal instances
        assert_eq!(asg[0].len(), 3);
        assert_eq!(asg[1].len(), 3);
    }

    #[test]
    fn assignment_prefers_larger_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: 100.0 },
            InstanceInfo { id: 1, mem_mb: 10_000.0 },
        ];
        let asg = assign_instances(&reqs, &outs, &inst, &mem);
        // the big instance keeps winning until its remaining dips below
        assert!(asg[1].len() >= 3, "{asg:?}");
    }

    #[test]
    fn assignment_resets_when_full() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // each request needs 80 MB; instance holds 100 MB -> resets every req
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 80, 0)).collect();
        let outs = vec![0usize; 5];
        let asg = assign_instances(&reqs, &outs, &instances(1, 100.0), &mem);
        assert_eq!(asg[0].len(), 5); // all still assigned (across waves)
    }

    #[test]
    fn assignment_covers_all_requests() {
        check("assignment partitions requests", 100, |rng| {
            let n_req = 1 + rng.below(40);
            let n_inst = 1 + rng.below(4);
            let reqs: Vec<Request> = (0..n_req)
                .map(|i| {
                    req(i as u64, 1 + rng.below(2000), rng.below(500))
                })
                .collect();
            let outs: Vec<usize> =
                reqs.iter().map(|r| r.output_len).collect();
            let mem = MemoryModel::default();
            let asg = assign_instances(
                &reqs,
                &outs,
                &instances(n_inst, 16_000.0),
                &mem,
            );
            let mut seen = vec![false; n_req];
            for list in &asg {
                for &ri in list {
                    if seen[ri] {
                        return Err(format!("request {ri} assigned twice"));
                    }
                    seen[ri] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("request dropped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_survives_nan_capacity() {
        // total_cmp ordering: a NaN pool must not panic the scheduler.
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: f64::NAN },
            InstanceInfo { id: 1, mem_mb: 1_000.0 },
        ];
        let asg = assign_instances(&reqs, &outs, &inst, &mem);
        assert_eq!(asg.iter().map(Vec::len).sum::<usize>(), 4);
        // and the broken instance must not absorb the wave
        assert_eq!(asg[1].len(), 4, "{asg:?}");
    }

    #[test]
    fn schedule_produces_valid_plans() {
        let reqs: Vec<Request> = (0..12)
            .map(|i| req(i, 100 + 50 * i as usize, 20 + 10 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(3, 16_000.0),
            &predictor,
            &mem,
            &sa,
        );
        assert_eq!(outcome.plans.len(), 3);
        let mut all: Vec<usize> = Vec::new();
        for plan in &outcome.plans {
            plan.schedule.validate(4).unwrap();
            assert_eq!(plan.schedule.len(), plan.jobs.len());
            all.extend(plan.request_order());
        }
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert!(outcome.overhead_ms >= 0.0);
        assert!(outcome.cpu_ms >= 0.0);
        assert_eq!(outcome.seed, sa.seed); // reproducibility record
        // cpu time covers every instance's mapping; each one individually
        // can never exceed the total
        for plan in &outcome.plans {
            assert!(plan.stats.overhead_ms <= outcome.cpu_ms + 1e-9);
        }
    }

    #[test]
    fn parallel_mapping_is_deterministic() {
        let reqs: Vec<Request> = (0..16)
            .map(|i| req(i, 100 + 37 * i as usize, 10 + 9 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let a = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa);
        let b = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa);
        assert_eq!(a.plans.len(), b.plans.len());
        for (pa, pb) in a.plans.iter().zip(&b.plans) {
            assert_eq!(pa.instance, pb.instance);
            assert_eq!(pa.schedule, pb.schedule);
        }
    }

    #[test]
    fn single_instance_gets_everything() {
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 100, 10)).collect();
        let outs = vec![10usize; 5];
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(1, 16_000.0),
            &LatencyPredictor::paper_table2(),
            &MemoryModel::default(),
            &SaParams::with_max_batch(2),
        );
        assert_eq!(outcome.plans[0].jobs.len(), 5);
    }
}
