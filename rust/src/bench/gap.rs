//! Optimality-gap matrix: SA and baseline policies vs branch-and-bound
//! certificates ([`crate::coordinator::gap`]).
//!
//! One *cell* of the matrix is a closed scheduling wave drawn from
//! {N, SLO class mix, divergence σ, KV mode, KV phase model} × seed. For
//! each cell the runner:
//!
//! 1. runs branch-and-bound to get the exact optimum or a certified
//!    upper bound `bound_g` (hard KV constrains the search; soft and
//!    unlimited modes certify against the KV-relaxed space);
//! 2. runs SA (best of `sa_restarts` seeds at `sa_iters_per_temp`, the
//!    golden-test configuration) through the same `Evaluator`/KV
//!    machinery;
//! 3. runs every cheap baseline (`fcfs`/`sjf`/`edf`/`mlfq`/
//!    `slack-index`/`edf-threshold`);
//! 4. emits a row of certified gaps (`(bound − g)/bound`, clamped at 0)
//!    and wall-clock, flagging any regime where an index/threshold
//!    policy beats the search (`index_beats_sa`) — the signal a future
//!    policy router would switch on.
//!
//! The divergence σ axis enters through the **KV quantile reservation**
//! column: footprints are charged at `lo_mult = exp(σ·Φ⁻¹(0.9))`
//! ([`quantile_multiplier`]) while the latency objective keeps pricing
//! the mean — so σ moves the Hard/Soft rows (tighter effective pools)
//! and leaves Unlimited rows unchanged, mirroring how divergence reaches
//! the planner in the serving path.
//!
//! `gated` marks rows where SA and the bound optimize the same problem
//! (Unlimited and Hard modes); Soft rows trade raw `G` for an excess
//! penalty, so their gap against the relaxed bound is diagnostic only
//! and CI's ≤ 5 % SA-gap gate skips them.

use crate::coordinator::gap::{branch_and_bound, certified_gap, BnbParams};
use crate::coordinator::kv::{KvConfig, KvPhaseModel};
use crate::coordinator::objective::{Evaluator, Job, Schedule};
use crate::coordinator::policies::Policy;
use crate::coordinator::predictor::{quantile_multiplier, LatencyPredictor};
use crate::coordinator::priority::annealing::{priority_mapping, SaParams};
use crate::coordinator::request::Slo;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// SLO class composition of a generated wave.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloMix {
    /// Every job carries an e2e-latency SLO (batch-style traffic).
    E2eOnly,
    /// Every job carries a TTFT+TPOT SLO (interactive traffic).
    InteractiveOnly,
    /// 50/50 split per job (the SLOs-Serve multi-SLO fixture).
    Mixed,
}

impl SloMix {
    pub fn name(&self) -> &'static str {
        match self {
            SloMix::E2eOnly => "e2e",
            SloMix::InteractiveOnly => "interactive",
            SloMix::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<SloMix> {
        match s {
            "e2e" => Some(SloMix::E2eOnly),
            "interactive" => Some(SloMix::InteractiveOnly),
            "mixed" => Some(SloMix::Mixed),
            _ => None,
        }
    }
}

/// KV enforcement axis of the matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GapKv {
    Unlimited,
    Hard,
    /// Soft penalty at the given weight.
    Soft(f64),
}

impl GapKv {
    pub fn name(&self) -> &'static str {
        match self {
            GapKv::Unlimited => "unlimited",
            GapKv::Hard => "hard",
            GapKv::Soft(_) => "soft",
        }
    }
}

/// Matrix configuration (axes × search budgets).
#[derive(Debug, Clone)]
pub struct GapConfig {
    pub ns: Vec<usize>,
    pub seeds: Vec<u64>,
    pub mixes: Vec<SloMix>,
    pub sigmas: Vec<f64>,
    pub kvs: Vec<(GapKv, KvPhaseModel)>,
    pub max_batch: usize,
    pub node_budget: usize,
    /// SA restarts per cell (best result kept — the golden-test rule).
    pub sa_restarts: u64,
    pub sa_iters_per_temp: usize,
}

impl Default for GapConfig {
    fn default() -> Self {
        GapConfig {
            ns: vec![6, 9, 12],
            seeds: vec![1, 2, 3],
            mixes: vec![SloMix::E2eOnly, SloMix::InteractiveOnly, SloMix::Mixed],
            sigmas: vec![0.0, 0.5],
            kvs: vec![
                (GapKv::Unlimited, KvPhaseModel::Reserve),
                (GapKv::Hard, KvPhaseModel::Reserve),
                (GapKv::Hard, KvPhaseModel::Phased),
                (GapKv::Soft(1.0), KvPhaseModel::Reserve),
            ],
            max_batch: 4,
            node_budget: 400_000,
            sa_restarts: 3,
            sa_iters_per_temp: 400,
        }
    }
}

impl GapConfig {
    /// Environment-variable overrides for CI-sized runs:
    /// `GAP_NS` (comma list), `GAP_SEEDS` (count), `GAP_NODE_BUDGET`,
    /// `GAP_MAX_BATCH`, `GAP_SIGMAS` (comma list). Unset keeps defaults.
    pub fn from_env() -> GapConfig {
        let mut cfg = GapConfig::default();
        if let Ok(v) = std::env::var("GAP_NS") {
            let ns: Vec<usize> =
                v.split(',').filter_map(|t| t.trim().parse().ok()).collect();
            if !ns.is_empty() {
                cfg.ns = ns;
            }
        }
        if let Ok(v) = std::env::var("GAP_SEEDS") {
            if let Ok(k) = v.trim().parse::<u64>() {
                if k > 0 {
                    cfg.seeds = (1..=k).collect();
                }
            }
        }
        if let Ok(v) = std::env::var("GAP_NODE_BUDGET") {
            if let Ok(b) = v.trim().parse::<usize>() {
                cfg.node_budget = b;
            }
        }
        if let Ok(v) = std::env::var("GAP_MAX_BATCH") {
            if let Ok(b) = v.trim().parse::<usize>() {
                if b > 0 {
                    cfg.max_batch = b;
                }
            }
        }
        if let Ok(v) = std::env::var("GAP_SIGMAS") {
            let ss: Vec<f64> =
                v.split(',').filter_map(|t| t.trim().parse().ok()).collect();
            if !ss.is_empty() {
                cfg.sigmas = ss;
            }
        }
        cfg
    }
}

/// One policy's outcome inside a cell.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub name: &'static str,
    pub g: f64,
    /// Certified gap vs the cell's bound (`max(0, (bound − g)/bound)`).
    pub gap: f64,
    pub wall_ms: f64,
}

/// One row of `BENCH_gap.json`.
#[derive(Debug, Clone)]
pub struct GapRow {
    pub n: usize,
    pub seed: u64,
    pub mix: SloMix,
    pub sigma: f64,
    pub kv: GapKv,
    pub phase: KvPhaseModel,
    pub max_batch: usize,
    /// Certified upper bound on the optimal G for this cell's problem.
    pub bound_g: f64,
    /// Whether branch-and-bound closed the instance (bound == optimum).
    pub closed: bool,
    pub nodes: usize,
    pub bnb_wall_ms: f64,
    pub sa: PolicyOutcome,
    pub baselines: Vec<PolicyOutcome>,
    /// A cheap index/threshold policy matched or beat the SA result —
    /// the regime a policy router would hand to the index policy.
    pub index_beats_sa: bool,
    /// SA and the bound optimize the same problem (Unlimited/Hard); the
    /// CI SA-gap gate only applies to these rows.
    pub gated: bool,
}

/// Generate one closed wave of `n` jobs for the given SLO mix (the
/// scheduler-invariants generator, parameterized by class).
pub fn gen_jobs(rng: &mut Rng, n: usize, mix: SloMix) -> Vec<Job> {
    (0..n)
        .map(|i| {
            let interactive = match mix {
                SloMix::E2eOnly => false,
                SloMix::InteractiveOnly => true,
                SloMix::Mixed => rng.chance(0.5),
            };
            Job {
                req_idx: i,
                input_len: 1 + rng.below(1500),
                output_len: 1 + rng.below(400),
                slo: if interactive {
                    Slo::Interactive {
                        ttft_ms: rng.uniform(500.0, 15_000.0),
                        tpot_ms: rng.uniform(15.0, 60.0),
                    }
                } else {
                    Slo::E2e { e2e_ms: rng.uniform(1_000.0, 60_000.0) }
                },
            }
        })
        .collect()
}

/// Build the cell's [`KvConfig`]: footprints charged at the σ-derived
/// 0.9-quantile multiplier, and for binding modes a pool sized to ~75 %
/// of the average FCFS batch demand (binding for packed batches) with a
/// fits-alone floor (so the constrained problem stays feasible).
pub fn kv_for(
    jobs: &[Job],
    kv: GapKv,
    phase: KvPhaseModel,
    sigma: f64,
    max_batch: usize,
) -> KvConfig {
    let lo_mult = quantile_multiplier(sigma, 0.9);
    match kv {
        GapKv::Unlimited => KvConfig::UNLIMITED.with_lo_mult(lo_mult),
        GapKv::Hard | GapKv::Soft(_) => {
            let probe = KvConfig::hard(u64::MAX).with_lo_mult(lo_mult);
            let blocks: Vec<u64> = jobs
                .iter()
                .map(|j| probe.job_blocks(j.input_len, j.output_len))
                .collect();
            let total: u64 = blocks.iter().sum();
            let max_single = blocks.iter().copied().max().unwrap_or(1);
            let num_batches = jobs.len().div_ceil(max_batch.max(1)) as u64;
            let pool =
                ((total * 3) / (4 * num_batches.max(1))).max(max_single);
            let cfg = match kv {
                GapKv::Hard => KvConfig::hard(pool),
                GapKv::Soft(w) => KvConfig::soft(pool, w),
                GapKv::Unlimited => unreachable!(),
            };
            cfg.with_phase(phase).with_lo_mult(lo_mult)
        }
    }
}

/// Run one cell: B&B certificate, best-of-restarts SA, every baseline.
pub fn run_cell(
    jobs: &[Job],
    predictor: &LatencyPredictor,
    cfg: &GapConfig,
    seed: u64,
    mix: SloMix,
    sigma: f64,
    kv: GapKv,
    phase: KvPhaseModel,
) -> GapRow {
    let ev = Evaluator::new(jobs, predictor);
    let kv_cfg = kv_for(jobs, kv, phase, sigma, cfg.max_batch);

    let bnb = branch_and_bound(
        &ev,
        &BnbParams {
            max_batch: cfg.max_batch,
            node_budget: cfg.node_budget,
            kv: kv_cfg,
        },
    );

    // SA: best of `sa_restarts` derived seeds (the golden-test rule),
    // raw G of the returned schedule.
    let t_sa = crate::util::now_ms();
    let mut sa_best: Option<(Schedule, f64)> = None;
    for r in 0..cfg.sa_restarts.max(1) {
        let params = SaParams {
            max_batch: cfg.max_batch,
            seed: seed ^ (0x5A ^ r).wrapping_mul(0x9E37_79B9),
            iters_per_temp: cfg.sa_iters_per_temp,
            kv: kv_cfg,
            ..Default::default()
        };
        let res = priority_mapping(&ev, &params);
        let g = ev.eval(&res.schedule).g;
        let better = match &sa_best {
            None => true,
            Some((_, bg)) => g > *bg,
        };
        if better {
            sa_best = Some((res.schedule, g));
        }
    }
    let sa_wall = crate::util::now_ms() - t_sa;
    let (_, sa_g) = sa_best.expect("at least one SA restart");
    let sa = PolicyOutcome {
        name: "slo-aware-sa",
        g: sa_g,
        gap: certified_gap(sa_g, bnb.bound_g),
        wall_ms: sa_wall,
    };

    let baseline_policies = [
        Policy::Fcfs,
        Policy::Sjf,
        Policy::Edf,
        Policy::Mlfq,
        Policy::SlackIndex,
        Policy::EdfThreshold,
    ];
    let mut baselines = Vec::with_capacity(baseline_policies.len());
    for p in baseline_policies {
        let t0 = crate::util::now_ms();
        let (s, _) = p.plan(&ev, cfg.max_batch);
        let wall = crate::util::now_ms() - t0;
        let g = ev.eval(&s).g;
        baselines.push(PolicyOutcome {
            name: p.name(),
            g,
            gap: certified_gap(g, bnb.bound_g),
            wall_ms: wall,
        });
    }
    let index_beats_sa = baselines
        .iter()
        .filter(|b| b.name == "slack-index" || b.name == "edf-threshold")
        .any(|b| b.g >= sa.g);

    GapRow {
        n: jobs.len(),
        seed,
        mix,
        sigma,
        kv,
        phase,
        max_batch: cfg.max_batch,
        bound_g: bnb.bound_g,
        closed: bnb.closed,
        nodes: bnb.nodes,
        bnb_wall_ms: bnb.overhead_ms,
        sa,
        baselines,
        index_beats_sa,
        gated: !matches!(kv, GapKv::Soft(_)),
    }
}

/// Sweep the full matrix. Jobs for a cell depend only on
/// `(seed, n, mix)`, so every KV/σ variant scores the identical wave.
pub fn run_matrix(cfg: &GapConfig) -> Vec<GapRow> {
    let predictor = LatencyPredictor::paper_table2();
    let mut rows = Vec::new();
    for &n in &cfg.ns {
        for &seed in &cfg.seeds {
            for &mix in &cfg.mixes {
                let mut rng = Rng::new(
                    seed ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                let jobs = gen_jobs(&mut rng, n, mix);
                for &sigma in &cfg.sigmas {
                    for &(kv, phase) in &cfg.kvs {
                        rows.push(run_cell(
                            &jobs, &predictor, cfg, seed, mix, sigma, kv,
                            phase,
                        ));
                    }
                }
            }
        }
    }
    rows
}

/// Matrix-level aggregates (the numbers CI gates on).
#[derive(Debug, Clone, Copy)]
pub struct GapSummary {
    pub cells: usize,
    /// Cells branch-and-bound closed exactly.
    pub closed: usize,
    /// Worst SA gap over rows where SA and the bound optimize the same
    /// problem (CI gates this at ≤ 5 %).
    pub max_gated_sa_gap: f64,
    /// Cells where an index/threshold policy matched or beat SA.
    pub index_beats_sa_cells: usize,
}

pub fn summarize(rows: &[GapRow]) -> GapSummary {
    let mut s = GapSummary {
        cells: rows.len(),
        closed: 0,
        max_gated_sa_gap: 0.0,
        index_beats_sa_cells: 0,
    };
    for r in rows {
        s.closed += r.closed as usize;
        s.index_beats_sa_cells += r.index_beats_sa as usize;
        if r.gated && r.sa.gap > s.max_gated_sa_gap {
            s.max_gated_sa_gap = r.sa.gap;
        }
    }
    s
}

/// Human-readable matrix table (one line per cell).
pub fn render_table(rows: &[GapRow]) -> String {
    let mut t = crate::metrics::Table::new(&[
        "n", "seed", "mix", "sigma", "kv", "phase", "bound G", "closed",
        "SA gap", "best baseline", "bl gap", "idx>=SA",
    ]);
    for r in rows {
        let best_bl = r
            .baselines
            .iter()
            .max_by(|a, b| a.g.total_cmp(&b.g))
            .expect("baselines non-empty");
        t.row(vec![
            r.n.to_string(),
            r.seed.to_string(),
            r.mix.name().to_string(),
            format!("{:.1}", r.sigma),
            r.kv.name().to_string(),
            format!("{:?}", r.phase).to_lowercase(),
            format!("{:.4e}", r.bound_g),
            if r.closed { "yes" } else { "no" }.to_string(),
            format!("{:.2}%", 100.0 * r.sa.gap),
            best_bl.name.to_string(),
            format!("{:.2}%", 100.0 * best_bl.gap),
            if r.index_beats_sa { "YES" } else { "-" }.to_string(),
        ]);
    }
    t.render()
}

/// The full `BENCH_gap.json` document: config echo + rows + summary.
pub fn report_json(cfg: &GapConfig, rows: &[GapRow]) -> Json {
    let s = summarize(rows);
    Json::obj(vec![
        ("bench", Json::str("gap_matrix")),
        ("max_batch", Json::num(cfg.max_batch as f64)),
        ("node_budget", Json::num(cfg.node_budget as f64)),
        ("sa_restarts", Json::num(cfg.sa_restarts as f64)),
        ("sa_iters_per_temp", Json::num(cfg.sa_iters_per_temp as f64)),
        ("rows", rows_json(rows)),
        (
            "summary",
            Json::obj(vec![
                ("cells", Json::num(s.cells as f64)),
                ("closed", Json::num(s.closed as f64)),
                ("max_gated_sa_gap", Json::num(s.max_gated_sa_gap)),
                (
                    "index_beats_sa_cells",
                    Json::num(s.index_beats_sa_cells as f64),
                ),
            ]),
        ),
    ])
}

fn outcome_json(o: &PolicyOutcome) -> Json {
    Json::obj(vec![
        ("name", Json::str(o.name)),
        ("g", Json::num(o.g)),
        ("gap", Json::num(o.gap)),
        ("wall_ms", Json::num(o.wall_ms)),
    ])
}

/// Serialize rows for `BENCH_gap.json`.
pub fn rows_json(rows: &[GapRow]) -> Json {
    Json::arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("n", Json::num(r.n as f64)),
                    ("seed", Json::num(r.seed as f64)),
                    ("mix", Json::str(r.mix.name())),
                    ("sigma", Json::num(r.sigma)),
                    ("kv", Json::str(r.kv.name())),
                    (
                        "kv_phase",
                        Json::str(match r.phase {
                            KvPhaseModel::Reserve => "reserve",
                            KvPhaseModel::Phased => "phased",
                        }),
                    ),
                    ("max_batch", Json::num(r.max_batch as f64)),
                    ("bound_g", Json::num(r.bound_g)),
                    ("closed", Json::Bool(r.closed)),
                    ("nodes", Json::num(r.nodes as f64)),
                    ("bnb_wall_ms", Json::num(r.bnb_wall_ms)),
                    ("sa_g", Json::num(r.sa.g)),
                    ("sa_gap", Json::num(r.sa.gap)),
                    ("sa_wall_ms", Json::num(r.sa.wall_ms)),
                    (
                        "baselines",
                        Json::arr(
                            r.baselines.iter().map(outcome_json).collect(),
                        ),
                    ),
                    ("index_beats_sa", Json::Bool(r.index_beats_sa)),
                    ("gated", Json::Bool(r.gated)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_names_roundtrip() {
        for mix in [SloMix::E2eOnly, SloMix::InteractiveOnly, SloMix::Mixed] {
            assert_eq!(SloMix::parse(mix.name()), Some(mix));
        }
        assert_eq!(SloMix::parse("nope"), None);
    }

    #[test]
    fn kv_for_pool_is_feasible_and_sigma_tightens() {
        let mut rng = Rng::new(7);
        let jobs = gen_jobs(&mut rng, 10, SloMix::Mixed);
        let flat = kv_for(&jobs, GapKv::Hard, KvPhaseModel::Reserve, 0.0, 4);
        assert!(flat.binding());
        // every job fits alone (the B&B filter precondition)
        for j in &jobs {
            assert!(flat.fits_alone(flat.job_blocks(j.input_len, j.output_len)));
        }
        // σ > 0 reserves at the 0.9 quantile: strictly larger footprints
        let tight = kv_for(&jobs, GapKv::Hard, KvPhaseModel::Reserve, 0.5, 4);
        assert!(tight.lo_mult > flat.lo_mult);
        let unlimited =
            kv_for(&jobs, GapKv::Unlimited, KvPhaseModel::Reserve, 0.0, 4);
        assert!(!unlimited.binding());
    }

    #[test]
    fn single_cell_produces_consistent_row() {
        let mut rng = Rng::new(3);
        let jobs = gen_jobs(&mut rng, 6, SloMix::Mixed);
        let pred = LatencyPredictor::paper_table2();
        let cfg = GapConfig {
            ns: vec![6],
            seeds: vec![3],
            sa_restarts: 2,
            sa_iters_per_temp: 100,
            node_budget: 200_000,
            ..Default::default()
        };
        let row = run_cell(
            &jobs,
            &pred,
            &cfg,
            3,
            SloMix::Mixed,
            0.0,
            GapKv::Unlimited,
            KvPhaseModel::Reserve,
        );
        assert!(row.closed, "n=6 must close");
        assert!(row.bound_g > 0.0);
        // certified bound dominates every reported policy
        assert!(row.sa.g <= row.bound_g + 1e-15);
        for b in &row.baselines {
            assert!(b.g <= row.bound_g + 1e-15, "{} beat the bound", b.name);
            assert!(b.gap >= 0.0);
        }
        assert!(row.gated);
        assert_eq!(row.baselines.len(), 6);
    }
}
