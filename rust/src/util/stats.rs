//! Statistics + small linear algebra substrate.
//!
//! Provides the summary statistics used by the metrics module and the
//! least-squares solver behind the paper's latency predictor (Eqs. 14–15 are
//! multiple linear regressions with an interaction term — a 4-coefficient
//! normal-equations solve).

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from a sample (unsorted). Returns None for empty input.
    pub fn from(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        Some(Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Percentile over a pre-sorted slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a sample (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Standard-normal quantile function Φ⁻¹(p) (the probit), Acklam's
/// rational approximation (|relative error| < 1.15e-9 on (0, 1)).
/// Out-of-range `p` saturates: 0 → −∞, 1 → +∞, NaN → NaN — callers that
/// must stay finite clamp `p` first.
pub fn normal_quantile(p: f64) -> f64 {
    if p.is_nan() {
        return f64::NAN;
    }
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    // Coefficients of Acklam's approximation.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Solve the linear system `A x = b` in place (Gaussian elimination with
/// partial pivoting). `a` is row-major `n × n`. Returns None if singular.
pub fn solve_linear(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // pivot
        let mut best = col;
        for row in (col + 1)..n {
            if a[row * n + col].abs() > a[best * n + col].abs() {
                best = row;
            }
        }
        if a[best * n + col].abs() < 1e-12 {
            return None;
        }
        if best != col {
            for k in 0..n {
                a.swap(col * n + k, best * n + k);
            }
            b.swap(col, best);
        }
        // eliminate
        let pivot = a[col * n + col];
        for row in (col + 1)..n {
            let factor = a[row * n + col] / pivot;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row * n + k] -= factor * a[col * n + k];
            }
            b[row] -= factor * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in (row + 1)..n {
            acc -= a[row * n + k] * x[k];
        }
        x[row] = acc / a[row * n + row];
    }
    Some(x)
}

/// Ordinary least squares: find `beta` minimizing `‖X beta − y‖²`.
///
/// `rows` are feature vectors (each length `k`); solves the normal equations
/// `XᵀX beta = Xᵀy`. Returns None if the design matrix is rank-deficient.
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(rows.len(), y.len());
    if rows.is_empty() {
        return None;
    }
    let k = rows[0].len();
    let mut xtx = vec![0.0; k * k];
    let mut xty = vec![0.0; k];
    for (row, &target) in rows.iter().zip(y) {
        assert_eq!(row.len(), k, "inconsistent feature width");
        for i in 0..k {
            xty[i] += row[i] * target;
            for j in 0..k {
                xtx[i * k + j] += row[i] * row[j];
            }
        }
    }
    solve_linear(&mut xtx, &mut xty, k)
}

/// Coefficient of determination for a fitted model.
pub fn r_squared(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    let m = mean(actual);
    let ss_res: f64 = predicted
        .iter()
        .zip(actual)
        .map(|(p, a)| (a - p).powi(2))
        .sum();
    let ss_tot: f64 = actual.iter().map(|a| (a - m).powi(2)).sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn normal_quantile_matches_known_values() {
        // Φ⁻¹ at tabulated points (to the approximation's accuracy).
        assert!(normal_quantile(0.5).abs() < 1e-9);
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.025) + 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.9) - 1.281552).abs() < 1e-4);
        assert!((normal_quantile(0.0013498980316301) + 3.0).abs() < 1e-4);
        // symmetry: Φ⁻¹(p) = −Φ⁻¹(1−p)
        for &p in &[0.01f64, 0.1, 0.3, 0.42] {
            assert!(
                (normal_quantile(p) + normal_quantile(1.0 - p)).abs() < 1e-6,
                "asymmetric at {p}"
            );
        }
        // saturation + NaN propagation
        assert_eq!(normal_quantile(0.0), f64::NEG_INFINITY);
        assert_eq!(normal_quantile(1.0), f64::INFINITY);
        assert!(normal_quantile(f64::NAN).is_nan());
    }

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(Summary::from(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
        assert_eq!(percentile_sorted(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn solve_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![3.0, 4.0];
        let x = solve_linear(&mut a, &mut b, 2).unwrap();
        assert_eq!(x, vec![3.0, 4.0]);
    }

    #[test]
    fn solve_requires_pivoting() {
        // first pivot is zero — needs row swap
        let mut a = vec![0.0, 1.0, 1.0, 0.0];
        let mut b = vec![2.0, 5.0];
        let x = solve_linear(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_returns_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve_linear(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn least_squares_recovers_exact_model() {
        // y = 2*x0 + 3*x1 - 1 (paper Eq.14 form: interaction + linears + const)
        let mut rng = Rng::new(0);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..50 {
            let x0 = rng.uniform(0.0, 10.0);
            let x1 = rng.uniform(0.0, 10.0);
            rows.push(vec![x0, x1, 1.0]);
            ys.push(2.0 * x0 + 3.0 * x1 - 1.0);
        }
        let beta = least_squares(&rows, &ys).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-8);
        assert!((beta[1] - 3.0).abs() < 1e-8);
        assert!((beta[2] + 1.0).abs() < 1e-8);
    }

    #[test]
    fn least_squares_with_noise_is_close() {
        let mut rng = Rng::new(1);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..2000 {
            let b = rng.uniform(1.0, 32.0);
            let l = rng.uniform(100.0, 2000.0);
            rows.push(vec![b * l, b, l, 1.0]);
            let y = 0.1 * b * l + 5.7 * b + 0.01 * l + 43.67
                + rng.gaussian(0.0, 1.0);
            ys.push(y);
        }
        let beta = least_squares(&rows, &ys).unwrap();
        assert!((beta[0] - 0.1).abs() < 1e-3, "alpha {}", beta[0]);
        assert!((beta[1] - 5.7).abs() < 0.2, "beta {}", beta[1]);
    }

    #[test]
    fn r_squared_perfect_and_flat() {
        assert!((r_squared(&[1.0, 2.0], &[1.0, 2.0]) - 1.0).abs() < 1e-12);
        assert_eq!(r_squared(&[5.0, 5.0], &[5.0, 5.0]), 1.0);
    }
}
