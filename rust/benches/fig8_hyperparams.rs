//! Paper Fig. 8: ΔG vs simulated-annealing hyperparameters (T₀, iter) for
//! (A) 10 requests bs 1, (B) 20 requests bs 2, (C) 40 requests bs 4.
//!
//! ΔG is the improvement of SA over the FCFS baseline G, averaged over
//! seeds. Paper shape: raising T₀ helps more than raising iter; both
//! saturate.

use slo_serve::bench::run_scenario;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::metrics::Table;

fn cfg(policy: &str, n: usize, bs: usize, seed: u64) -> RunConfig {
    RunConfig {
        policy: policy.into(),
        n_requests: n,
        max_batch: bs,
        seed,
        output_pred: OutputPrediction::Oracle { rel_err: 0.05 },
        slos: SloTargets::default().scaled(0.4),
        ..Default::default()
    }
}

fn delta_g(n: usize, bs: usize, t0: f64, iter: usize, seeds: &[u64]) -> f64 {
    let mut sa_g = 0.0;
    let mut fcfs_g = 0.0;
    for &seed in seeds {
        let mut c = cfg("slo-aware-sa", n, bs, seed);
        c.sa.t0 = t0;
        c.sa.iters_per_temp = iter;
        sa_g += run_scenario(&c).unwrap().metrics.g_req_per_s;
        fcfs_g += run_scenario(&cfg("fcfs", n, bs, seed))
            .unwrap()
            .metrics
            .g_req_per_s;
    }
    (sa_g / fcfs_g - 1.0) * 100.0
}

fn main() {
    println!("== Fig. 8: ΔG (%) vs initial temperature T₀ and iters-per-temp ==\n");
    let seeds: Vec<u64> = (0..3).collect();
    let panels = [(10usize, 1usize, "A"), (20, 2, "B"), (40, 4, "C")];
    for (n, bs, label) in panels {
        println!("-- Fig. 8({label}): {n} requests, max batch {bs}");
        let mut t = Table::new(&["T0 \\ iter", "50", "100", "200"]);
        for &t0 in &[100.0f64, 200.0, 500.0] {
            let mut row = vec![format!("{t0}")];
            for &iter in &[50usize, 100, 200] {
                row.push(format!("{:+.1}%", delta_g(n, bs, t0, iter, &seeds)));
            }
            t.row(row);
        }
        print!("{}", t.render());
        println!();
    }
    println!("paper shape: ΔG grows with T₀ (more escapes from local optima) more");
    println!("than with iter; e.g. Fig. 8(A): 45.6%→49.8% raising T₀ 100→200 vs");
    println!("45.6%→47.2% doubling iter.");
}
