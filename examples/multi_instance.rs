//! Multi-instance scheduling (paper §4.4 / Fig. 11): four simulated
//! engines behind Algorithm 2's round-robin max-remaining-memory
//! assignment, with per-instance SA priority mapping, executed on
//! concurrent instance worker threads.
//!
//!     cargo run --release --example multi_instance

use slo_serve::bench::{fit_predictor_from_profile, warm_output_profiler};
use slo_serve::config::profiles::by_name;
use slo_serve::config::{OutputPrediction, SloTargets};
use slo_serve::coordinator::predict_outputs;
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::scheduler::{schedule, InstanceInfo};
use slo_serve::engine::instance::InstanceHandle;
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::EngineRequest;
use slo_serve::metrics::{fmt, RunMetrics, Table};
use slo_serve::util::rng::Rng;
use slo_serve::workload::dataset::RequestFactory;

fn main() -> anyhow::Result<()> {
    const INSTANCES: usize = 4;
    const REQUESTS: usize = 40;
    const MAX_BATCH: usize = 2;

    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let slos = SloTargets::default().scaled(0.4);
    let mut factory = RequestFactory::new(7, slos);
    let requests = factory.mixed_wave(REQUESTS);

    // Fit predictor from profiling; warm the output-length models.
    let predictor = fit_predictor_from_profile(&profile, 7);
    let profiler = warm_output_profiler(7, 200);
    let mut rng = Rng::new(7);
    let predicted = predict_outputs(
        &requests, &profiler,
        OutputPrediction::Profiler, &mut rng,
        profile.max_total_tokens / 2,
    );

    // Algorithm 2: assign + per-instance priority mapping.
    let infos: Vec<InstanceInfo> = (0..INSTANCES)
        .map(|id| InstanceInfo { id, mem_mb: profile.kv_pool_mb })
        .collect();
    let outcome = schedule(
        &requests, &predicted, &infos, &predictor,
        &profile.mem, &SaParams::with_max_batch(MAX_BATCH),
    )?;
    println!(
        "scheduling overhead across {INSTANCES} instances: {:.3} ms wall \
         (parallel mapping), {:.3} ms cpu (Σ per-instance, the paper's \
         sequential-mapping cost)",
        outcome.overhead_ms, outcome.cpu_ms,
    );

    // Execute concurrently: one worker thread per instance.
    let handles: Vec<InstanceHandle> = (0..INSTANCES)
        .map(|i| InstanceHandle::spawn(
            i,
            Box::new(SimEngine::new(profile.clone(), MAX_BATCH, i as u64)),
        ))
        .collect();
    let mut tickets = Vec::new();
    for plan in &outcome.plans {
        for (_, start, size) in plan.schedule.batch_spans() {
            let batch: Vec<EngineRequest> = plan.schedule.order
                [start..start + size]
                .iter()
                .map(|&j| {
                    let r = &requests[plan.jobs[j].req_idx];
                    EngineRequest {
                        id: r.id,
                        input_len: r.input_len,
                        max_new_tokens: r.output_len,
                        prompt: None,
                    }
                })
                .collect();
            tickets.push((plan.instance, handles[plan.instance].submit(batch)));
        }
    }
    let mut completions = Vec::new();
    let by_id: std::collections::HashMap<u64, _> =
        requests.iter().map(|r| (r.id, r)).collect();
    for (_, ticket) in tickets {
        for item in ticket.wait()? {
            let r = by_id[&item.id];
            completions.push(slo_serve::coordinator::request::Completion {
                id: r.id,
                task: r.task,
                slo: r.slo,
                input_len: r.input_len,
                predicted_lo: r.output_len,
                generated: item.generated,
                e2e_ms: item.finish_ms,
                ttft_ms: item.first_token_ms,
                tpot_ms: item.tpot_ms(),
                wait_ms: item.start_ms,
                batch_size: item.batch_size,
                text: None,
            });
        }
    }
    let m = RunMetrics::from_completions(&completions);
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["instances".into(), INSTANCES.to_string()]);
    t.row(vec!["requests".into(), m.n.to_string()]);
    t.row(vec!["attainment".into(), format!("{:.0}%", m.attainment() * 100.0)]);
    t.row(vec!["avg latency (ms)".into(), fmt(m.avg_latency_ms())]);
    t.row(vec!["G (req/s)".into(), fmt(m.g_req_per_s)]);
    print!("{}", t.render());
    println!("multi_instance OK");
    Ok(())
}
