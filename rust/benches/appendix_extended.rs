//! Paper Appendix Figs. 12–18: extended experiments across models,
//! hardware, and frameworks — {Qwen2.5-7B, Qwen2.5-32B} × {2×V100, 4×V100,
//! 1×A800} × {vLLM, LMDeploy}, request numbers up to 40.
//!
//! Headline claims under test: up to ~5× SLO-attainment gain in the
//! strict corner (Qwen2.5-32B @ A800, LMDeploy, 40 requests, bs 1) and up
//! to ~31.6% average-latency reduction (Qwen2.5-7B @ A800, LMDeploy,
//! 8 requests, bs 2).

use slo_serve::bench::run_scenario;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::metrics::Table;

fn run(policy: &str, profile: &str, n: usize, bs: usize, seeds: &[u64])
    -> (f64, f64, f64) {
    let mut att = 0.0;
    let mut lat = 0.0;
    let mut g = 0.0;
    for &seed in seeds {
        let c = RunConfig {
            policy: policy.into(),
            profile: profile.into(),
            n_requests: n,
            max_batch: bs,
            seed,
            output_pred: OutputPrediction::Oracle { rel_err: 0.05 },
            slos: SloTargets::default().scaled(0.4),
            ..Default::default()
        };
        let m = run_scenario(&c).unwrap().metrics;
        att += m.attainment();
        lat += m.avg_latency_ms();
        g += m.g_req_per_s;
    }
    let k = seeds.len() as f64;
    (att / k, lat / k, g / k)
}

fn main() {
    println!("== Appendix Figs. 12–18: extended model × hardware × framework sweep ==\n");
    let seeds: Vec<u64> = (0..2).collect();
    let profiles = [
        ("Fig12", "qwen7b-v100x2-lmdeploy"),
        ("Fig13", "qwen32b-v100x4-vllm"),
        ("Fig14", "qwen32b-v100x4-lmdeploy"),
        ("Fig15", "qwen7b-a800-vllm"),
        ("Fig16", "qwen7b-a800-lmdeploy"),
        ("Fig17", "qwen32b-a800-vllm"),
        ("Fig18", "qwen32b-a800-lmdeploy"),
    ];
    let mut best_att_ratio: (f64, String) = (0.0, String::new());
    let mut best_lat_cut: (f64, String) = (0.0, String::new());
    for (fig, profile) in profiles {
        println!("-- {fig}: {profile}");
        let mut t = Table::new(&[
            "req#", "bs", "fcfs att", "sa att", "att ratio",
            "fcfs lat(ms)", "sa lat(ms)", "lat cut",
        ]);
        for &bs in &[1usize, 2, 4] {
            for &n in &[10usize, 20, 40] {
                let (fa, fl, _) = run("fcfs", profile, n, bs, &seeds);
                let (sa, sl, _) = run("slo-aware-sa", profile, n, bs, &seeds);
                let ratio = if fa > 0.0 { sa / fa } else { f64::NAN };
                let cut = (1.0 - sl / fl) * 100.0;
                let label = format!("{profile} n={n} bs={bs}");
                if ratio.is_finite() && ratio > best_att_ratio.0 {
                    best_att_ratio = (ratio, label.clone());
                }
                if cut > best_lat_cut.0 {
                    best_lat_cut = (cut, label);
                }
                t.row(vec![
                    n.to_string(),
                    bs.to_string(),
                    format!("{:.0}%", fa * 100.0),
                    format!("{:.0}%", sa * 100.0),
                    if ratio.is_finite() {
                        format!("{ratio:.2}x")
                    } else {
                        "inf".into()
                    },
                    format!("{fl:.0}"),
                    format!("{sl:.0}"),
                    format!("{cut:+.1}%"),
                ]);
            }
        }
        print!("{}", t.render());
        println!();
    }
    println!("max attainment ratio: {:.2}x ({})", best_att_ratio.0, best_att_ratio.1);
    println!("max latency reduction: {:.1}% ({})", best_lat_cut.0, best_lat_cut.1);
    println!("\npaper shape: biggest attainment gains (up to 5x) in the strict corner");
    println!("(32B on one A800, many requests, bs 1); latency cuts up to 31.6% depend");
    println!("more on baseline sequence randomness than on model/framework.");
}
