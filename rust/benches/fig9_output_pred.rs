//! Paper Fig. 9: impact of output-length prediction accuracy on the SA
//! scheduler's ΔG, for max batch sizes 1 / 2 / 4.
//!
//! Modes: the shipped profiler-Gaussian predictor vs oracles with ±10%,
//! ±5%, ±2.5% relative error (the paper simulates predictor accuracy by
//! perturbing actual output lengths). Paper shape: more accurate
//! prediction ⇒ larger ΔG, up to +84% over baseline at 40 req / bs 4.

use slo_serve::bench::run_scenario;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::metrics::Table;

fn run(policy: &str, n: usize, bs: usize, pred: OutputPrediction, seeds: &[u64]) -> f64 {
    let mut g = 0.0;
    for &seed in seeds {
        let c = RunConfig {
            policy: policy.into(),
            n_requests: n,
            max_batch: bs,
            seed,
            output_pred: pred,
            slos: SloTargets::default().scaled(0.4),
            ..Default::default()
        };
        g += run_scenario(&c).unwrap().metrics.g_req_per_s;
    }
    g / seeds.len() as f64
}

fn main() {
    println!("== Fig. 9: ΔG (%) vs output-length prediction accuracy ==\n");
    let seeds: Vec<u64> = (0..3).collect();
    let modes: [(&str, OutputPrediction); 4] = [
        ("profiler-gaussian", OutputPrediction::Profiler),
        ("oracle ±10%", OutputPrediction::Oracle { rel_err: 0.10 }),
        ("oracle ±5%", OutputPrediction::Oracle { rel_err: 0.05 }),
        ("oracle ±2.5%", OutputPrediction::Oracle { rel_err: 0.025 }),
    ];
    for (panel, bs) in [("A", 1usize), ("B", 2), ("C", 4)] {
        println!("-- Fig. 9({panel}): max batch {bs}");
        let mut t = Table::new(&["req#", "predictor", "ΔG vs fcfs"]);
        for &n in &[10usize, 20, 40] {
            let base = run("fcfs", n, bs, OutputPrediction::Profiler, &seeds);
            for (name, mode) in modes {
                let g = run("slo-aware-sa", n, bs, mode, &seeds);
                t.row(vec![
                    n.to_string(),
                    name.into(),
                    format!("{:+.1}%", (g / base - 1.0) * 100.0),
                ]);
            }
        }
        print!("{}", t.render());
        println!();
    }
    println!("paper shape: accuracy ↑ ⇒ ΔG ↑ (±2.5% oracle gave +65% over the");
    println!("profiler version and +84% over baseline at 40 req / bs 4).");
}
