//! Objective function `G` and schedule representation (paper §3.1, Eqs. 1–13).
//!
//! A *schedule* is a permutation of the jobs plus a partition into
//! consecutive batches (`b_0..b_{M-1}`, Eq. 10). Batches execute
//! sequentially on an explicit **timeline** ([`TimelineOrigin`]): batch
//! `k` starts at `max(end of batch k−1, latest member arrival)`, so both
//! engine idle gaps between arrival waves and per-job arrival offsets
//! flow into every entry wait. A job's waiting time is its batch's start
//! time minus its own arrival (Eq. 11 generalized); with every arrival at
//! t = 0 this collapses — bit for bit — to the paper's closed-wave sum of
//! earlier batch maxima. `G = n / Σ t_e2e` (Eqs. 2–3) — the ratio of SLO
//! attainment to accumulated latency.
//!
//! Two evaluators implement Eqs. 2–13:
//!
//! * [`Evaluator`] — the reference full evaluation: O(N) predictor calls,
//!   zero heap allocation per call. Used for seeds, baselines, and as the
//!   ground truth the delta path is checked against.
//! * [`IncrementalEval`] — the simulated-annealing hot path (≈10⁴ calls per
//!   scheduling decision). It owns the candidate schedule plus per-batch
//!   aggregates (max exec, Σ(wait+exec), met count, entry wait) backed by a
//!   per-wave [`PredTable`], so a neighbourhood move recomputes only the
//!   touched batches plus the downstream suffix whose entry wait actually
//!   shifted (exact `f64` comparison), then re-reduces the per-batch
//!   partials.
//!
//! **Equivalence guarantee**: both evaluators accumulate `Σ t_e2e` as
//! per-batch partial sums (job order within the batch, then batch order)
//! and waiting time as the running sum of batch maxima. Because the
//! groupings are identical, the table entries are stored predictor outputs,
//! and the unchanged-suffix shortcut fires only on exact `f64` equality of
//! the entry wait, every [`IncrementalEval`] result is **bit-identical** to
//! a fresh [`Evaluator::eval`] of the same schedule — enforced by
//! `tests/incremental_eval_equivalence.rs`.
//!
//! **KV-block occupancy** (Eq. 20): [`IncrementalEval`] additionally
//! maintains each batch's KV-block demand — the member-footprint sum
//! under [`KvPhaseModel::Reserve`], the exact phase-aware occupancy peak
//! ([`crate::coordinator::kv::phased_peak_blocks`]) under
//! [`KvPhaseModel::Phased`] — and the total excess over the configured
//! pool ([`IncrementalEval::kv_excess`]), updated by the same
//! touched-batch rule as the latency partials. Under a hard [`KvConfig`]
//! it hands the move generator a [`moves::KvVeto`] — pricing candidates
//! by footprint sums under `Reserve` and by exact occupancy peaks under
//! `Phased` — so infeasible candidates are never materialized.
//! [`Evaluator::kv_excess`] is the O(N) reference the
//! equivalence tests check against. With an unlimited pool the excess is
//! identically zero and nothing about the pre-KV behaviour changes.
//!
//! **Latency prices the mean, KV reserves the quantile**: every latency
//! term above uses the *point* output-length prediction, while reserve
//! footprints go through [`KvConfig::job_blocks`], which can charge a
//! conservative output-length quantile instead
//! ([`KvConfig::lo_mult`], fed by
//! [`crate::coordinator::predictor::LatencyPredictor::quantile`]). Both
//! evaluators read footprints through the same `KvConfig`/`PredTable`
//! column, so the incremental–full equivalence holds at any quantile;
//! `lo_mult == 1.0` is the pre-quantile accounting bit for bit.

use crate::coordinator::kv::{self, KvConfig, KvPhaseModel};
use crate::coordinator::pred_table::PredTable;
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::moves::{self, OrderUndo};
use crate::coordinator::request::{Request, Slo};
use crate::util::rng::Rng;

/// Scheduler's view of one job: lengths are *predictions* (the true output
/// length is hidden from the scheduler — §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Job {
    /// Index into the coordinator's request slice.
    pub req_idx: usize,
    pub input_len: usize,
    /// Predicted output length (from the profiler's per-task model or an
    /// oracle variant in the Fig. 9 study).
    pub output_len: usize,
    pub slo: Slo,
}

impl Job {
    pub fn from_request(req_idx: usize, r: &Request, predicted_out: usize) -> Job {
        Job { req_idx, input_len: r.input_len, output_len: predicted_out, slo: r.slo }
    }
}

/// A candidate scheduling solution: execution order + batch partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    /// Job indices (into the `Job` slice) in execution order.
    pub order: Vec<usize>,
    /// Batch sizes; contiguous segments of `order`. `Σ batches == order.len()`.
    pub batches: Vec<usize>,
}

impl Schedule {
    /// Arrival order, greedily packed to `max_batch` (the FCFS seed —
    /// Algorithm 1's first starting solution).
    pub fn fcfs(n: usize, max_batch: usize) -> Schedule {
        Schedule::from_order((0..n).collect(), max_batch)
    }

    /// Pack a given order into full batches of `max_batch`.
    pub fn from_order(order: Vec<usize>, max_batch: usize) -> Schedule {
        assert!(max_batch > 0);
        let n = order.len();
        let mut batches = vec![max_batch; n / max_batch];
        if n % max_batch != 0 {
            batches.push(n % max_batch);
        }
        Schedule { order, batches }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Structural invariants (used by tests and the property harness):
    /// order is a permutation of 0..n; batches are positive, ≤ max_batch,
    /// and partition the order.
    pub fn validate(&self, max_batch: usize) -> Result<(), String> {
        let n = self.order.len();
        let mut seen = vec![false; n];
        for &j in &self.order {
            if j >= n {
                return Err(format!("order contains out-of-range index {j}"));
            }
            if seen[j] {
                return Err(format!("order repeats index {j}"));
            }
            seen[j] = true;
        }
        if self.batches.iter().any(|&b| b == 0) {
            return Err("empty batch".into());
        }
        if let Some(&b) = self.batches.iter().find(|&&b| b > max_batch) {
            return Err(format!("batch size {b} exceeds max {max_batch}"));
        }
        let total: usize = self.batches.iter().sum();
        if total != n {
            return Err(format!("batches sum {total} != n {n}"));
        }
        Ok(())
    }

    /// Iterate `(batch_index, start_offset, size)`.
    pub fn batch_spans(&self) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        let mut start = 0usize;
        self.batches.iter().enumerate().map(move |(k, &size)| {
            let span = (k, start, size);
            start += size;
            span
        })
    }

    /// Position → batch index map (Eq. 10's `a_i`), written into `out`.
    pub fn batch_of_position(&self, out: &mut Vec<usize>) {
        out.clear();
        for (k, _, size) in self.batch_spans() {
            out.extend(std::iter::repeat(k).take(size));
        }
    }
}

/// Aggregate evaluation of a schedule under predicted latencies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Eval {
    /// Objective `G = n / Σ t_e2e` (requests per millisecond here; benches
    /// convert to req/s for display).
    pub g: f64,
    /// `n` — requests meeting their SLO (Eq. 6).
    pub met: usize,
    /// `Σ t_e2e` over all requests (ms).
    pub total_e2e_ms: f64,
    /// Makespan: completion time of the last batch (ms).
    pub makespan_ms: f64,
}

impl Eval {
    pub const ZERO: Eval =
        Eval { g: 0.0, met: 0, total_e2e_ms: 0.0, makespan_ms: 0.0 };

    /// Average latency (the paper reports G alongside attainment & mean).
    pub fn avg_latency_ms(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.total_e2e_ms / n as f64
        }
    }
}

/// Per-job predicted timeline (diagnostics / tests).
#[derive(Debug, Clone, Copy)]
pub struct JobTimeline {
    pub job: usize,
    pub batch: usize,
    /// Absolute start time of the job's batch on the wave timeline (ms).
    pub start_ms: f64,
    /// Waiting time measured from the job's arrival (Eq. 11 generalized):
    /// `start_ms − arrival_ms`.
    pub wait_ms: f64,
    pub exec_ms: f64,
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    pub met: bool,
}

/// The time origin of a predicted schedule: when the engine becomes free
/// for the first batch (`t0`) plus each job's arrival time. This is what
/// replaced the scalar base-wait offset: idle gaps between arrival waves
/// and per-job arrival offsets both flow through the same
/// `max(previous batch end, latest member arrival)` start-time rule.
///
/// An empty `arrivals` vector means *every job arrived at t = 0* (the
/// paper's closed-wave setting) — evaluation is then bit-identical to the
/// arrival-free implementation.
///
/// ```
/// use slo_serve::coordinator::objective::{
///     Evaluator, Job, Schedule, TimelineOrigin,
/// };
/// use slo_serve::coordinator::predictor::LatencyPredictor;
/// use slo_serve::coordinator::request::Slo;
///
/// let predictor = LatencyPredictor::paper_table2();
/// let job = |i| Job {
///     req_idx: i,
///     input_len: 100,
///     output_len: 10,
///     slo: Slo::E2e { e2e_ms: 1e9 },
/// };
/// let jobs = vec![job(0), job(1)];
/// // job 1 arrives 5 s into the trace: its batch cannot start earlier,
/// // and its wait is measured from that arrival — the engine idles in
/// // between, which the closed-wave model could not express.
/// let origin = TimelineOrigin { t0: 0.0, arrivals: vec![0.0, 5_000.0] };
/// let ev = Evaluator::with_timeline(&jobs, &predictor, &origin);
/// let s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
/// let (_, tl) = ev.eval_detailed(&s);
/// assert_eq!(tl[1].start_ms, 5_000.0); // idle gap modeled
/// assert_eq!(tl[1].wait_ms, 0.0);      // wait measured from arrival
/// ```
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TimelineOrigin {
    /// Time (ms) at which the engine is free to start the first batch —
    /// for the online controller, the predicted end of the compacted
    /// dispatched prefix; 0.0 for closed waves.
    pub t0: f64,
    /// Per-job arrival times (ms); empty ⇒ all jobs at t = 0.
    pub arrivals: Vec<f64>,
}

impl TimelineOrigin {
    /// A timeline starting at `t0` with every job at t = 0 (the compacted
    /// online controller before arrival awareness is enabled).
    pub fn at(t0: f64) -> TimelineOrigin {
        TimelineOrigin { t0, arrivals: Vec::new() }
    }

    /// The start time of a batch whose members' latest arrival is `arr`,
    /// given the engine becomes free at `free`: `max(free, arr)`, written
    /// so that `free` is returned verbatim (same bits) whenever `arr`
    /// does not exceed it — the closed-wave bit-identity hinge.
    #[inline]
    pub fn batch_start(free: f64, arr: f64) -> f64 {
        if arr > free {
            arr
        } else {
            free
        }
    }
}

/// Reusable evaluator: borrows the job set and predictor, owns scratch.
pub struct Evaluator<'a> {
    jobs: &'a [Job],
    predictor: &'a LatencyPredictor,
    /// Time the engine becomes free for the first batch (the
    /// [`TimelineOrigin::t0`] of this wave); 0.0 for closed waves, in
    /// which case every result is bit-identical to the pre-timeline
    /// implementation.
    t0_ms: f64,
    /// Per-job arrival times; empty ⇒ all at t = 0.
    arrivals: &'a [f64],
    /// Chunked-prefill chunk size the timeline is priced at; 0 (the
    /// default) prices whole-batch prefill — the pre-chunking arithmetic
    /// bit for bit for E2e-class SLOs.
    chunk_tokens: usize,
}

impl<'a> Evaluator<'a> {
    pub fn new(jobs: &'a [Job], predictor: &'a LatencyPredictor) -> Self {
        Evaluator { jobs, predictor, t0_ms: 0.0, arrivals: &[], chunk_tokens: 0 }
    }

    /// This evaluator pricing chunked prefill at `chunk_tokens` tokens
    /// per chunk (0 = off): member prefills run sequentially in batch
    /// order as batch-of-1 chunk calls, a member's TTFT lands at its
    /// *final* chunk completion, and decode starts after every member's
    /// prefill — mirroring
    /// [`crate::engine::sim::SimEngine::with_chunk_tokens`].
    pub fn with_chunk_tokens(mut self, chunk_tokens: usize) -> Self {
        self.chunk_tokens = chunk_tokens;
        self
    }

    /// The chunked-prefill chunk size this evaluator prices (0 = off).
    pub fn chunk_tokens(&self) -> usize {
        self.chunk_tokens
    }

    /// [`Evaluator::new`] with an initial waiting time: every job's entry
    /// wait starts at `base_wait_ms` instead of zero.
    #[deprecated(
        since = "0.1.0",
        note = "superseded by the explicit timeline: use \
                `Evaluator::with_timeline` (or `with_arrivals`) with a \
                `TimelineOrigin { t0, arrivals }` — a scalar base wait is \
                the degenerate all-arrivals-at-zero case"
    )]
    pub fn with_base_wait(
        jobs: &'a [Job],
        predictor: &'a LatencyPredictor,
        base_wait_ms: f64,
    ) -> Self {
        Evaluator {
            jobs,
            predictor,
            t0_ms: base_wait_ms,
            arrivals: &[],
            chunk_tokens: 0,
        }
    }

    /// Evaluate on an explicit timeline (module docs): batch `k` starts at
    /// `max(end of batch k−1, latest member arrival)` with the first
    /// batch's "previous end" being `origin.t0`; per-job waits are
    /// measured from each job's own arrival.
    pub fn with_timeline(
        jobs: &'a [Job],
        predictor: &'a LatencyPredictor,
        origin: &'a TimelineOrigin,
    ) -> Self {
        Evaluator::with_arrivals(jobs, predictor, origin.t0, &origin.arrivals)
    }

    /// [`Evaluator::with_timeline`] over borrowed parts — lets the online
    /// controller lend the arrival column straight out of its
    /// [`PredTable`] without cloning.
    pub fn with_arrivals(
        jobs: &'a [Job],
        predictor: &'a LatencyPredictor,
        t0_ms: f64,
        arrivals: &'a [f64],
    ) -> Self {
        assert!(
            arrivals.is_empty() || arrivals.len() == jobs.len(),
            "arrival column must cover every job (or be empty for t = 0)"
        );
        Evaluator { jobs, predictor, t0_ms, arrivals, chunk_tokens: 0 }
    }

    pub fn jobs(&self) -> &[Job] {
        self.jobs
    }

    pub fn predictor(&self) -> &LatencyPredictor {
        self.predictor
    }

    /// The timeline origin's `t0`: when the engine is free for the first
    /// batch.
    pub fn t0_ms(&self) -> f64 {
        self.t0_ms
    }

    /// Alias of [`Evaluator::t0_ms`] kept for the pre-timeline name.
    pub fn base_wait_ms(&self) -> f64 {
        self.t0_ms
    }

    /// The per-job arrival column (empty ⇒ all jobs at t = 0).
    pub fn arrivals(&self) -> &[f64] {
        self.arrivals
    }

    /// Arrival time of `job` (0.0 when the column is empty).
    #[inline]
    fn arrival(&self, job: usize) -> f64 {
        if self.arrivals.is_empty() {
            0.0
        } else {
            self.arrivals[job]
        }
    }

    /// Latest arrival among `members` (0.0 when the column is empty, so
    /// `batch_start` degenerates to the running free time).
    #[inline]
    fn batch_arrival_max(&self, members: &[usize]) -> f64 {
        if self.arrivals.is_empty() {
            return 0.0;
        }
        let mut arr = f64::NEG_INFINITY;
        for &j in members {
            if self.arrivals[j] > arr {
                arr = self.arrivals[j];
            }
        }
        arr
    }

    /// Total KV-block excess of a schedule under `kv` (Eq. 20): for each
    /// batch, its demand under `kv.phase` (footprint sum for `Reserve`,
    /// phase-aware occupancy peak for `Phased`) minus the pool, clamped
    /// at zero, summed over batches. O(N) from the raw job lengths — the
    /// reference [`IncrementalEval::kv_excess`] is checked against.
    pub fn kv_excess(&self, schedule: &Schedule, kv: &KvConfig) -> u64 {
        if !kv.binding() {
            return 0;
        }
        let mut excess = 0u64;
        let mut members: Vec<(usize, usize)> = Vec::new();
        for (_, start, size) in schedule.batch_spans() {
            let demand = match kv.phase {
                KvPhaseModel::Reserve => schedule.order[start..start + size]
                    .iter()
                    .map(|&j| {
                        let job = &self.jobs[j];
                        kv.job_blocks(job.input_len, job.output_len)
                    })
                    .sum(),
                KvPhaseModel::Phased => {
                    members.clear();
                    members.extend(
                        schedule.order[start..start + size].iter().map(|&j| {
                            let job = &self.jobs[j];
                            (job.input_len, job.output_len)
                        }),
                    );
                    kv::phased_peak_blocks(&members, kv.block_tokens)
                }
            };
            excess += kv.batch_excess(demand);
        }
        excess
    }

    /// Evaluate G for a schedule (Eqs. 2–13). O(N), allocation-free.
    ///
    /// `Σ t_e2e` is accumulated as per-batch partial sums — the same
    /// grouping [`IncrementalEval`] reduces over, which is what makes the
    /// two paths bit-identical (module docs). Batch start times follow the
    /// timeline rule ([`TimelineOrigin::batch_start`]); with no arrival
    /// column and `t0 = 0` every operation matches the pre-timeline code
    /// bit for bit.
    pub fn eval(&self, schedule: &Schedule) -> Eval {
        debug_assert_eq!(schedule.len(), self.jobs.len());
        let mut free = self.t0_ms;
        let mut total_e2e = 0.0f64;
        let mut met = 0usize;
        let mut start = 0usize;
        for &bsize in &schedule.batches {
            let members = &schedule.order[start..start + bsize];
            let begin =
                TimelineOrigin::batch_start(free, self.batch_arrival_max(members));
            let mut batch_max = 0.0f64;
            let mut batch_sum = 0.0f64;
            if self.chunk_tokens == 0 {
                // Whole-batch prefill: every member's first token lands
                // at the batch prefill completion, which the max-input
                // member determines — the engine's `prefill_ms(b, max_in)`
                // charge, not the member's own solo prefill.
                let max_in = members
                    .iter()
                    .map(|&j| self.jobs[j].input_len)
                    .max()
                    .unwrap_or(0);
                let batch_prefill = self.predictor.prefill_ms(bsize, max_in);
                for &j in members {
                    let job = &self.jobs[j];
                    let p = self
                        .predictor
                        .predict(bsize, job.input_len, job.output_len);
                    let wait = begin - self.arrival(j);
                    let e2e = wait + p.exec_ms;
                    let ttft = wait + batch_prefill;
                    batch_sum += e2e;
                    if job.slo.met(e2e, ttft, p.tpot_ms) {
                        met += 1;
                    }
                    if p.exec_ms > batch_max {
                        batch_max = p.exec_ms;
                    }
                }
            } else {
                // Chunked prefill: members prefill sequentially in batch
                // order (batch-of-1 chunks), so member i's first token
                // lands at its own final chunk completion (prefix sum of
                // chunk times); decode starts once every member has
                // prefilled. A ≤1-token member finishes at its final
                // chunk; the rest decode for `exec − prefill` at the
                // batch size, on top of the whole chunk phase.
                let mut chunk_total = 0.0f64;
                for &j in members {
                    chunk_total += self
                        .predictor
                        .chunked_prefill_ms(self.jobs[j].input_len, self.chunk_tokens);
                }
                let mut offset = 0.0f64;
                for &j in members {
                    let job = &self.jobs[j];
                    let p = self
                        .predictor
                        .predict(bsize, job.input_len, job.output_len);
                    offset += self
                        .predictor
                        .chunked_prefill_ms(job.input_len, self.chunk_tokens);
                    let wait = begin - self.arrival(j);
                    let exec = if job.output_len <= 1 {
                        offset
                    } else {
                        chunk_total + (p.exec_ms - p.prefill_ms)
                    };
                    let e2e = wait + exec;
                    let ttft = wait + offset;
                    batch_sum += e2e;
                    if job.slo.met(e2e, ttft, p.tpot_ms) {
                        met += 1;
                    }
                    if exec > batch_max {
                        batch_max = exec;
                    }
                }
            }
            total_e2e += batch_sum;
            free = begin + batch_max;
            start += bsize;
        }
        let g = if total_e2e > 0.0 { met as f64 / total_e2e } else { 0.0 };
        Eval { g, met, total_e2e_ms: total_e2e, makespan_ms: free }
    }

    /// Like [`Evaluator::eval`] but also returns per-job timelines
    /// (allocates).
    pub fn eval_detailed(&self, schedule: &Schedule) -> (Eval, Vec<JobTimeline>) {
        let mut timelines = Vec::with_capacity(self.jobs.len());
        let mut free = self.t0_ms;
        let mut total_e2e = 0.0f64;
        let mut met = 0usize;
        for (k, start, bsize) in schedule.batch_spans() {
            let members = &schedule.order[start..start + bsize];
            let begin =
                TimelineOrigin::batch_start(free, self.batch_arrival_max(members));
            let mut batch_max = 0.0f64;
            let mut batch_sum = 0.0f64;
            if self.chunk_tokens == 0 {
                let max_in = members
                    .iter()
                    .map(|&j| self.jobs[j].input_len)
                    .max()
                    .unwrap_or(0);
                let batch_prefill = self.predictor.prefill_ms(bsize, max_in);
                for &j in members {
                    let job = &self.jobs[j];
                    let p = self
                        .predictor
                        .predict(bsize, job.input_len, job.output_len);
                    let wait = begin - self.arrival(j);
                    let e2e = wait + p.exec_ms;
                    let ttft = wait + batch_prefill;
                    let ok = job.slo.met(e2e, ttft, p.tpot_ms);
                    batch_sum += e2e;
                    met += ok as usize;
                    batch_max = batch_max.max(p.exec_ms);
                    timelines.push(JobTimeline {
                        job: j,
                        batch: k,
                        start_ms: begin,
                        wait_ms: wait,
                        exec_ms: p.exec_ms,
                        ttft_ms: ttft,
                        tpot_ms: p.tpot_ms,
                        met: ok,
                    });
                }
            } else {
                let mut chunk_total = 0.0f64;
                for &j in members {
                    chunk_total += self
                        .predictor
                        .chunked_prefill_ms(self.jobs[j].input_len, self.chunk_tokens);
                }
                let mut offset = 0.0f64;
                for &j in members {
                    let job = &self.jobs[j];
                    let p = self
                        .predictor
                        .predict(bsize, job.input_len, job.output_len);
                    offset += self
                        .predictor
                        .chunked_prefill_ms(job.input_len, self.chunk_tokens);
                    let wait = begin - self.arrival(j);
                    let exec = if job.output_len <= 1 {
                        offset
                    } else {
                        chunk_total + (p.exec_ms - p.prefill_ms)
                    };
                    let e2e = wait + exec;
                    let ttft = wait + offset;
                    let ok = job.slo.met(e2e, ttft, p.tpot_ms);
                    batch_sum += e2e;
                    met += ok as usize;
                    batch_max = batch_max.max(exec);
                    timelines.push(JobTimeline {
                        job: j,
                        batch: k,
                        start_ms: begin,
                        wait_ms: wait,
                        exec_ms: exec,
                        ttft_ms: ttft,
                        tpot_ms: p.tpot_ms,
                        met: ok,
                    });
                }
            }
            total_e2e += batch_sum;
            free = begin + batch_max;
        }
        let g = if total_e2e > 0.0 { met as f64 / total_e2e } else { 0.0 };
        (
            Eval { g, met, total_e2e_ms: total_e2e, makespan_ms: free },
            timelines,
        )
    }

    /// Predicted e2e at batch size 1 (the sort key for Algorithm 1's second
    /// starting solution).
    pub fn solo_e2e_ms(&self, job: usize) -> f64 {
        let j = &self.jobs[job];
        self.predictor.predict(1, j.input_len, j.output_len).exec_ms
    }
}

/// Per-batch KV-block demand of `schedule` under `kv`'s demand model,
/// written into `out` (index = batch). `job_blocks[j]` is job `j`'s full
/// footprint (the `Reserve` summand); `jobs` supplies the raw lengths the
/// `Phased` peak needs. Shared by the full-evaluation reference search
/// path, which has no incremental aggregates to borrow a
/// [`moves::KvVeto`] from.
pub fn batch_kv_blocks(
    schedule: &Schedule,
    jobs: &[Job],
    job_blocks: &[u64],
    kv: &KvConfig,
    out: &mut Vec<u64>,
) {
    out.clear();
    let mut members: Vec<(usize, usize)> = Vec::new();
    for (_, start, size) in schedule.batch_spans() {
        let demand = match kv.phase {
            KvPhaseModel::Reserve => schedule.order[start..start + size]
                .iter()
                .map(|&j| job_blocks[j])
                .sum(),
            KvPhaseModel::Phased => {
                members.clear();
                members.extend(
                    schedule.order[start..start + size]
                        .iter()
                        .map(|&j| (jobs[j].input_len, jobs[j].output_len)),
                );
                kv::phased_peak_blocks(&members, kv.block_tokens)
            }
        };
        out.push(demand);
    }
}

/// Struct-of-arrays store for the per-batch aggregates the incremental
/// evaluator maintains (index = batch). Keeping each aggregate in its own
/// flat column — rather than a `Vec` of per-batch structs — makes the
/// suffix re-reduction, the snapshot/restore pair, and the KV-excess
/// pricing straight single-array passes the compiler can unroll and
/// auto-vectorize, and it means a rollback touches only the columns as
/// contiguous `memcpy`s.
///
/// The `bend` column caches `wait[k] + bmax[k]` (batch k's end time) so
/// the changed-wait suffix walk and the makespan read one column instead
/// of recombining two; it is written from the exact same expression the
/// sequential evaluation uses, so every read is bit-identical to the
/// recombination it replaces.
#[derive(Debug, Clone, Default)]
struct BatchSoa {
    /// Max exec time in batch k (at its current size).
    bmax: Vec<f64>,
    /// Σ (wait + exec) over batch k's jobs, in order.
    bsum: Vec<f64>,
    /// SLO-met count in batch k at its current start time.
    bmet: Vec<usize>,
    /// Start time of batch k on the wave timeline
    /// (`max(end of batch k−1, barr[k])`, chained sequentially from t0).
    wait: Vec<f64>,
    /// End time of batch k (`wait[k] + bmax[k]`, cached).
    bend: Vec<f64>,
    /// Latest member arrival in batch k (from the table's arrival
    /// column; 0.0 throughout for closed waves).
    barr: Vec<f64>,
    /// KV-block demand of batch k (Eq. 20; footprint sum under
    /// `Reserve`, phase-aware occupancy peak under `Phased`).
    bkv: Vec<u64>,
}

impl BatchSoa {
    /// Zero-fill every column at length `m`.
    fn clear_resize(&mut self, m: usize) {
        self.bmax.clear();
        self.bmax.resize(m, 0.0);
        self.bsum.clear();
        self.bsum.resize(m, 0.0);
        self.bmet.clear();
        self.bmet.resize(m, 0);
        self.wait.clear();
        self.wait.resize(m, 0.0);
        self.bend.clear();
        self.bend.resize(m, 0.0);
        self.barr.clear();
        self.barr.resize(m, 0.0);
        self.bkv.clear();
        self.bkv.resize(m, 0);
    }

    /// Copy every column from `src` into reused buffers (no allocation
    /// once warm) — the snapshot and restore primitive.
    fn copy_from(&mut self, src: &BatchSoa) {
        self.bmax.clear();
        self.bmax.extend_from_slice(&src.bmax);
        self.bsum.clear();
        self.bsum.extend_from_slice(&src.bsum);
        self.bmet.clear();
        self.bmet.extend_from_slice(&src.bmet);
        self.wait.clear();
        self.wait.extend_from_slice(&src.wait);
        self.bend.clear();
        self.bend.extend_from_slice(&src.bend);
        self.barr.clear();
        self.barr.extend_from_slice(&src.barr);
        self.bkv.clear();
        self.bkv.extend_from_slice(&src.bkv);
    }

    /// Mirror a batch removal at index `r` across every column.
    fn remove(&mut self, r: usize) {
        self.bmax.remove(r);
        self.bsum.remove(r);
        self.bmet.remove(r);
        self.wait.remove(r);
        self.bend.remove(r);
        self.barr.remove(r);
        self.bkv.remove(r);
    }

    /// Mirror a trailing batch append (zeroed; recomputed by the caller).
    fn push_zero(&mut self) {
        self.bmax.push(0.0);
        self.bsum.push(0.0);
        self.bmet.push(0);
        self.wait.push(0.0);
        self.bend.push(0.0);
        self.barr.push(0.0);
        self.bkv.push(0);
    }

    fn len(&self) -> usize {
        self.bmax.len()
    }
}

/// Delta evaluator driving the simulated-annealing hot path.
///
/// Owns the current candidate [`Schedule`] plus per-batch aggregates in a
/// struct-of-arrays layout ([`BatchSoa`]); a
/// [`IncrementalEval::try_random_move`] applies one neighbourhood move
/// in-place, updates only what the move invalidated, and returns the new
/// [`Eval`]. The caller then either [`IncrementalEval::commit`]s (free) or
/// [`IncrementalEval::rollback`]s (restores the pre-move state from
/// reused snapshot buffers). No heap allocation occurs per move once the
/// snapshot buffers are warm.
///
/// Cost per move: O(touched-batch sizes) table lookups, plus a recompute of
/// the downstream suffix only while its entry wait differs (exact `f64`
/// comparison) from the cached value, plus an O(M) re-reduction over
/// per-batch partial columns (M = batch count). See the module docs for
/// why the result is bit-identical to [`Evaluator::eval`].
pub struct IncrementalEval<'a> {
    jobs: &'a [Job],
    table: &'a PredTable,
    kv: KvConfig,
    /// Time the engine is free for the first batch
    /// ([`TimelineOrigin::t0`]); 0.0 for closed waves.
    t0_ms: f64,
    schedule: Schedule,
    /// Per-batch aggregate columns (SoA).
    agg: BatchSoa,
    /// Σ over batches of demand beyond the pool (0 when not binding).
    kv_excess: u64,
    eval: Eval,
    // Pre-move snapshots (reused buffers) for rollback.
    saved_batches: Vec<usize>,
    saved: BatchSoa,
    saved_kv_excess: u64,
    saved_eval: Eval,
    pending: Option<OrderUndo>,
}

impl<'a> IncrementalEval<'a> {
    /// Build the incremental state for `schedule` (O(N) table lookups)
    /// with an unlimited KV pool — the pre-KV behaviour.
    pub fn new(jobs: &'a [Job], table: &'a PredTable, schedule: Schedule) -> Self {
        IncrementalEval::new_kv(jobs, table, schedule, KvConfig::UNLIMITED, 0.0)
    }

    /// [`IncrementalEval::new`] with a KV configuration and a timeline
    /// origin `t0_ms` (the first batch's earliest start; arrival times
    /// come from the table's arrival column — zeros for closed waves).
    /// Under [`crate::coordinator::kv::KvMode::Hard`] every
    /// [`IncrementalEval::try_random_move_masked`] hands the move
    /// generator a [`moves::KvVeto`] over the current per-batch demand,
    /// so candidates that would overcommit a batch are refused before
    /// application.
    pub fn new_kv(
        jobs: &'a [Job],
        table: &'a PredTable,
        schedule: Schedule,
        kv: KvConfig,
        t0_ms: f64,
    ) -> Self {
        assert_eq!(schedule.len(), jobs.len());
        debug_assert_eq!(table.len(), jobs.len());
        let mut s = IncrementalEval {
            jobs,
            table,
            kv,
            t0_ms,
            schedule,
            agg: BatchSoa::default(),
            kv_excess: 0,
            eval: Eval::ZERO,
            saved_batches: Vec::new(),
            saved: BatchSoa::default(),
            saved_kv_excess: 0,
            saved_eval: Eval::ZERO,
            pending: None,
        };
        s.rebuild();
        s
    }

    /// The current candidate schedule.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Consume the evaluator, yielding its schedule.
    pub fn into_schedule(self) -> Schedule {
        self.schedule
    }

    /// Evaluation of the current schedule (bit-identical to
    /// [`Evaluator::eval`] on the same schedule).
    pub fn eval(&self) -> Eval {
        self.eval
    }

    /// Total KV-block excess of the current schedule (bit-identical to
    /// [`Evaluator::kv_excess`] under the same [`KvConfig`]); 0 whenever
    /// the pool is unlimited.
    pub fn kv_excess(&self) -> u64 {
        self.kv_excess
    }

    /// KV-block demand of batch `k` under the configured phase model:
    /// the member-footprint sum for [`KvPhaseModel::Reserve`], the exact
    /// occupancy peak for [`KvPhaseModel::Phased`].
    pub fn batch_kv_blocks(&self, k: usize) -> u64 {
        self.agg.bkv[k]
    }

    /// The KV configuration this evaluator enforces.
    pub fn kv_config(&self) -> &KvConfig {
        &self.kv
    }

    /// Replace the schedule and rebuild all aggregates from scratch.
    pub fn reset(&mut self, schedule: Schedule) {
        assert_eq!(schedule.len(), self.jobs.len());
        self.schedule = schedule;
        self.pending = None;
        self.rebuild();
    }

    fn rebuild(&mut self) {
        let m = self.schedule.batches.len();
        self.agg.clear_resize(m);
        let mut free = self.t0_ms;
        let mut start = 0usize;
        for k in 0..m {
            self.recompute_batch(k, start, free);
            free = self.agg.bend[k];
            start += self.schedule.batches[k];
        }
        self.reduce();
    }

    /// Recompute batch k's aggregates given the engine-free time `free`
    /// entering it: the batch's arrival max and timeline start first
    /// (written to `barr[k]` / `wait[k]`), then the same per-job order
    /// and accumulation as [`Evaluator::eval`]'s inner loop, plus the
    /// batch's KV demand under the configured phase model.
    fn recompute_batch(&mut self, k: usize, start: usize, free: f64) {
        let bsize = self.schedule.batches[k];
        let phased = self.kv.phased();
        let mut arr = f64::NEG_INFINITY;
        for &j in &self.schedule.order[start..start + bsize] {
            let a = self.table.arrival_ms(j);
            if a > arr {
                arr = a;
            }
        }
        let begin = TimelineOrigin::batch_start(free, arr);
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        let mut met = 0usize;
        let mut kvb = 0u64;
        if self.table.chunk_tokens() == 0 {
            // Batch-wide prefill for TTFT: the max-input member's table
            // row holds exactly `prefill_ms(bsize, max_in)` (entries are
            // stored predictor outputs), so this is bit-identical to the
            // full evaluator's direct predictor call. Ties don't matter:
            // equal inputs produce equal bits.
            let span = &self.schedule.order[start..start + bsize];
            let mut arg = span[0];
            for &j in &span[1..] {
                if self.jobs[j].input_len > self.jobs[arg].input_len {
                    arg = j;
                }
            }
            let batch_prefill = self.table.get(arg, bsize).prefill_ms;
            for &j in span {
                let job = &self.jobs[j];
                let p = self.table.get(j, bsize);
                let wait = begin - self.table.arrival_ms(j);
                let e2e = wait + p.exec_ms;
                let ttft = wait + batch_prefill;
                sum += e2e;
                if job.slo.met(e2e, ttft, p.tpot_ms) {
                    met += 1;
                }
                if p.exec_ms > max {
                    max = p.exec_ms;
                }
                if !phased {
                    kvb += self.table.kv_blocks(j);
                }
            }
        } else {
            // Chunked pricing (same two-pass accumulation order as the
            // full evaluator, so results stay bit-identical): pass A sums
            // the member chunk times; pass B re-walks the prefix sums for
            // per-member final-chunk completions.
            let mut chunk_total = 0.0f64;
            for &j in &self.schedule.order[start..start + bsize] {
                chunk_total += self.table.chunk_ms(j);
            }
            let mut offset = 0.0f64;
            for &j in &self.schedule.order[start..start + bsize] {
                let job = &self.jobs[j];
                let p = self.table.get(j, bsize);
                offset += self.table.chunk_ms(j);
                let wait = begin - self.table.arrival_ms(j);
                let exec = if job.output_len <= 1 {
                    offset
                } else {
                    chunk_total + (p.exec_ms - p.prefill_ms)
                };
                let e2e = wait + exec;
                let ttft = wait + offset;
                sum += e2e;
                if job.slo.met(e2e, ttft, p.tpot_ms) {
                    met += 1;
                }
                if exec > max {
                    max = exec;
                }
                if !phased {
                    kvb += self.table.kv_blocks(j);
                }
            }
        }
        if phased {
            // allocation-free closure form over the member span — one
            // shared peak implementation with the move veto and the
            // reference evaluator.
            let order = &self.schedule.order[start..start + bsize];
            kvb = kv::phased_peak_over(
                bsize,
                |i| {
                    let job = &self.jobs[order[i]];
                    (job.input_len, job.output_len)
                },
                self.kv.block_tokens,
            );
        }
        self.agg.barr[k] = arr;
        self.agg.wait[k] = begin;
        self.agg.bmax[k] = max;
        self.agg.bsum[k] = sum;
        self.agg.bmet[k] = met;
        self.agg.bkv[k] = kvb;
        // Same expression the sequential evaluation chains (`wait + bmax`),
        // cached so suffix walks and makespan read one column.
        self.agg.bend[k] = begin + max;
    }

    /// Re-reduce totals over per-batch partial columns — same grouping as
    /// the full evaluator, so the result is bit-identical. Each accumulator
    /// folds its own column in one tight pass (the accumulators are
    /// independent, so splitting the loop per column keeps every sequential
    /// summation order unchanged while letting the compiler vectorize the
    /// single-array walks).
    fn reduce(&mut self) {
        let m = self.schedule.batches.len();
        let mut total = 0.0f64;
        for &s in &self.agg.bsum {
            total += s;
        }
        let mut met = 0usize;
        for &c in &self.agg.bmet {
            met += c;
        }
        let mut excess = 0u64;
        for &b in &self.agg.bkv {
            excess += self.kv.batch_excess(b);
        }
        let makespan = if m == 0 { 0.0 } else { self.agg.bend[m - 1] };
        let g = if total > 0.0 { met as f64 / total } else { 0.0 };
        self.kv_excess = excess;
        self.eval = Eval { g, met, total_e2e_ms: total, makespan_ms: makespan };
    }

    /// Apply one random neighbourhood move in-place. Returns the candidate
    /// evaluation, or `None` if no move was possible (state untouched).
    /// Must be followed by [`IncrementalEval::commit`] or
    /// [`IncrementalEval::rollback`] before the next move.
    pub fn try_random_move(
        &mut self,
        max_batch: usize,
        rng: &mut Rng,
    ) -> Option<Eval> {
        self.try_random_move_masked(max_batch, 0, rng)
    }

    /// [`IncrementalEval::try_random_move`] with the first `frozen_batches`
    /// batches masked off (online admission: they are already dispatched).
    /// Masked moves never change the frozen prefix's membership, order, or
    /// boundaries, so its cached aggregates stay valid by construction.
    /// With `frozen_batches == 0` this is bit-identical (same RNG stream,
    /// same edits) to the unmasked path.
    pub fn try_random_move_masked(
        &mut self,
        max_batch: usize,
        frozen_batches: usize,
        rng: &mut Rng,
    ) -> Option<Eval> {
        self.try_random_move_windowed(max_batch, frozen_batches, 0, rng)
    }

    /// [`IncrementalEval::try_random_move_masked`] with the search further
    /// restricted to a sliding window of `window` batches beyond the
    /// frozen prefix (0 = unbounded). Windowed planning keeps the SA
    /// focused on the next `window` dispatches — the chunk-granular
    /// online mode — while batches beyond the window ride along
    /// untouched. With `window == 0` this is bit-identical (same RNG
    /// stream, same edits) to the masked path (invariant 15).
    pub fn try_random_move_windowed(
        &mut self,
        max_batch: usize,
        frozen_batches: usize,
        window: usize,
        rng: &mut Rng,
    ) -> Option<Eval> {
        debug_assert!(self.pending.is_none(), "move pending; commit or rollback");
        // Snapshot into reused buffers (no allocation once warm): the
        // batch boundaries plus a straight per-column copy of the SoA.
        self.saved_batches.clear();
        self.saved_batches.extend_from_slice(&self.schedule.batches);
        self.saved.copy_from(&self.agg);
        self.saved_kv_excess = self.kv_excess;
        self.saved_eval = self.eval;

        // Hard KV mode: the generator consults the live occupancy and
        // refuses overcommitting candidates before any mutation. With an
        // unlimited pool no veto is constructed and the RNG stream is the
        // pre-KV one.
        let veto = if self.kv.vetoes_moves() {
            Some(moves::KvVeto {
                job_blocks: self.table.kv_blocks_all(),
                batch_blocks: &self.agg.bkv,
                pool_blocks: self.kv.pool_blocks,
                phased: if self.kv.phased() {
                    Some(moves::PhasedVeto {
                        jobs: self.jobs,
                        block_tokens: self.kv.block_tokens,
                    })
                } else {
                    None
                },
            })
        } else {
            None
        };
        let mv = moves::random_move_desc_win(
            &mut self.schedule,
            max_batch,
            frozen_batches,
            window,
            veto.as_ref(),
            rng,
        )?;
        self.pending = Some(mv.undo);

        // Mirror the move's structural edits on the per-batch columns so
        // entry k still describes the batch now at index k.
        if let Some(r) = mv.removed_batch {
            self.agg.remove(r);
        }
        if mv.appended_batch {
            self.agg.push_zero();
        }
        let m = self.schedule.batches.len();
        debug_assert_eq!(self.agg.len(), m);

        // Engine-free time entering the first touched batch, derived from
        // the untouched prefix exactly as the sequential full evaluation
        // would (bend[k-1] caches batch k-1's start + bmax = end).
        let b_lo = mv.b_lo;
        let mut free = if b_lo == 0 {
            self.t0_ms
        } else {
            self.agg.bend[b_lo - 1]
        };
        let mut start: usize = self.schedule.batches[..b_lo].iter().sum();
        let mut k = b_lo;
        while k < m {
            let membership_changed = k == mv.b_lo || k == mv.b_hi;
            if !membership_changed
                && TimelineOrigin::batch_start(free, self.agg.barr[k])
                    == self.agg.wait[k]
            {
                if k > mv.b_hi {
                    // Unchanged membership (so barr and bmax are valid)
                    // and exactly unchanged start time: the whole
                    // remaining suffix is still valid.
                    break;
                }
                // Untouched batch between two swapped positions — cached
                // aggregates remain valid, just pass through.
            } else {
                // Membership changed (barr may have too) or the start
                // shifted: recompute everything at the new timeline slot.
                self.recompute_batch(k, start, free);
            }
            free = self.agg.bend[k];
            start += self.schedule.batches[k];
            k += 1;
        }
        self.reduce();
        Some(self.eval)
    }

    /// Accept the pending move (free — state is already updated).
    pub fn commit(&mut self) {
        self.pending = None;
    }

    /// Reject the pending move: restore schedule and aggregates to the
    /// pre-move state from the snapshot buffers.
    pub fn rollback(&mut self) {
        let undo = self.pending.take().expect("rollback without a pending move");
        undo.revert(&mut self.schedule.order);
        self.schedule.batches.clear();
        self.schedule.batches.extend_from_slice(&self.saved_batches);
        self.agg.copy_from(&self.saved);
        self.kv_excess = self.saved_kv_excess;
        self.eval = self.saved_eval;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};

    /// Predictor with trivially controllable costs:
    /// prefill = l_i ms, per-token decode = 1 ms (so exec = l_i + l_o).
    fn unit_predictor() -> LatencyPredictor {
        LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 1.0 },
        )
    }

    fn e2e_job(input: usize, output: usize, bound: f64) -> Job {
        Job {
            req_idx: 0,
            input_len: input,
            output_len: output,
            slo: Slo::E2e { e2e_ms: bound },
        }
    }

    #[test]
    fn schedule_fcfs_packing() {
        let s = Schedule::fcfs(7, 3);
        assert_eq!(s.batches, vec![3, 3, 1]);
        assert_eq!(s.order, (0..7).collect::<Vec<_>>());
        s.validate(3).unwrap();
        let exact = Schedule::fcfs(6, 3);
        assert_eq!(exact.batches, vec![3, 3]);
    }

    #[test]
    fn schedule_validation_catches_errors() {
        let mut s = Schedule::fcfs(4, 2);
        s.order[0] = 9;
        assert!(s.validate(2).is_err());
        let mut s = Schedule::fcfs(4, 2);
        s.order[1] = 0;
        assert!(s.validate(2).is_err());
        let mut s = Schedule::fcfs(4, 2);
        s.batches = vec![3, 1];
        assert!(s.validate(2).is_err()); // exceeds max
        let mut s = Schedule::fcfs(4, 2);
        s.batches = vec![2, 1];
        assert!(s.validate(2).is_err()); // sum mismatch
    }

    #[test]
    fn figure3_example() {
        // Paper Fig. 3: exec {300,500,800} ms, SLOs {800,500,1800} ms, bs=1.
        // (B) order 1,2,3 -> 2/3 met, Σe2e = 2700 -> G = 0.74 req/s.
        // (C) order 2,1,3 -> 3/3 met, Σe2e = 2900 -> G = 1.03 req/s.
        let pred = unit_predictor();
        let jobs = [
            e2e_job(300, 0, 800.0),
            e2e_job(500, 0, 500.0),
            e2e_job(800, 0, 1800.0),
        ];
        let ev = Evaluator::new(&jobs, &pred);

        let b = Schedule { order: vec![0, 1, 2], batches: vec![1, 1, 1] };
        let eb = ev.eval(&b);
        assert_eq!(eb.met, 2);
        assert!((eb.total_e2e_ms - 2700.0).abs() < 1e-9);
        assert!((eb.g * 1000.0 - 0.7407).abs() < 1e-3); // req/s

        let c = Schedule { order: vec![1, 0, 2], batches: vec![1, 1, 1] };
        let ec = ev.eval(&c);
        assert_eq!(ec.met, 3);
        assert!((ec.total_e2e_ms - 2900.0).abs() < 1e-9);
        assert!((ec.g * 1000.0 - 1.0345).abs() < 1e-3);
        assert!(ec.g > eb.g);
    }

    #[test]
    fn waiting_time_accumulates_batch_maxima() {
        let pred = unit_predictor();
        // batch 1: {100, 200} -> max 200; batch 2: {50}
        let jobs = [
            e2e_job(100, 0, 1e9),
            e2e_job(200, 0, 1e9),
            e2e_job(50, 0, 1e9),
        ];
        let ev = Evaluator::new(&jobs, &pred);
        let s = Schedule { order: vec![0, 1, 2], batches: vec![2, 1] };
        let (_, tl) = ev.eval_detailed(&s);
        assert_eq!(tl[0].wait_ms, 0.0);
        assert_eq!(tl[1].wait_ms, 0.0);
        assert!((tl[2].wait_ms - 200.0).abs() < 1e-9);
        assert_eq!(tl[2].batch, 1);
    }

    #[test]
    fn interactive_slo_uses_ttft_tpot() {
        let pred = unit_predictor();
        let jobs = [
            Job {
                req_idx: 0,
                input_len: 100,
                output_len: 10,
                slo: Slo::Interactive { ttft_ms: 100.0, tpot_ms: 1.0 },
            },
            e2e_job(50, 0, 1e9),
        ];
        let ev = Evaluator::new(&jobs, &pred);
        // job 0 first: ttft = 0 + 100 <= 100, tpot = 1.0 <= 1.0 -> met
        let s1 = Schedule { order: vec![0, 1], batches: vec![1, 1] };
        assert_eq!(ev.eval(&s1).met, 2);
        // job 0 second: waits 50 -> ttft = 150 > 100 -> missed
        let s2 = Schedule { order: vec![1, 0], batches: vec![1, 1] };
        assert_eq!(ev.eval(&s2).met, 1);
    }

    #[test]
    fn eval_matches_eval_detailed() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..9)
            .map(|i| e2e_job(100 + 37 * i, 30 + 11 * i, 20_000.0))
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let s = Schedule { order: (0..9).rev().collect(), batches: vec![4, 4, 1] };
        let a = ev.eval(&s);
        let (b, tl) = ev.eval_detailed(&s);
        assert_eq!(a, b);
        assert_eq!(tl.len(), 9);
        let sum: f64 = tl.iter().map(|t| t.wait_ms + t.exec_ms).sum();
        assert!((sum - a.total_e2e_ms).abs() < 1e-6);
    }

    #[test]
    fn batch_of_position_matches_spans() {
        let s = Schedule { order: (0..5).collect(), batches: vec![2, 2, 1] };
        let mut map = Vec::new();
        s.batch_of_position(&mut map);
        assert_eq!(map, vec![0, 0, 1, 1, 2]);
    }

    #[test]
    fn larger_batch_slows_everyone() {
        // Eq. 14/15 interaction term: batching raises per-request latency.
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..4).map(|_| e2e_job(500, 100, 1e12)).collect();
        let ev = Evaluator::new(&jobs, &pred);
        let batched = Schedule { order: (0..4).collect(), batches: vec![4] };
        let solo = Schedule { order: (0..4).collect(), batches: vec![1, 1, 1, 1] };
        let eb = ev.eval(&batched);
        let es = ev.eval(&solo);
        // batched: all see exec(b=4); solo: first sees exec(b=1) with no wait
        let (_, tlb) = ev.eval_detailed(&batched);
        let (_, tls) = ev.eval_detailed(&solo);
        assert!(tlb[0].exec_ms > tls[0].exec_ms);
        // but batching reduces makespan
        assert!(eb.makespan_ms < es.makespan_ms);
    }

    #[test]
    fn incremental_init_matches_full_eval() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..11)
            .map(|i| e2e_job(100 + 53 * i, 20 + 9 * i, 8_000.0))
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, 4);
        let s = Schedule { order: (0..11).rev().collect(), batches: vec![4, 4, 3] };
        let inc = IncrementalEval::new(&jobs, &table, s.clone());
        assert_eq!(inc.eval(), ev.eval(&s));
        assert_eq!(inc.schedule(), &s);
    }

    #[test]
    fn incremental_move_commit_and_rollback_match_full_eval() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..10)
            .map(|i| e2e_job(80 + 41 * i, 15 + 7 * i, 6_000.0))
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, 3);
        let mut inc =
            IncrementalEval::new(&jobs, &table, Schedule::fcfs(10, 3));
        let mut rng = Rng::new(42);
        for step in 0..200 {
            let before = inc.eval();
            let before_schedule = inc.schedule().clone();
            match inc.try_random_move(3, &mut rng) {
                None => continue,
                Some(e) => {
                    inc.schedule().validate(3).unwrap();
                    assert_eq!(e, ev.eval(inc.schedule()), "step {step}");
                    if step % 2 == 0 {
                        inc.commit();
                    } else {
                        inc.rollback();
                        assert_eq!(inc.eval(), before, "rollback step {step}");
                        assert_eq!(inc.schedule(), &before_schedule);
                    }
                }
            }
        }
    }

    #[test]
    fn incremental_masked_moves_match_full_eval_and_freeze_prefix() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..12)
            .map(|i| e2e_job(60 + 37 * i, 10 + 5 * i, 7_000.0))
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, 3);
        let mut inc =
            IncrementalEval::new(&jobs, &table, Schedule::fcfs(12, 3));
        let frozen = 2usize;
        let frozen_pos: usize =
            inc.schedule().batches[..frozen].iter().sum();
        let order_prefix = inc.schedule().order[..frozen_pos].to_vec();
        let batch_prefix = inc.schedule().batches[..frozen].to_vec();
        let mut rng = Rng::new(9);
        for step in 0..300 {
            match inc.try_random_move_masked(3, frozen, &mut rng) {
                None => continue,
                Some(e) => {
                    inc.schedule().validate(3).unwrap();
                    assert_eq!(e, ev.eval(inc.schedule()), "step {step}");
                    assert_eq!(
                        inc.schedule().order[..frozen_pos],
                        order_prefix[..],
                        "step {step}"
                    );
                    assert_eq!(
                        inc.schedule().batches[..frozen],
                        batch_prefix[..],
                        "step {step}"
                    );
                    if step % 3 == 0 {
                        inc.rollback();
                    } else {
                        inc.commit();
                    }
                }
            }
        }
    }

    #[test]
    fn incremental_kv_occupancy_matches_reference_after_moves() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..12)
            .map(|i| e2e_job(40 + 95 * i, 10 + 11 * i, 9_000.0))
            .collect();
        // soft mode: moves are NOT vetoed, so the walk visits
        // overcommitted states and the excess must track them exactly.
        let kv = KvConfig::soft(20, 1.0);
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build_kv(&jobs, &pred, 4, &kv);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            Schedule::fcfs(12, 4),
            kv,
            0.0,
        );
        let mut rng = Rng::new(77);
        for step in 0..300 {
            if let Some(e) = inc.try_random_move_masked(4, 0, &mut rng) {
                assert_eq!(e, ev.eval(inc.schedule()), "step {step}");
                assert_eq!(
                    inc.kv_excess(),
                    ev.kv_excess(inc.schedule(), &kv),
                    "step {step}"
                );
                if step % 3 == 0 {
                    inc.rollback();
                } else {
                    inc.commit();
                }
                // after commit or rollback the invariant must still hold
                assert_eq!(inc.kv_excess(), ev.kv_excess(inc.schedule(), &kv));
            }
        }
    }

    #[test]
    fn incremental_hard_mode_preserves_feasibility() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        // every job: 1..=4 blocks; FCFS at max_batch 3 must fit pool 12
        let jobs: Vec<Job> = (0..9)
            .map(|i| e2e_job(1 + 16 * (i % 4), 0, 9_000.0))
            .collect();
        let kv = KvConfig::hard(12);
        let table = PredTable::build_kv(&jobs, &pred, 3, &kv);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            Schedule::fcfs(9, 3),
            kv,
            0.0,
        );
        assert_eq!(inc.kv_excess(), 0, "seed must be feasible");
        let mut rng = Rng::new(13);
        for step in 0..400 {
            if inc.try_random_move_masked(3, 0, &mut rng).is_some() {
                assert_eq!(inc.kv_excess(), 0, "step {step}: veto leaked");
                for k in 0..inc.schedule().batches.len() {
                    assert!(inc.batch_kv_blocks(k) <= 12, "step {step}");
                }
                inc.commit();
            }
        }
    }

    #[test]
    #[allow(deprecated)] // with_base_wait stays green through the new path
    fn base_wait_shifts_every_entry_wait() {
        let pred = unit_predictor();
        let jobs = [e2e_job(100, 0, 1e9), e2e_job(200, 0, 1e9)];
        let shifted = Evaluator::with_base_wait(&jobs, &pred, 50.0);
        let plain = Evaluator::new(&jobs, &pred);
        assert_eq!(plain.base_wait_ms(), 0.0);
        assert_eq!(shifted.base_wait_ms(), 50.0);
        let s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
        let (es, tls) = shifted.eval_detailed(&s);
        let (ep, tlp) = plain.eval_detailed(&s);
        assert!((tls[0].wait_ms - 50.0).abs() < 1e-12);
        assert!((tls[1].wait_ms - (tlp[1].wait_ms + 50.0)).abs() < 1e-9);
        assert!((es.total_e2e_ms - (ep.total_e2e_ms + 100.0)).abs() < 1e-9);
        // incremental path agrees bit for bit with the shifted evaluator
        let table = PredTable::build(&jobs, &pred, 2);
        let mut inc =
            IncrementalEval::new_kv(&jobs, &table, s.clone(), Default::default(), 50.0);
        assert_eq!(inc.eval(), es);
        let mut rng = Rng::new(3);
        for _ in 0..60 {
            if let Some(e) = inc.try_random_move(2, &mut rng) {
                assert_eq!(e, shifted.eval(inc.schedule()));
                inc.commit();
            }
        }
    }

    #[test]
    fn timeline_models_idle_gaps_and_arrival_offsets() {
        // unit predictor: exec = input length in ms
        let pred = unit_predictor();
        let jobs = [
            e2e_job(100, 0, 1e9), // arrives at 0
            e2e_job(200, 0, 1e9), // arrives at 1000 (after batch 0 ends)
            e2e_job(50, 0, 1e9),  // arrives at 1100 (while batch 1 runs)
        ];
        let origin =
            TimelineOrigin { t0: 0.0, arrivals: vec![0.0, 1_000.0, 1_100.0] };
        let ev = Evaluator::with_timeline(&jobs, &pred, &origin);
        let s = Schedule { order: vec![0, 1, 2], batches: vec![1, 1, 1] };
        let (eval, tl) = ev.eval_detailed(&s);
        // batch 0: starts at t0 = 0, ends at 100
        assert_eq!(tl[0].start_ms, 0.0);
        assert_eq!(tl[0].wait_ms, 0.0);
        // batch 1: engine idle 100..1000 — starts at the arrival, not 100
        assert_eq!(tl[1].start_ms, 1_000.0);
        assert_eq!(tl[1].wait_ms, 0.0);
        // batch 2: engine busy until 1200 > arrival 1100 — waits 100
        assert_eq!(tl[2].start_ms, 1_200.0);
        assert!((tl[2].wait_ms - 100.0).abs() < 1e-9);
        // makespan is the absolute end of the last batch
        assert!((eval.makespan_ms - 1_250.0).abs() < 1e-9);
        // Σ e2e sums arrival-relative latencies
        assert!((eval.total_e2e_ms - (100.0 + 200.0 + 150.0)).abs() < 1e-9);
    }

    #[test]
    fn explicit_zero_arrivals_are_bit_identical_to_closed_wave() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..9)
            .map(|i| e2e_job(100 + 41 * i, 20 + 7 * i, 9_000.0))
            .collect();
        let zeros = vec![0.0; jobs.len()];
        let plain = Evaluator::new(&jobs, &pred);
        let timeline = Evaluator::with_arrivals(&jobs, &pred, 0.0, &zeros);
        let s = Schedule { order: (0..9).rev().collect(), batches: vec![4, 4, 1] };
        let a = plain.eval(&s);
        let b = timeline.eval(&s);
        assert_eq!(a.g.to_bits(), b.g.to_bits());
        assert_eq!(a.total_e2e_ms.to_bits(), b.total_e2e_ms.to_bits());
        assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
        assert_eq!(a.met, b.met);
    }

    #[test]
    fn incremental_matches_full_with_arrivals_after_moves() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> = (0..10)
            .map(|i| e2e_job(80 + 41 * i, 15 + 7 * i, 6_000.0))
            .collect();
        // staggered arrivals: every later job ~400 ms apart
        let arrivals: Vec<f64> = (0..10).map(|i| 400.0 * i as f64).collect();
        let ev = Evaluator::with_arrivals(&jobs, &pred, 120.0, &arrivals);
        let mut table = PredTable::build(&jobs, &pred, 3);
        table.set_arrivals(&arrivals);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            Schedule::fcfs(10, 3),
            Default::default(),
            120.0,
        );
        assert_eq!(inc.eval(), ev.eval(inc.schedule()));
        let mut rng = Rng::new(42);
        for step in 0..300 {
            match inc.try_random_move(3, &mut rng) {
                None => continue,
                Some(e) => {
                    assert_eq!(e, ev.eval(inc.schedule()), "step {step}");
                    if step % 2 == 0 {
                        inc.commit();
                    } else {
                        inc.rollback();
                        assert_eq!(inc.eval(), ev.eval(inc.schedule()));
                    }
                }
            }
        }
    }

    #[test]
    fn phased_demand_tracked_through_moves() {
        use crate::coordinator::kv::{KvConfig, KvPhaseModel};
        let pred = LatencyPredictor::paper_table2();
        // staggered outputs so phased < reserve on mixed batches
        let jobs: Vec<Job> = (0..10)
            .map(|i| e2e_job(40 + 60 * i, 5 + 37 * (i % 4), 9_000.0))
            .collect();
        let kv = KvConfig::soft(18, 1.0).with_phase(KvPhaseModel::Phased);
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build_kv(&jobs, &pred, 4, &kv);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            Schedule::fcfs(10, 4),
            kv,
            0.0,
        );
        let mut rng = Rng::new(5);
        for step in 0..300 {
            if let Some(e) = inc.try_random_move_masked(4, 0, &mut rng) {
                assert_eq!(e, ev.eval(inc.schedule()), "step {step}");
                assert_eq!(
                    inc.kv_excess(),
                    ev.kv_excess(inc.schedule(), &kv),
                    "step {step}: phased excess drifted"
                );
                // phased demand never exceeds the reserve sum
                let reserve = kv.with_phase(KvPhaseModel::Reserve);
                assert!(
                    ev.kv_excess(inc.schedule(), &kv)
                        <= ev.kv_excess(inc.schedule(), &reserve)
                );
                if step % 3 == 0 {
                    inc.rollback();
                } else {
                    inc.commit();
                }
                assert_eq!(inc.kv_excess(), ev.kv_excess(inc.schedule(), &kv));
            }
        }
    }

    #[test]
    fn incremental_reset_rebuilds() {
        let pred = unit_predictor();
        let jobs = [e2e_job(100, 0, 1e9), e2e_job(200, 0, 1e9)];
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, 2);
        let mut inc = IncrementalEval::new(&jobs, &table, Schedule::fcfs(2, 2));
        let solo = Schedule { order: vec![1, 0], batches: vec![1, 1] };
        inc.reset(solo.clone());
        assert_eq!(inc.eval(), ev.eval(&solo));
        assert_eq!(inc.into_schedule(), solo);
    }
}
