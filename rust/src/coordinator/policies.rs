//! Scheduling policies: the SLO-aware mapper plus the baselines it is
//! evaluated against (paper §2.2, §5.1).
//!
//! * `Fcfs`       — vLLM/LMDeploy behaviour: arrival order, engine-packed
//!                  maximal batches, no SLO awareness.
//! * `Sjf`        — shortest predicted execution first (no SLO awareness).
//! * `Edf`        — earliest deadline first over the SLO bound.
//! * `Mlfq`       — FastServe-like: priority from *input length only*
//!                  (its skip-join MLFQ assigns queues by prompt length).
//! * `SloAware`   — Algorithm 1 (simulated annealing).
//! * `Exhaustive` — the optimality strawman (small N only).
//!
//! Two structure-exploiting baselines from the "Optimal Scheduling
//! Algorithms for LLM Inference: Theory and Practice" line of work
//! (PAPERS.md) round out the gap harness — cheap index/threshold rules
//! the search must beat to justify its overhead:
//!
//! * `SlackIndex`    — static laxity index: jobs sorted by
//!                     `(deadline − solo exec) / solo exec` ascending
//!                     (least relative slack first), greedily packed.
//!                     O(N log N), SLO- and predictor-aware but blind to
//!                     batch interaction.
//! * `EdfThreshold`  — EDF order with a *threshold-style batching rule*:
//!                     one static batch size `k`, chosen as the argmax of
//!                     the evaluated objective over `k ∈ 1..=max_batch`
//!                     (first maximizer wins). O(N·max_batch) evaluator
//!                     calls — the cheapest policy that adapts batch
//!                     geometry to load.

use crate::coordinator::objective::{Evaluator, Job, Schedule};
use crate::coordinator::priority::annealing::{
    priority_mapping, SaParams, SearchStats,
};
use crate::coordinator::priority::exhaustive::exhaustive_mapping;
use crate::coordinator::request::Slo;

/// Policy selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    Fcfs,
    Sjf,
    Edf,
    Mlfq,
    SlackIndex,
    EdfThreshold,
    SloAware(SaParams),
    Exhaustive,
}

/// Deadline an SLO is urgent against: the e2e bound, or TTFT for
/// interactive SLOs. Shared by `Edf`, the slack index, and the
/// preemption/migration layers (`online`, `scheduler`), so every
/// slack-ordered decision measures urgency against the same bound.
pub fn slo_deadline_ms(slo: &Slo) -> f64 {
    match *slo {
        Slo::E2e { e2e_ms } => e2e_ms,
        Slo::Interactive { ttft_ms, .. } => ttft_ms,
    }
}

/// Deadline a job is urgent against ([`slo_deadline_ms`] of its SLO).
fn deadline(j: &Job) -> f64 {
    slo_deadline_ms(&j.slo)
}

/// The `SlackIndex` ordering key: relative laxity
/// `(deadline − exec) / exec`, both measured from the same origin
/// ("now" for a queued job, the current clock for a running one).
/// Smaller is more urgent; ±inf/NaN degenerate inputs stay total under
/// `f64::total_cmp`. Shared verbatim with the engine's preemption victim
/// selection (`engine/sim.rs`), so victim choice and the scheduling
/// baseline agree on what "slack" means.
pub fn slack_key(deadline_ms: f64, exec_ms: f64) -> f64 {
    (deadline_ms - exec_ms) / exec_ms
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Sjf => "sjf",
            Policy::Edf => "edf",
            Policy::Mlfq => "mlfq",
            Policy::SlackIndex => "slack-index",
            Policy::EdfThreshold => "edf-threshold",
            Policy::SloAware(_) => "slo-aware-sa",
            Policy::Exhaustive => "slo-aware-exhaustive",
        }
    }

    /// Produce an execution plan for `jobs` (indices local to the slice).
    ///
    /// Returns the schedule and, where applicable, search statistics.
    pub fn plan(
        &self,
        ev: &Evaluator,
        max_batch: usize,
    ) -> (Schedule, Option<SearchStats>) {
        let n = ev.jobs().len();
        match self {
            Policy::Fcfs => (Schedule::fcfs(n, max_batch), None),
            Policy::Sjf => {
                // total_cmp (not partial_cmp().unwrap()): a degenerate
                // predictor fit can yield NaN solo-e2e values, which must
                // degrade the ordering, not panic the scheduler — the same
                // rule the SA seed sort and assign_instances follow.
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    ev.solo_e2e_ms(a).total_cmp(&ev.solo_e2e_ms(b))
                });
                (Schedule::from_order(order, max_batch), None)
            }
            Policy::Edf => {
                let mut order: Vec<usize> = (0..n).collect();
                // total_cmp for the same NaN-safety as Sjf (SLO bounds are
                // caller-supplied floats).
                order.sort_by(|&a, &b| {
                    deadline(&ev.jobs()[a]).total_cmp(&deadline(&ev.jobs()[b]))
                });
                (Schedule::from_order(order, max_batch), None)
            }
            Policy::Mlfq => {
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by_key(|&a| ev.jobs()[a].input_len);
                (Schedule::from_order(order, max_batch), None)
            }
            Policy::SlackIndex => {
                // Least relative slack first: (deadline − solo exec) /
                // solo exec ascending. A zero/degenerate solo exec yields
                // ±inf or NaN — total_cmp keeps the order total (the PR 5
                // NaN rule), no special-casing.
                let slack = |j: usize| {
                    let e = ev.solo_e2e_ms(j);
                    slack_key(deadline(&ev.jobs()[j]), e)
                };
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| slack(a).total_cmp(&slack(b)));
                (Schedule::from_order(order, max_batch), None)
            }
            Policy::EdfThreshold => {
                let t_start = crate::util::now_ms();
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    deadline(&ev.jobs()[a]).total_cmp(&deadline(&ev.jobs()[b]))
                });
                // Threshold rule: one static batch size, the first
                // k ∈ 1..=max_batch maximizing the evaluated objective
                // (strict > replacement, so ties keep the smallest k).
                let mut best: Option<(Schedule, f64)> = None;
                let mut evals = 0usize;
                for k in 1..=max_batch.max(1) {
                    let s = Schedule::from_order(order.clone(), k);
                    let g = ev.eval(&s).g;
                    evals += 1;
                    let better = match &best {
                        None => true,
                        Some((_, bg)) => g > *bg,
                    };
                    if better {
                        best = Some((s, g));
                    }
                }
                let overhead_ms = crate::util::now_ms() - t_start;
                let stats = SearchStats {
                    evals,
                    accepted: 0,
                    improved: 0,
                    early_exit: false,
                    overhead_ms,
                    cpu_ms: overhead_ms,
                    exchanges: 0,
                    winner_chain: 0,
                };
                let (s, _) = best.expect("max_batch >= 1 always evaluates");
                (s, Some(stats))
            }
            Policy::SloAware(params) => {
                let params = SaParams { max_batch, ..*params };
                let res = priority_mapping(ev, &params);
                (res.schedule, Some(res.stats))
            }
            Policy::Exhaustive => {
                match exhaustive_mapping(ev, max_batch) {
                    Some(res) => {
                        let stats = SearchStats {
                            evals: res.evals,
                            accepted: 0,
                            improved: 0,
                            early_exit: false,
                            overhead_ms: res.overhead_ms,
                            cpu_ms: res.overhead_ms,
                            exchanges: 0,
                            winner_chain: 0,
                        };
                        (res.schedule, Some(stats))
                    }
                    // fall back to FCFS beyond the feasibility cap
                    None => (Schedule::fcfs(n, max_batch), None),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
    use crate::coordinator::request::Slo;

    fn unit_predictor() -> LatencyPredictor {
        LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 1.0 },
        )
    }

    fn jobs() -> Vec<Job> {
        vec![
            Job { req_idx: 0, input_len: 500, output_len: 0, slo: Slo::E2e { e2e_ms: 900.0 } },
            Job { req_idx: 1, input_len: 100, output_len: 0, slo: Slo::E2e { e2e_ms: 5000.0 } },
            Job {
                req_idx: 2,
                input_len: 300,
                output_len: 10,
                slo: Slo::Interactive { ttft_ms: 400.0, tpot_ms: 50.0 },
            },
        ]
    }

    #[test]
    fn fcfs_keeps_arrival_order() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        let (s, stats) = Policy::Fcfs.plan(&ev, 2);
        assert_eq!(s.order, vec![0, 1, 2]);
        assert_eq!(s.batches, vec![2, 1]);
        assert!(stats.is_none());
    }

    #[test]
    fn sjf_sorts_by_predicted_exec() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        let (s, _) = Policy::Sjf.plan(&ev, 1);
        // exec: j0=500, j1=100, j2=310
        assert_eq!(s.order, vec![1, 2, 0]);
    }

    #[test]
    fn edf_sorts_by_deadline() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        let (s, _) = Policy::Edf.plan(&ev, 1);
        // deadlines: j0=900, j1=5000, j2=400 (ttft)
        assert_eq!(s.order, vec![2, 0, 1]);
    }

    #[test]
    fn mlfq_sorts_by_input_len() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        let (s, _) = Policy::Mlfq.plan(&ev, 1);
        assert_eq!(s.order, vec![1, 2, 0]);
    }

    #[test]
    fn slo_aware_beats_or_matches_fcfs() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        let (fcfs, _) = Policy::Fcfs.plan(&ev, 1);
        let (sa, stats) =
            Policy::SloAware(SaParams::default()).plan(&ev, 1);
        assert!(ev.eval(&sa).g >= ev.eval(&fcfs).g);
        assert!(stats.is_some());
    }

    #[test]
    fn exhaustive_fallback_beyond_cap() {
        let pred = unit_predictor();
        let js: Vec<Job> = (0..20)
            .map(|i| Job {
                req_idx: i,
                input_len: 10,
                output_len: 0,
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let ev = Evaluator::new(&js, &pred);
        let (s, stats) = Policy::Exhaustive.plan(&ev, 2);
        assert_eq!(s.order, (0..20).collect::<Vec<_>>()); // FCFS fallback
        assert!(stats.is_none());
    }

    #[test]
    fn slack_index_orders_by_relative_slack() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        // solo exec: j0=500, j1=100, j2=310; deadlines: 900, 5000, 400
        // slack: j0=(900-500)/500=0.8, j1=49.0, j2=(400-310)/310≈0.29
        let (s, stats) = Policy::SlackIndex.plan(&ev, 1);
        assert_eq!(s.order, vec![2, 0, 1]);
        assert!(stats.is_none());
    }

    #[test]
    fn edf_threshold_dominates_plain_edf() {
        // Edf-at-max-batch is one of the threshold rule's candidates
        // (k = max_batch over the same order), so it can never win.
        let pred = LatencyPredictor::paper_table2();
        let js: Vec<Job> = (0..8)
            .map(|i| Job {
                req_idx: i,
                input_len: 100 + 173 * i,
                output_len: 20 + 31 * i,
                slo: Slo::E2e { e2e_ms: 2_000.0 + 911.0 * i as f64 },
            })
            .collect();
        let ev = Evaluator::new(&js, &pred);
        for mb in [1usize, 2, 4] {
            let (edf, _) = Policy::Edf.plan(&ev, mb);
            let (thr, stats) = Policy::EdfThreshold.plan(&ev, mb);
            thr.validate(mb).unwrap();
            assert!(ev.eval(&thr).g >= ev.eval(&edf).g);
            assert_eq!(stats.unwrap().evals, mb);
        }
    }

    #[test]
    fn sjf_survives_degenerate_and_nan_predictors() {
        // Regression (PR 5): Sjf used partial_cmp().unwrap(), which
        // panicked whenever a degenerate fit produced NaN solo-e2e.
        let js = jobs();
        // all-zero coefficients: every solo e2e is 0.0 — ordering must be
        // total (stable schedule, no panic) and valid
        let zero = LatencyPredictor::new(PhaseCoeffs::ZERO, PhaseCoeffs::ZERO);
        let ev = Evaluator::new(&js, &zero);
        let (s, _) = Policy::Sjf.plan(&ev, 2);
        s.validate(2).unwrap();
        assert_eq!(s.order, vec![0, 1, 2]); // ties keep index order
        // NaN coefficients (0·NaN propagates): must not panic either
        let nan = LatencyPredictor::new(
            PhaseCoeffs { alpha: f64::NAN, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: f64::NAN, gamma: 0.0, delta: 1.0 },
        );
        let ev = Evaluator::new(&js, &nan);
        let (s, _) = Policy::Sjf.plan(&ev, 2);
        s.validate(2).unwrap();
        // Edf shares the total ordering rule for NaN SLO bounds
        let mut weird = jobs();
        weird[1].slo = Slo::E2e { e2e_ms: f64::NAN };
        let ev = Evaluator::new(&weird, &zero);
        let (s, _) = Policy::Edf.plan(&ev, 2);
        s.validate(2).unwrap();
        // the index/threshold policies inherit the same totality: a zero
        // solo exec makes the slack index ±inf (or NaN for 0/0), and the
        // threshold rule evaluates NaN objectives — neither may panic
        for policy in [Policy::SlackIndex, Policy::EdfThreshold] {
            let (s, _) = policy.plan(&ev, 2);
            s.validate(2).unwrap_or_else(|e| {
                panic!("{} under degenerate predictor: {e}", policy.name())
            });
        }
    }

    #[test]
    fn slack_key_matches_inline_formula_bitwise() {
        // The factored-out key must be the PR 8 inline arithmetic, bit
        // for bit — the SlackIndex ordering and the engine's preemption
        // victim selection both hang off it.
        for (d, e) in [
            (900.0f64, 500.0f64),
            (5000.0, 100.0),
            (400.0, 310.0),
            (0.0, 0.0),      // NaN stays NaN
            (1.0, 0.0),      // +inf
            (-3.5, 7.25),
            (f64::INFINITY, 12.0),
        ] {
            let inline = (d - e) / e;
            let keyed = slack_key(d, e);
            assert_eq!(inline.to_bits(), keyed.to_bits(), "d={d} e={e}");
        }
    }

    #[test]
    fn all_policies_produce_valid_schedules() {
        let pred = unit_predictor();
        let js = jobs();
        let ev = Evaluator::new(&js, &pred);
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::Edf,
            Policy::Mlfq,
            Policy::SlackIndex,
            Policy::EdfThreshold,
            Policy::SloAware(SaParams::default()),
            Policy::Exhaustive,
        ] {
            let (s, _) = policy.plan(&ev, 2);
            s.validate(2).unwrap_or_else(|e| {
                panic!("{} produced invalid schedule: {e}", policy.name())
            });
        }
    }
}
