//! Exhaustive-search priority mapping (the paper's strawman, §4.3).
//!
//! Enumerates every permutation of the execution order (Heap's algorithm)
//! × every batch composition with parts ≤ max_batch, evaluating `G` for
//! each — `O(N! · 2^N)` total. Used as the optimality baseline in Fig. 7 and
//! the overhead comparison in Table 1; infeasible beyond ~10 requests
//! (the paper stops displaying it at 8–10).

use crate::coordinator::objective::{Eval, Evaluator, Schedule};

/// Hard cap to protect callers from accidental factorial blow-up.
pub const MAX_EXHAUSTIVE_N: usize = 11;

/// Result of the exhaustive search.
#[derive(Debug, Clone)]
pub struct ExhaustiveResult {
    pub schedule: Schedule,
    pub eval: Eval,
    /// Number of (permutation × composition) candidates evaluated.
    pub evals: usize,
    pub overhead_ms: f64,
}

/// Enumerate all compositions of `n` into parts in `1..=max_batch`.
pub fn batch_compositions(n: usize, max_batch: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    fn rec(
        remaining: usize,
        max_batch: usize,
        cur: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if remaining == 0 {
            out.push(cur.clone());
            return;
        }
        for part in 1..=max_batch.min(remaining) {
            cur.push(part);
            rec(remaining - part, max_batch, cur, out);
            cur.pop();
        }
    }
    rec(n, max_batch.max(1), &mut cur, &mut out);
    out
}

/// Exhaustively search for the schedule maximizing `G`.
///
/// Returns None if `n > MAX_EXHAUSTIVE_N` (caller should fall back to SA).
pub fn exhaustive_mapping(
    ev: &Evaluator,
    max_batch: usize,
) -> Option<ExhaustiveResult> {
    let n = ev.jobs().len();
    if n > MAX_EXHAUSTIVE_N {
        return None;
    }
    let t_start = crate::util::now_ms();
    if n == 0 {
        return Some(ExhaustiveResult {
            schedule: Schedule { order: vec![], batches: vec![] },
            eval: Eval { g: 0.0, met: 0, total_e2e_ms: 0.0, makespan_ms: 0.0 },
            evals: 0,
            overhead_ms: crate::util::now_ms() - t_start,
        });
    }

    let compositions = batch_compositions(n, max_batch);
    let mut best: Option<(Schedule, Eval)> = None;
    let mut evals = 0usize;

    // Heap's algorithm over the order; for each permutation, try every
    // batch composition.
    let mut order: Vec<usize> = (0..n).collect();
    let mut c = vec![0usize; n];
    let mut candidate =
        Schedule { order: order.clone(), batches: Vec::new() };

    let consider = |order: &[usize],
                        candidate: &mut Schedule,
                        best: &mut Option<(Schedule, Eval)>,
                        evals: &mut usize| {
        for comp in &compositions {
            candidate.order.clear();
            candidate.order.extend_from_slice(order);
            candidate.batches.clear();
            candidate.batches.extend_from_slice(comp);
            let eval = ev.eval(candidate);
            *evals += 1;
            let better = match best {
                None => true,
                Some((_, b)) => eval.g > b.g,
            };
            if better {
                *best = Some((candidate.clone(), eval));
            }
        }
    };

    consider(&order, &mut candidate, &mut best, &mut evals);
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                order.swap(0, i);
            } else {
                order.swap(c[i], i);
            }
            consider(&order, &mut candidate, &mut best, &mut evals);
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }

    let (schedule, eval) = best.unwrap();
    Some(ExhaustiveResult {
        schedule,
        eval,
        evals,
        overhead_ms: crate::util::now_ms() - t_start,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::objective::Job;
    use crate::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
    use crate::coordinator::priority::annealing::{
        priority_mapping, SaParams,
    };
    use crate::coordinator::request::Slo;
    use crate::util::rng::Rng;

    fn unit_predictor() -> LatencyPredictor {
        LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 1.0 },
        )
    }

    #[test]
    fn compositions_counts() {
        // parts ≤ 1: exactly one composition
        assert_eq!(batch_compositions(5, 1), vec![vec![1; 5]]);
        // parts ≤ 2 of n follow Fibonacci: n=4 -> 5
        assert_eq!(batch_compositions(4, 2).len(), 5);
        // parts ≤ n: 2^(n-1) compositions
        assert_eq!(batch_compositions(5, 5).len(), 16);
        // all compositions sum to n and respect the cap
        for comp in batch_compositions(6, 3) {
            assert_eq!(comp.iter().sum::<usize>(), 6);
            assert!(comp.iter().all(|&p| (1..=3).contains(&p)));
        }
    }

    #[test]
    fn finds_figure3_optimum() {
        let pred = unit_predictor();
        let jobs = vec![
            Job { req_idx: 0, input_len: 300, output_len: 0, slo: Slo::E2e { e2e_ms: 800.0 } },
            Job { req_idx: 1, input_len: 500, output_len: 0, slo: Slo::E2e { e2e_ms: 500.0 } },
            Job { req_idx: 2, input_len: 800, output_len: 0, slo: Slo::E2e { e2e_ms: 1800.0 } },
        ];
        let ev = Evaluator::new(&jobs, &pred);
        let res = exhaustive_mapping(&ev, 1).unwrap();
        assert_eq!(res.eval.met, 3);
        assert_eq!(res.schedule.order, vec![1, 0, 2]);
        assert_eq!(res.evals, 6); // 3! perms × 1 composition
    }

    #[test]
    fn refuses_oversized_input() {
        let pred = unit_predictor();
        let jobs: Vec<Job> = (0..MAX_EXHAUSTIVE_N + 1)
            .map(|i| Job {
                req_idx: i,
                input_len: 10,
                output_len: 0,
                slo: Slo::E2e { e2e_ms: 1e9 },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        assert!(exhaustive_mapping(&ev, 1).is_none());
    }

    #[test]
    fn sa_within_one_percent_of_exhaustive() {
        // The paper reports SA ≤1.0% worse than exhaustive across tests.
        let pred = LatencyPredictor::paper_table2();
        let mut worst_ratio: f64 = 1.0;
        for seed in 0..6u64 {
            let mut rng = Rng::new(seed);
            let jobs: Vec<Job> = (0..6)
                .map(|i| Job {
                    req_idx: i,
                    input_len: rng.range(50, 1200) as usize,
                    output_len: rng.range(10, 300) as usize,
                    slo: Slo::E2e {
                        e2e_ms: rng.uniform(1_500.0, 20_000.0),
                    },
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let ex = exhaustive_mapping(&ev, 2).unwrap();
            let sa = priority_mapping(
                &ev,
                &SaParams { max_batch: 2, seed, ..Default::default() },
            );
            assert!(sa.eval.g <= ex.eval.g + 1e-15, "SA beat exhaustive?!");
            if ex.eval.g > 0.0 {
                worst_ratio = worst_ratio.min(sa.eval.g / ex.eval.g);
            }
        }
        assert!(
            worst_ratio >= 0.99,
            "SA degradation {:.2}% > 1%",
            (1.0 - worst_ratio) * 100.0
        );
    }

    #[test]
    fn empty_input_ok() {
        let pred = unit_predictor();
        let jobs: Vec<Job> = vec![];
        let ev = Evaluator::new(&jobs, &pred);
        let res = exhaustive_mapping(&ev, 4).unwrap();
        assert_eq!(res.evals, 0);
    }
}
