//! Equivalence guarantee of the SA hot path: after ANY sequence of
//! neighbourhood moves, the incremental evaluator's `Eval` must be
//! **bit-identical** (`==` on every field, not merely close) to a fresh
//! full `Evaluator::eval` of the same schedule — across random wave sizes,
//! `max_batch`, SLO mixes (`E2e` and `Interactive`), and predictor
//! coefficient sets. Rollback must restore both the schedule and the
//! evaluation exactly.
//!
//! Thousands of random move sequences run per test (see the case counts);
//! replay a failure with `PROP_SEED=<n>` as printed by the harness.

use slo_serve::coordinator::kv::{KvConfig, KvPhaseModel};
use slo_serve::coordinator::objective::{
    Evaluator, IncrementalEval, Job, Schedule,
};
use slo_serve::coordinator::pred_table::PredTable;
use slo_serve::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
use slo_serve::coordinator::priority::annealing::{
    priority_mapping, priority_mapping_full, SaParams,
};
use slo_serve::coordinator::request::Slo;
use slo_serve::util::prop::check;
use slo_serve::util::rng::Rng;

fn random_coeffs(rng: &mut Rng, scale: f64) -> PhaseCoeffs {
    PhaseCoeffs {
        alpha: rng.uniform(0.0, 0.5) * scale,
        beta: rng.uniform(0.0, 8.0) * scale,
        gamma: rng.uniform(0.0, 0.05) * scale,
        delta: rng.uniform(0.0, 60.0) * scale,
    }
}

fn random_predictor(rng: &mut Rng) -> LatencyPredictor {
    LatencyPredictor::new(
        random_coeffs(rng, 1.0),
        random_coeffs(rng, 0.02),
    )
}

fn random_jobs(rng: &mut Rng, n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            req_idx: i,
            input_len: 1 + rng.below(2000),
            output_len: rng.below(400),
            slo: if rng.chance(0.5) {
                Slo::E2e { e2e_ms: rng.uniform(100.0, 60_000.0) }
            } else {
                Slo::Interactive {
                    ttft_ms: rng.uniform(100.0, 15_000.0),
                    tpot_ms: rng.uniform(5.0, 60.0),
                }
            },
        })
        .collect()
}

fn random_start(rng: &mut Rng, n: usize, max_batch: usize) -> Schedule {
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    Schedule::from_order(order, max_batch)
}

#[test]
fn incremental_eval_bit_identical_to_full_eval_after_every_move() {
    // 250 cases × up to 80 moves ≈ 20k random move applications.
    check("incremental == full after every move", 250, |rng| {
        let n = 1 + rng.below(28);
        let max_batch = 1 + rng.below(8);
        let pred = random_predictor(rng);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, max_batch);
        let mut inc =
            IncrementalEval::new(&jobs, &table, random_start(rng, n, max_batch));
        // initial state must already agree
        if inc.eval() != ev.eval(inc.schedule()) {
            return Err(format!(
                "init mismatch: inc {:?} full {:?}",
                inc.eval(),
                ev.eval(inc.schedule())
            ));
        }
        for step in 0..80 {
            let pre_eval = inc.eval();
            let pre_schedule = inc.schedule().clone();
            let moved = match inc.try_random_move(max_batch, rng) {
                None => {
                    if inc.schedule() != &pre_schedule {
                        return Err("failed move mutated schedule".into());
                    }
                    continue;
                }
                Some(e) => e,
            };
            inc.schedule()
                .validate(max_batch)
                .map_err(|e| format!("step {step}: invalid schedule: {e}"))?;
            let full = ev.eval(inc.schedule());
            if moved != full {
                return Err(format!(
                    "step {step} (n={n} mb={max_batch}): incremental {moved:?} \
                     != full {full:?} for {:?}",
                    inc.schedule()
                ));
            }
            if rng.chance(0.5) {
                inc.commit();
            } else {
                inc.rollback();
                if inc.schedule() != &pre_schedule {
                    return Err(format!(
                        "step {step}: rollback changed schedule: {:?} != {:?}",
                        inc.schedule(),
                        pre_schedule
                    ));
                }
                if inc.eval() != pre_eval {
                    return Err(format!(
                        "step {step}: rollback eval {:?} != {pre_eval:?}",
                        inc.eval()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn incremental_eval_survives_long_committed_walks() {
    // All-commit walks drift far from the initial partition; the aggregates
    // must never decay. Checked sparsely to keep full evals cheap.
    check("long committed walk stays exact", 60, |rng| {
        let n = 8 + rng.below(40);
        let max_batch = 1 + rng.below(6);
        let pred = random_predictor(rng);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let table = PredTable::build(&jobs, &pred, max_batch);
        let mut inc =
            IncrementalEval::new(&jobs, &table, random_start(rng, n, max_batch));
        for step in 0..400 {
            if inc.try_random_move(max_batch, rng).is_some() {
                inc.commit();
            }
            if step % 40 == 0 {
                let full = ev.eval(inc.schedule());
                if inc.eval() != full {
                    return Err(format!(
                        "step {step}: drift: inc {:?} != full {full:?}",
                        inc.eval()
                    ));
                }
            }
        }
        let full = ev.eval(inc.schedule());
        if inc.eval() != full {
            return Err(format!("final drift: inc {:?} != {full:?}", inc.eval()));
        }
        Ok(())
    });
}

#[test]
fn incremental_eval_matches_full_on_random_timelines() {
    // The arrival-aware timeline (TimelineOrigin) must keep the
    // incremental == full guarantee: random arrivals, random t0, random
    // moves — every field exactly equal after every move.
    check("incremental == full on random timelines", 150, |rng| {
        let n = 1 + rng.below(24);
        let max_batch = 1 + rng.below(6);
        let pred = random_predictor(rng);
        let jobs = random_jobs(rng, n);
        let t0 = rng.uniform(0.0, 500.0);
        let arrivals: Vec<f64> =
            (0..n).map(|_| rng.uniform(0.0, 5_000.0)).collect();
        let ev = Evaluator::with_arrivals(&jobs, &pred, t0, &arrivals);
        let mut table = PredTable::build(&jobs, &pred, max_batch);
        table.set_arrivals(&arrivals);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            random_start(rng, n, max_batch),
            Default::default(),
            t0,
        );
        if inc.eval() != ev.eval(inc.schedule()) {
            return Err(format!(
                "init mismatch: inc {:?} full {:?}",
                inc.eval(),
                ev.eval(inc.schedule())
            ));
        }
        for step in 0..60 {
            let moved = match inc.try_random_move(max_batch, rng) {
                None => continue,
                Some(e) => e,
            };
            let full = ev.eval(inc.schedule());
            if moved != full {
                return Err(format!(
                    "step {step} (n={n} mb={max_batch} t0={t0}): \
                     incremental {moved:?} != full {full:?}"
                ));
            }
            if rng.chance(0.5) {
                inc.commit();
            } else {
                inc.rollback();
                if inc.eval() != ev.eval(inc.schedule()) {
                    return Err(format!("step {step}: rollback drifted"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn soa_incremental_matches_full_across_the_kv_grid() {
    // Regression gate for the struct-of-arrays aggregate store: random
    // timelines × {Reserve, Phased} × {Unlimited, Hard, Soft} — every
    // per-column aggregate must keep the incremental Eval AND the KV
    // excess bit-identical to the full reference after every move,
    // commit, and rollback.
    check("SoA incremental == full across the KV grid", 120, |rng| {
        let n = 1 + rng.below(20);
        let max_batch = 1 + rng.below(6);
        let pred = random_predictor(rng);
        let jobs = random_jobs(rng, n);
        let t0 = rng.uniform(0.0, 500.0);
        let arrivals: Vec<f64> =
            (0..n).map(|_| rng.uniform(0.0, 5_000.0)).collect();
        let pool = 1 + rng.below(4_000) as u64;
        let base = match rng.below(3) {
            0 => KvConfig::UNLIMITED,
            1 => KvConfig::hard(pool),
            _ => KvConfig::soft(pool, rng.uniform(1e-6, 1e-3)),
        };
        let kv = if rng.chance(0.5) {
            base.with_phase(KvPhaseModel::Phased)
        } else {
            base
        };
        let ev = Evaluator::with_arrivals(&jobs, &pred, t0, &arrivals);
        let mut table = PredTable::build_kv(&jobs, &pred, max_batch, &kv);
        table.set_arrivals(&arrivals);
        let mut inc = IncrementalEval::new_kv(
            &jobs,
            &table,
            random_start(rng, n, max_batch),
            kv,
            t0,
        );
        let tag = format!("n={n} mb={max_batch} kv={kv:?}");
        if inc.eval() != ev.eval(inc.schedule())
            || inc.kv_excess() != ev.kv_excess(inc.schedule(), &kv)
        {
            return Err(format!("init mismatch ({tag})"));
        }
        for step in 0..50 {
            let pre_eval = inc.eval();
            let pre_excess = inc.kv_excess();
            let pre_schedule = inc.schedule().clone();
            let moved = match inc.try_random_move(max_batch, rng) {
                None => continue,
                Some(e) => e,
            };
            let full = ev.eval(inc.schedule());
            if moved != full {
                return Err(format!(
                    "step {step} ({tag}): eval {moved:?} != full {full:?}"
                ));
            }
            let full_excess = ev.kv_excess(inc.schedule(), &kv);
            if inc.kv_excess() != full_excess {
                return Err(format!(
                    "step {step} ({tag}): excess {} != full {full_excess}",
                    inc.kv_excess()
                ));
            }
            if rng.chance(0.5) {
                inc.commit();
            } else {
                inc.rollback();
                if inc.schedule() != &pre_schedule
                    || inc.eval() != pre_eval
                    || inc.kv_excess() != pre_excess
                {
                    return Err(format!("step {step} ({tag}): rollback drifted"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fast_and_full_search_paths_agree_end_to_end() {
    // Bit-identical evaluations + a shared RNG stream force the two
    // priority_mapping implementations onto the same trajectory.
    check("priority_mapping == priority_mapping_full", 25, |rng| {
        let n = 2 + rng.below(16);
        let max_batch = 1 + rng.below(5);
        let pred = random_predictor(rng);
        let jobs = random_jobs(rng, n);
        let ev = Evaluator::new(&jobs, &pred);
        let params = SaParams {
            max_batch,
            seed: rng.next_u64(),
            t0: 100.0,
            iters_per_temp: 20,
            ..Default::default()
        };
        let fast = priority_mapping(&ev, &params);
        let full = priority_mapping_full(&ev, &params);
        if fast.schedule != full.schedule {
            return Err(format!(
                "schedules diverge (n={n} mb={max_batch}): {:?} vs {:?}",
                fast.schedule, full.schedule
            ));
        }
        if fast.eval != full.eval {
            return Err(format!(
                "evals diverge: {:?} vs {:?}",
                fast.eval, full.eval
            ));
        }
        if fast.stats.evals != full.stats.evals
            || fast.stats.accepted != full.stats.accepted
            || fast.stats.improved != full.stats.improved
            || fast.stats.early_exit != full.stats.early_exit
        {
            return Err(format!(
                "stats diverge: {:?} vs {:?}",
                fast.stats, full.stats
            ));
        }
        Ok(())
    });
}
