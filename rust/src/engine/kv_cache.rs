//! Paged KV-cache block allocator (PagedAttention-style substrate).
//!
//! vLLM's core memory trick — carving KV memory into fixed-size token
//! blocks so sequences grow without contiguous reservations — is the
//! substrate both engines use for admission control and memory metrics.
//! The allocator tracks per-sequence block lists, exposes utilization and
//! internal fragmentation, and refuses allocations beyond capacity (the
//! signal the continuous-batching loop uses for admission).

use std::collections::HashMap;

use thiserror::Error;

#[derive(Debug, Error, PartialEq, Eq)]
pub enum KvError {
    #[error("out of KV memory: need {need_blocks} blocks, {free_blocks} free")]
    OutOfMemory { need_blocks: usize, free_blocks: usize },
    #[error("sequence {0} already allocated")]
    AlreadyAllocated(u64),
    #[error("sequence {0} not found")]
    NotFound(u64),
}

/// Allocator configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// Tokens per block (vLLM default 16).
    pub block_tokens: usize,
    /// Total number of blocks in the pool.
    pub total_blocks: usize,
}

impl KvCacheConfig {
    /// Derive a pool from a memory budget and per-token cost.
    pub fn from_memory(pool_mb: f64, mb_per_token: f64, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        let tokens = (pool_mb / mb_per_token).max(0.0) as usize;
        KvCacheConfig { block_tokens, total_blocks: tokens / block_tokens }
    }

    pub fn total_tokens(&self) -> usize {
        self.block_tokens * self.total_blocks
    }
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    blocks: Vec<u32>,
    tokens: usize,
}

/// Block allocator with per-sequence accounting.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    cfg: KvCacheConfig,
    free: Vec<u32>,
    seqs: HashMap<u64, SeqAlloc>,
}

impl BlockAllocator {
    pub fn new(cfg: KvCacheConfig) -> Self {
        BlockAllocator {
            cfg,
            free: (0..cfg.total_blocks as u32).rev().collect(),
            seqs: HashMap::new(),
        }
    }

    pub fn config(&self) -> KvCacheConfig {
        self.cfg
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_tokens)
    }

    /// Blocks a fresh sequence of `tokens` tokens would pin (≥ 1 — even
    /// an empty sequence takes a block). The allocator-side twin of the
    /// scheduler's rounding rule
    /// ([`crate::coordinator::kv::blocks_for`]); admission pre-checks
    /// must use this so they agree with [`BlockAllocator::alloc_seq`]
    /// exactly.
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        self.blocks_for(tokens.max(1))
    }

    /// Allocate a new sequence holding `tokens` tokens.
    pub fn alloc_seq(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&seq) {
            return Err(KvError::AlreadyAllocated(seq));
        }
        let need = self.blocks_needed(tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfMemory {
                need_blocks: need,
                free_blocks: self.free.len(),
            });
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.seqs.insert(seq, SeqAlloc { blocks, tokens });
        Ok(())
    }

    /// Grow a sequence by `extra` tokens (decode steps appending KV).
    pub fn extend_seq(&mut self, seq: u64, extra: usize) -> Result<(), KvError> {
        let alloc = self.seqs.get_mut(&seq).ok_or(KvError::NotFound(seq))?;
        let new_tokens = alloc.tokens + extra;
        let need_total = new_tokens.div_ceil(self.cfg.block_tokens);
        let extra_blocks = need_total.saturating_sub(alloc.blocks.len());
        if extra_blocks > self.free.len() {
            return Err(KvError::OutOfMemory {
                need_blocks: extra_blocks,
                free_blocks: self.free.len(),
            });
        }
        let mut newly = self.free.split_off(self.free.len() - extra_blocks);
        alloc.blocks.append(&mut newly);
        alloc.tokens = new_tokens;
        Ok(())
    }

    /// Release a sequence's blocks.
    pub fn free_seq(&mut self, seq: u64) -> Result<(), KvError> {
        let alloc = self.seqs.remove(&seq).ok_or(KvError::NotFound(seq))?;
        self.free.extend(alloc.blocks);
        Ok(())
    }

    /// Would `tokens` more tokens (as a fresh sequence) fit right now?
    pub fn fits(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.cfg.total_blocks - self.free.len()
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn seq_tokens(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }

    /// Fraction of the pool allocated.
    pub fn utilization(&self) -> f64 {
        if self.cfg.total_blocks == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.cfg.total_blocks as f64
    }

    /// Internal fragmentation: allocated token slots never usable by other
    /// sequences (block granularity waste), as a fraction of allocated slots.
    pub fn internal_fragmentation(&self) -> f64 {
        let allocated_slots: usize = self
            .seqs
            .values()
            .map(|a| a.blocks.len() * self.cfg.block_tokens)
            .sum();
        if allocated_slots == 0 {
            return 0.0;
        }
        let used_tokens: usize = self.seqs.values().map(|a| a.tokens).sum();
        (allocated_slots - used_tokens) as f64 / allocated_slots as f64
    }

    /// Release everything (engine reset between experiment waves).
    pub fn reset(&mut self) {
        self.free = (0..self.cfg.total_blocks as u32).rev().collect();
        self.seqs.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn alloc(blocks: usize) -> BlockAllocator {
        BlockAllocator::new(KvCacheConfig {
            block_tokens: 16,
            total_blocks: blocks,
        })
    }

    #[test]
    fn from_memory_derivation() {
        // 100 MB at 0.5 MB/token = 200 tokens = 12 blocks of 16 (192 tokens)
        let cfg = KvCacheConfig::from_memory(100.0, 0.5, 16);
        assert_eq!(cfg.total_blocks, 12);
        assert_eq!(cfg.total_tokens(), 192);
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = alloc(10);
        a.alloc_seq(1, 33).unwrap(); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        assert_eq!(a.seq_tokens(1), Some(33));
        a.free_seq(1).unwrap();
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn rejects_double_alloc_and_missing_free() {
        let mut a = alloc(10);
        a.alloc_seq(1, 5).unwrap();
        assert_eq!(a.alloc_seq(1, 5), Err(KvError::AlreadyAllocated(1)));
        assert_eq!(a.free_seq(2), Err(KvError::NotFound(2)));
        assert_eq!(a.extend_seq(2, 1), Err(KvError::NotFound(2)));
    }

    #[test]
    fn out_of_memory() {
        let mut a = alloc(2);
        assert!(matches!(
            a.alloc_seq(1, 100),
            Err(KvError::OutOfMemory { .. })
        ));
        a.alloc_seq(2, 32).unwrap(); // exactly 2 blocks
        assert!(!a.fits(1));
    }

    #[test]
    fn extend_grows_blocks_lazily() {
        let mut a = alloc(4);
        a.alloc_seq(1, 10).unwrap(); // 1 block, 6 slack
        a.extend_seq(1, 6).unwrap(); // exactly fills the block
        assert_eq!(a.used_blocks(), 1);
        a.extend_seq(1, 1).unwrap(); // spills into a second block
        assert_eq!(a.used_blocks(), 2);
        assert_eq!(a.seq_tokens(1), Some(17));
    }

    #[test]
    fn fragmentation_accounting() {
        let mut a = alloc(10);
        a.alloc_seq(1, 1).unwrap(); // 1 token in a 16-slot block
        assert!((a.internal_fragmentation() - 15.0 / 16.0).abs() < 1e-9);
        a.extend_seq(1, 15).unwrap();
        assert_eq!(a.internal_fragmentation(), 0.0);
        assert_eq!(alloc(5).internal_fragmentation(), 0.0);
    }

    #[test]
    fn zero_token_alloc_takes_one_block() {
        let mut a = alloc(2);
        a.alloc_seq(1, 0).unwrap();
        assert_eq!(a.used_blocks(), 1);
    }

    #[test]
    fn failed_alloc_leaves_state_untouched() {
        // rollback invariant: a rejected allocation must not perturb the
        // allocator — same free list, same sequences, same accounting
        let mut a = alloc(4);
        a.alloc_seq(1, 40).unwrap(); // 3 blocks, 1 free
        let used = a.used_blocks();
        let free = a.free_blocks();
        assert!(matches!(
            a.alloc_seq(2, 100), // needs 7 blocks
            Err(KvError::OutOfMemory { need_blocks: 7, free_blocks: 1 })
        ));
        assert_eq!(a.used_blocks(), used);
        assert_eq!(a.free_blocks(), free);
        assert_eq!(a.active_seqs(), 1);
        assert_eq!(a.seq_tokens(2), None);
        // the survivor is fully intact and can still grow into the slack
        assert_eq!(a.seq_tokens(1), Some(40));
        a.extend_seq(1, 8).unwrap();
        assert_eq!(a.used_blocks(), 3);
        // double-alloc rejection is equally side-effect-free
        assert_eq!(a.alloc_seq(1, 1), Err(KvError::AlreadyAllocated(1)));
        assert_eq!(a.seq_tokens(1), Some(48));
    }

    #[test]
    fn failed_extend_leaves_sequence_untouched() {
        let mut a = alloc(3);
        a.alloc_seq(1, 16).unwrap(); // 1 block
        a.alloc_seq(2, 32).unwrap(); // 2 blocks — pool now full
        // growing seq 1 needs a new block; none free — must fail and
        // leave seq 1 at its pre-call token count and block count
        assert!(matches!(
            a.extend_seq(1, 1),
            Err(KvError::OutOfMemory { need_blocks: 1, free_blocks: 0 })
        ));
        assert_eq!(a.seq_tokens(1), Some(16));
        assert_eq!(a.used_blocks(), 3);
        assert_eq!(a.free_blocks(), 0);
        // freeing the neighbour unblocks the same extend verbatim
        a.free_seq(2).unwrap();
        a.extend_seq(1, 1).unwrap();
        assert_eq!(a.seq_tokens(1), Some(17));
        assert_eq!(a.used_blocks(), 2);
    }

    #[test]
    fn reset_restores_pristine_pool() {
        let mut a = alloc(8);
        a.alloc_seq(1, 100).unwrap();
        a.alloc_seq(2, 16).unwrap();
        assert!(a.used_blocks() > 0);
        a.reset();
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(a.free_blocks(), 8);
        assert_eq!(a.active_seqs(), 0);
        assert_eq!(a.seq_tokens(1), None);
        assert_eq!(a.utilization(), 0.0);
        assert_eq!(a.internal_fragmentation(), 0.0);
        // the pool is fully reusable after reset
        a.alloc_seq(1, 128).unwrap(); // all 8 blocks
        assert_eq!(a.free_blocks(), 0);
    }

    #[test]
    fn conservation_property() {
        check("block conservation under random ops", 200, |rng: &mut Rng| {
            let total = 1 + rng.below(64);
            let mut a = alloc(total);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..100 {
                match rng.below(3) {
                    0 => {
                        let tokens = rng.below(200);
                        if a.alloc_seq(next_id, tokens).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let idx = rng.below(live.len());
                        let _ = a.extend_seq(live[idx], rng.below(40));
                    }
                    2 if !live.is_empty() => {
                        let idx = rng.below(live.len());
                        let id = live.swap_remove(idx);
                        a.free_seq(id).unwrap();
                    }
                    _ => {}
                }
                let used: usize = a.used_blocks();
                if used + a.free_blocks() != total {
                    return Err(format!(
                        "leak: used {used} + free {} != {total}",
                        a.free_blocks()
                    ));
                }
            }
            // free everything and verify full recovery
            for id in live {
                a.free_seq(id).unwrap();
            }
            if a.free_blocks() != total {
                return Err("blocks not fully recovered".into());
            }
            Ok(())
        });
    }
}
