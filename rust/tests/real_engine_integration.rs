//! Integration over the REAL PJRT engine + AOT artifacts.
//!
//! Requires `make artifacts` to have run (the Makefile test target
//! guarantees this). These tests prove the full L1→L2→L3 composition:
//! Pallas kernels inside the lowered HLO, executed from Rust, produce
//! deterministic, batch-consistent generations.

use slo_serve::engine::real::RealEngine;
use slo_serve::engine::{Engine, EngineRequest};

fn artifacts_dir() -> String {
    std::env::var("SLO_SERVE_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

fn engine() -> RealEngine {
    RealEngine::load(&artifacts_dir()).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    )
}

fn req(id: u64, prompt: &[u8], max_new: usize) -> EngineRequest {
    EngineRequest {
        id,
        input_len: 0,
        max_new_tokens: max_new,
        prompt: Some(prompt.to_vec()),
    }
}

#[test]
fn generates_exact_token_budget() {
    let mut e = engine();
    let out = e
        .run_batch(&[req(1, b"fn main() { println!(", 8)])
        .unwrap();
    assert_eq!(out.len(), 1);
    // untrained model never emits EOS in 8 tokens with overwhelming
    // probability; budget is exact
    assert!(out[0].generated <= 8);
    assert!(out[0].generated >= 1);
    assert_eq!(out[0].text.as_ref().unwrap().len() <= 8, true);
    assert!(out[0].finish_ms >= out[0].first_token_ms);
    assert!(out[0].first_token_ms >= out[0].start_ms);
}

#[test]
fn deterministic_greedy_generation() {
    let mut e1 = engine();
    let mut e2 = engine();
    let a = e1.run_batch(&[req(1, b"The quick brown fox", 6)]).unwrap();
    let b = e2.run_batch(&[req(1, b"The quick brown fox", 6)]).unwrap();
    assert_eq!(a[0].text, b[0].text, "greedy decode must be deterministic");
}

#[test]
fn batch_rows_match_solo_rows() {
    // Batching must not change a row's greedy generation (the model-level
    // row-independence invariant, end to end through PJRT).
    let mut e = engine();
    let solo = e.run_batch(&[req(1, b"import numpy as np", 5)]).unwrap();
    let batch = e
        .run_batch(&[
            req(2, b"import numpy as np", 5),
            req(3, b"Hello world, this is a longer prompt", 5),
        ])
        .unwrap();
    assert_eq!(
        solo[0].text, batch[0].text,
        "row 0 generation changed when batched"
    );
}

#[test]
fn rejects_oversized_and_empty() {
    let mut e = engine();
    let cap = e.max_total_tokens();
    assert!(e
        .run_batch(&[EngineRequest {
            id: 1,
            input_len: cap,
            max_new_tokens: 10,
            prompt: None,
        }])
        .is_err());
    assert!(e.run_batch(&[]).is_err());
    let too_many: Vec<EngineRequest> = (0..e.max_batch() as u64 + 1)
        .map(|i| req(i, b"x", 2))
        .collect();
    assert!(e.run_batch(&too_many).is_err());
}

#[test]
fn synthetic_prompts_by_length() {
    let mut e = engine();
    let out = e
        .run_batch(&[EngineRequest {
            id: 7,
            input_len: 40,
            max_new_tokens: 4,
            prompt: None,
        }])
        .unwrap();
    assert!(out[0].generated >= 1);
}

#[test]
fn clock_is_monotone_and_wall() {
    let mut e = engine();
    let t0 = e.now_ms();
    let _ = e.run_batch(&[req(1, b"abc", 3)]).unwrap();
    assert!(e.now_ms() > t0);
}
