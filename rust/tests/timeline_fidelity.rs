//! Predicted-vs-executed timeline fidelity (ISSUE 4 acceptance):
//!
//! * **exact-model equality** — with a constant-duration latency model
//!   (zero noise, oracle outputs), the arrival-aware timeline evaluator's
//!   predicted completion times equal `SimEngine`'s executed completions
//!   on random arrival traces, seed for seed. The timeline machinery
//!   (idle-gap jumps, per-job arrival offsets, frozen-prefix chaining,
//!   KV deferral) is thereby pinned exactly; with a real latency model
//!   the only residual error is model error, not timeline error.
//! * **phased-mode equality** — the same property holds with a binding
//!   `KvPhaseModel::Phased` pool driving admission back-pressure, on
//!   ≥ 3 seeds.
//! * **legacy gap** — on sparse traces the t = 0 (pre-timeline)
//!   evaluation overestimates waits by the un-modelled idle gaps, while
//!   the arrival-aware timeline is exact — the fidelity gap this change
//!   closes.

use slo_serve::config::profiles::HardwareProfile;
use slo_serve::coordinator::kv::{KvConfig, KvPhaseModel};
use slo_serve::coordinator::online::{
    run_online_opts, OnlineOpts, OnlineOutcome, ReplanStrategy,
};
use slo_serve::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::profiler::MemoryModel;
use slo_serve::coordinator::request::{Request, Slo, TaskType};
use slo_serve::engine::sim::SimEngine;
use slo_serve::util::rng::Rng;

/// Profile whose ground truth is a constant per-batch duration: prefill
/// is `exec_ms` regardless of batch size or lengths, decode is free, and
/// every request generates exactly one token at prefill. The predictor
/// is *exact* for this engine, so any predicted-vs-executed difference
/// is timeline error.
fn constant_profile(exec_ms: f64) -> HardwareProfile {
    HardwareProfile {
        name: "const-exec".into(),
        truth: LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: exec_ms },
            PhaseCoeffs::ZERO,
        ),
        kv_pool_mb: 2_000.0, // 4000 tokens -> 250 blocks
        mem: MemoryModel { utility: 1.0, mb_per_token: 0.5 },
        noise_std: 0.0,
        max_total_tokens: 4096,
    }
}

/// Random single-token requests with increasing arrival times; `min_gap`
/// and `max_gap` bound the inter-arrival spacing.
fn random_trace(
    rng: &mut Rng,
    n: usize,
    min_gap: f64,
    max_gap: f64,
) -> Vec<Request> {
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += rng.uniform(min_gap, max_gap);
            let mut r = Request::synthetic(
                i as u64,
                TaskType::Code,
                1 + rng.below(500),
                1, // one token: completion == batch start + exec
                Slo::E2e { e2e_ms: 1e9 },
            );
            r.arrival_ms = t;
            r
        })
        .collect()
}

fn run(
    trace: &[Request],
    profile: &HardwareProfile,
    sa: &SaParams,
    opts: OnlineOpts,
) -> OnlineOutcome {
    let outs: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let mut engine = SimEngine::new(profile.clone(), sa.max_batch, 0)
        .with_kv_phase(sa.kv.phase)
        .with_chunk_tokens(sa.chunk_tokens);
    run_online_opts(
        trace,
        &outs,
        &mut engine,
        &profile.truth,
        sa,
        ReplanStrategy::Warm,
        opts,
    )
    .unwrap()
}

/// Assert every request's predicted wait/e2e equals its executed
/// counterpart (the outcome's vectors are both sorted by id).
fn assert_predictions_exact(out: &OnlineOutcome, tag: &str) {
    assert_eq!(out.predicted.len(), out.completions.len(), "{tag}");
    for (p, c) in out.predicted.iter().zip(&out.completions) {
        assert_eq!(p.id, c.id, "{tag}");
        assert!(
            (p.e2e_ms - c.e2e_ms).abs() < 1e-9,
            "{tag}: request {} predicted e2e {} != executed {}",
            p.id,
            p.e2e_ms,
            c.e2e_ms
        );
        assert!(
            (p.wait_ms - c.wait_ms).abs() < 1e-9,
            "{tag}: request {} predicted wait {} != executed {}",
            p.id,
            p.wait_ms,
            c.wait_ms
        );
        assert!(
            (p.ttft_ms - c.ttft_ms).abs() < 1e-9,
            "{tag}: request {} predicted ttft {} != executed {}",
            p.id,
            p.ttft_ms,
            c.ttft_ms
        );
    }
}

#[test]
fn predicted_completions_equal_executed_under_exact_model() {
    const EXEC_MS: f64 = 100.0;
    let profile = constant_profile(EXEC_MS);
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed ^ 0x71D3);
        let n = 12 + rng.below(20);
        // mixed spacing: some arrivals land mid-batch (queueing), some
        // after idle gaps (the un-modelled case before this change)
        let trace = random_trace(&mut rng, n, 0.0, 2.5 * EXEC_MS);
        let sa = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 10,
            ..Default::default()
        };
        let out = run(
            &trace,
            &profile,
            &sa,
            OnlineOpts { arrival_aware: true, ..Default::default() },
        );
        assert_eq!(out.completions.len(), n, "seed {seed}");
        assert_predictions_exact(&out, &format!("seed {seed}"));
    }
}

#[test]
fn predicted_completions_equal_executed_in_phased_mode() {
    const EXEC_MS: f64 = 80.0;
    let profile = constant_profile(EXEC_MS);
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed ^ 0x0F1A);
        let n = 10 + rng.below(14);
        // bursty arrivals so a binding pool actually defers admissions
        let trace = random_trace(&mut rng, n, 0.0, 0.6 * EXEC_MS);
        let sa = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 10,
            // every request fits alone (<= 32 blocks), small enough that
            // backlog saturation defers admissions mid-trace
            kv: KvConfig::hard(64).with_phase(KvPhaseModel::Phased),
            ..Default::default()
        };
        let out = run(
            &trace,
            &profile,
            &sa,
            OnlineOpts { arrival_aware: true, ..Default::default() },
        );
        assert_eq!(out.completions.len(), n, "seed {seed}");
        assert_predictions_exact(&out, &format!("phased seed {seed}"));
    }
}

/// Profile whose prefill cost is purely length-proportional
/// (`γ · max_input` per batch, decode free): per-member prefill pricing
/// is observably wrong for every non-longest batch member, so this is
/// the model that distinguishes the batch-wide TTFT formula from the
/// old `wait + own-prefill` one.
fn gamma_profile(gamma: f64) -> HardwareProfile {
    HardwareProfile {
        name: "gamma-prefill".into(),
        truth: LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma, delta: 0.0 },
            PhaseCoeffs::ZERO,
        ),
        kv_pool_mb: 2_000.0,
        mem: MemoryModel { utility: 1.0, mb_per_token: 0.5 },
        noise_std: 0.0,
        max_total_tokens: 4096,
    }
}

#[test]
fn predicted_ttft_equals_executed_batch_first_token() {
    // The engine emits every member's first token when the *batch*
    // prefill (`γ · max_input`) finishes; under the old per-member TTFT
    // formula a short prompt sharing a batch with a long one was
    // predicted an earlier first token than the engine can produce.
    const GAMMA: f64 = 0.5;
    let profile = gamma_profile(GAMMA);
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed ^ 0x77F7);
        let n = 12 + rng.below(12);
        let trace = random_trace(&mut rng, n, 0.0, 60.0);
        let sa = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 10,
            ..Default::default()
        };
        let out = run(
            &trace,
            &profile,
            &sa,
            OnlineOpts { arrival_aware: true, ..Default::default() },
        );
        assert_eq!(out.completions.len(), n, "seed {seed}");
        // the property is only sharp if some batch actually mixes
        // members (prompt lengths are random, so almost surely distinct)
        assert!(
            out.completions.iter().any(|c| c.batch_size > 1),
            "seed {seed}: trace degenerated to singleton batches"
        );
        for (p, c) in out.predicted.iter().zip(&out.completions) {
            assert_eq!(p.id, c.id, "seed {seed}");
            assert!(
                (p.ttft_ms - c.ttft_ms).abs() < 1e-9,
                "seed {seed}: request {} predicted ttft {} != executed \
                 {} (batch size {})",
                p.id,
                p.ttft_ms,
                c.ttft_ms,
                c.batch_size
            );
            assert!(
                (p.wait_ms - c.wait_ms).abs() < 1e-9,
                "seed {seed}: request {} predicted wait {} != executed {}",
                p.id,
                p.wait_ms,
                c.wait_ms
            );
        }
    }
}

#[test]
fn chunked_predictions_equal_executed() {
    // Chunked execution under the constant-duration model: every chunk
    // costs δ, so a member's first token lands at
    // `batch start + Σ_{j ≤ i} ceil(input_j / C) · δ` — a *different*
    // number per member, and (for multi-chunk prompts) a different batch
    // duration than whole-prompt prefill. Predicted wait/ttft/e2e must
    // all track it exactly (invariant 15's chunked half).
    const EXEC_MS: f64 = 50.0;
    const CHUNK: usize = 128;
    let profile = constant_profile(EXEC_MS);
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed ^ 0xC41C);
        let n = 10 + rng.below(14);
        let trace = random_trace(&mut rng, n, 0.0, 2.0 * EXEC_MS);
        let sa = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 10,
            chunk_tokens: CHUNK,
            ..Default::default()
        };
        let out = run(
            &trace,
            &profile,
            &sa,
            OnlineOpts { arrival_aware: true, ..Default::default() },
        );
        assert_eq!(out.completions.len(), n, "seed {seed}");
        // at least one prompt must span several chunks or the test
        // degenerates to the unchunked one (lengths reach 500 > 2·128)
        assert!(
            trace.iter().any(|r| r.input_len > CHUNK),
            "seed {seed}: no multi-chunk prompt in the trace"
        );
        assert_predictions_exact(&out, &format!("chunked seed {seed}"));
    }
}

#[test]
fn legacy_timeline_overestimates_waits_on_sparse_traces() {
    const EXEC_MS: f64 = 100.0;
    let profile = constant_profile(EXEC_MS);
    let mut rng = Rng::new(0xBEE);
    // every gap exceeds the batch duration: the engine idles before each
    // request, executed waits are ~0, and the t = 0 evaluation charges
    // each job the full (fictional) backlog of earlier batch maxima.
    let trace = random_trace(&mut rng, 12, 2.0 * EXEC_MS, 4.0 * EXEC_MS);
    let sa = SaParams {
        max_batch: 4,
        seed: 7,
        t0: 100.0,
        iters_per_temp: 10,
        ..Default::default()
    };
    let mean_err = |out: &OnlineOutcome| {
        let total: f64 = out
            .predicted
            .iter()
            .zip(&out.completions)
            .map(|(p, c)| (p.wait_ms - c.wait_ms).abs())
            .sum();
        total / out.predicted.len() as f64
    };
    let legacy = run(&trace, &profile, &sa, OnlineOpts::default());
    let aware = run(
        &trace,
        &profile,
        &sa,
        OnlineOpts { arrival_aware: true, ..Default::default() },
    );
    let (err_legacy, err_aware) = (mean_err(&legacy), mean_err(&aware));
    assert!(
        err_aware < 1e-9,
        "arrival-aware timeline should be exact here, err {err_aware}"
    );
    assert!(
        err_legacy > EXEC_MS,
        "legacy timeline should accumulate un-modelled idle gaps, \
         err {err_legacy}"
    );
}
