//! Request profiler (paper §4.2, §4.4, §5.1 "Workflows").
//!
//! Three responsibilities:
//!
//! 1. **Output-length modelling** — tracks actual output lengths per task
//!    type and fits a running Gaussian (Welford's online algorithm); the
//!    priority mapper samples predicted output lengths from it. Business
//!    users may instead supply a fixed range/distribution per task type.
//! 2. **Memory accounting** — maintains the memory-utility factor μ and the
//!    per-token memory consumption σ of Eq. 20 (`token_num(m) = m·μ/σ`).
//! 3. **Latency sample collection** — gathers (batch, length, latency)
//!    observations feeding the predictor's least-squares fit.

use std::collections::BTreeMap;

use crate::coordinator::predictor::{LatencyPredictor, PhaseSample};
use crate::coordinator::request::TaskType;
use crate::util::rng::Rng;

/// Running Gaussian over observed output lengths (Welford).
#[derive(Debug, Clone, Default)]
pub struct OutputLenModel {
    count: usize,
    mean: f64,
    m2: f64,
}

impl OutputLenModel {
    pub fn observe(&mut self, len: usize) {
        self.count += 1;
        let x = len as f64;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / self.count as f64).sqrt()
        }
    }

    /// Sample a predicted output length (≥1), clamped to `max_len`.
    pub fn sample(&self, rng: &mut Rng, max_len: usize) -> usize {
        if self.count == 0 {
            // no data yet: fall back to a broad prior
            return (max_len / 4).max(1);
        }
        let v = rng.gaussian(self.mean, self.std());
        (v.round().max(1.0) as usize).min(max_len.max(1))
    }
}

/// Optional business-supplied output spec (§4.2: "an optional input variable
/// to allow business users to specify a typical output range or
/// distribution for each task type").
#[derive(Debug, Clone, Copy)]
pub enum OutputSpec {
    /// Fixed Gaussian (mean, std).
    Gaussian { mean: f64, std: f64 },
    /// Uniform range [lo, hi].
    Range { lo: usize, hi: usize },
}

/// Memory model parameters of Eq. 20.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryModel {
    /// μ — memory utility (< 1 due to fragmentation).
    pub utility: f64,
    /// σ — memory per token (MB/token).
    pub mb_per_token: f64,
}

impl MemoryModel {
    /// Eq. 20: number of tokens a given remaining memory can host.
    pub fn token_capacity(&self, remaining_mb: f64) -> usize {
        if remaining_mb <= 0.0 {
            return 0;
        }
        (remaining_mb * self.utility / self.mb_per_token).floor() as usize
    }

    /// Inverse: memory footprint of a token count (MB).
    pub fn tokens_to_mb(&self, tokens: usize) -> f64 {
        tokens as f64 * self.mb_per_token / self.utility
    }
}

impl Default for MemoryModel {
    fn default() -> Self {
        // vLLM-style defaults: 0.9 utilization (paper §5.1); per-token KV
        // footprint of Qwen2.5-7B-class models ≈ 0.5 MB/token at FP16.
        MemoryModel { utility: 0.9, mb_per_token: 0.5 }
    }
}

/// The request profiler.
#[derive(Debug, Clone, Default)]
pub struct RequestProfiler {
    output_models: BTreeMap<TaskType, OutputLenModel>,
    output_specs: BTreeMap<TaskType, OutputSpec>,
    prefill_samples: Vec<PhaseSample>,
    decode_samples: Vec<PhaseSample>,
    mem_ratio_sum: f64,
    mem_ratio_count: usize,
    mem_bytes_sum: f64,
    mem_tokens_sum: f64,
}

impl RequestProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a business-supplied output spec for a task type.
    pub fn set_output_spec(&mut self, task: TaskType, spec: OutputSpec) {
        self.output_specs.insert(task, spec);
    }

    /// Record the actual output length of a completed request.
    pub fn observe_output(&mut self, task: TaskType, len: usize) {
        self.output_models.entry(task).or_default().observe(len);
    }

    pub fn output_model(&self, task: TaskType) -> Option<&OutputLenModel> {
        self.output_models.get(&task)
    }

    /// Predict an output length for a new request of `task`.
    ///
    /// Priority: business spec > fitted Gaussian > broad prior.
    pub fn predict_output(
        &self,
        task: TaskType,
        rng: &mut Rng,
        max_len: usize,
    ) -> usize {
        if let Some(spec) = self.output_specs.get(&task) {
            let v = match *spec {
                OutputSpec::Gaussian { mean, std } => {
                    rng.gaussian(mean, std).round()
                }
                OutputSpec::Range { lo, hi } => {
                    rng.range(lo as i64, hi.max(lo) as i64) as f64
                }
            };
            return (v.max(1.0) as usize).min(max_len.max(1));
        }
        match self.output_models.get(&task) {
            Some(m) => m.sample(rng, max_len),
            None => (max_len / 4).max(1),
        }
    }

    /// Record a prefill latency observation (profiling rounds, §5.1).
    pub fn observe_prefill(&mut self, batch: usize, input_len: usize, ms: f64) {
        self.prefill_samples.push(PhaseSample { batch, len: input_len, ms });
    }

    /// Record a per-token decode latency observation.
    pub fn observe_decode(
        &mut self,
        batch: usize,
        accumulated_len: usize,
        ms_per_token: f64,
    ) {
        self.decode_samples.push(PhaseSample {
            batch,
            len: accumulated_len,
            ms: ms_per_token,
        });
    }

    pub fn sample_counts(&self) -> (usize, usize) {
        (self.prefill_samples.len(), self.decode_samples.len())
    }

    /// Fit a latency predictor from the collected samples (§4.2).
    /// Returns `(predictor, r²_prefill, r²_decode)`.
    pub fn fit_predictor(&self) -> Option<(LatencyPredictor, f64, f64)> {
        LatencyPredictor::fit(&self.prefill_samples, &self.decode_samples)
    }

    /// Record an observed (peak memory used / available) ratio — updates μ.
    pub fn observe_memory_ratio(&mut self, used_over_available: f64) {
        self.mem_ratio_sum += used_over_available.clamp(0.0, 1.0);
        self.mem_ratio_count += 1;
    }

    /// Record aggregate memory consumption for a token count — updates σ.
    pub fn observe_memory_per_token(&mut self, total_mb: f64, tokens: usize) {
        self.mem_bytes_sum += total_mb;
        self.mem_tokens_sum += tokens as f64;
    }

    /// Current memory model (falls back to defaults where unobserved).
    pub fn memory_model(&self) -> MemoryModel {
        let default = MemoryModel::default();
        let utility = if self.mem_ratio_count > 0 {
            self.mem_ratio_sum / self.mem_ratio_count as f64
        } else {
            default.utility
        };
        let mb_per_token = if self.mem_tokens_sum > 0.0 {
            self.mem_bytes_sum / self.mem_tokens_sum
        } else {
            default.mb_per_token
        };
        MemoryModel { utility, mb_per_token }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch_stats() {
        let mut m = OutputLenModel::default();
        let data = [10usize, 20, 30, 40, 50];
        for &d in &data {
            m.observe(d);
        }
        assert_eq!(m.count(), 5);
        assert!((m.mean() - 30.0).abs() < 1e-9);
        let var: f64 = data
            .iter()
            .map(|&d| (d as f64 - 30.0).powi(2))
            .sum::<f64>()
            / 5.0;
        assert!((m.std() - var.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn sample_clamps_and_floors() {
        let mut m = OutputLenModel::default();
        m.observe(1);
        m.observe(1);
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let s = m.sample(&mut rng, 5);
            assert!((1..=5).contains(&s));
        }
    }

    #[test]
    fn sample_without_data_uses_prior() {
        let m = OutputLenModel::default();
        let mut rng = Rng::new(0);
        assert_eq!(m.sample(&mut rng, 400), 100);
    }

    #[test]
    fn gaussian_prediction_tracks_observations() {
        let mut p = RequestProfiler::new();
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let len = rng.gaussian(200.0, 20.0).max(1.0) as usize;
            p.observe_output(TaskType::Chat, len);
        }
        let m = p.output_model(TaskType::Chat).unwrap();
        assert!((m.mean() - 200.0).abs() < 3.0, "mean {}", m.mean());
        assert!((m.std() - 20.0).abs() < 3.0, "std {}", m.std());
        // sampled predictions should centre on the same mean
        let samples: Vec<f64> = (0..2000)
            .map(|_| p.predict_output(TaskType::Chat, &mut rng, 10_000) as f64)
            .collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - 200.0).abs() < 5.0, "sampled mean {mean}");
    }

    #[test]
    fn business_spec_overrides_model() {
        let mut p = RequestProfiler::new();
        p.observe_output(TaskType::Code, 500);
        p.set_output_spec(TaskType::Code, OutputSpec::Range { lo: 7, hi: 9 });
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = p.predict_output(TaskType::Code, &mut rng, 1000);
            assert!((7..=9).contains(&s));
        }
    }

    #[test]
    fn token_capacity_eq20() {
        let m = MemoryModel { utility: 0.9, mb_per_token: 0.5 };
        // token_num = m·μ/σ = 1000·0.9/0.5 = 1800
        assert_eq!(m.token_capacity(1000.0), 1800);
        assert_eq!(m.token_capacity(0.0), 0);
        assert_eq!(m.token_capacity(-5.0), 0);
        // inverse within rounding
        assert!((m.tokens_to_mb(1800) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn memory_model_from_observations() {
        let mut p = RequestProfiler::new();
        p.observe_memory_ratio(0.8);
        p.observe_memory_ratio(0.9);
        p.observe_memory_per_token(500.0, 2000);
        let m = p.memory_model();
        assert!((m.utility - 0.85).abs() < 1e-9);
        assert!((m.mb_per_token - 0.25).abs() < 1e-9);
    }

    #[test]
    fn fit_predictor_via_profiler() {
        let mut p = RequestProfiler::new();
        let truth = LatencyPredictor::paper_table2();
        for b in [1usize, 2, 4, 8] {
            for l in [100usize, 500, 1000, 2000] {
                p.observe_prefill(b, l, truth.prefill.eval(b as f64, l as f64));
                p.observe_decode(b, l, truth.decode.eval(b as f64, l as f64));
            }
        }
        let (fitted, r2p, r2d) = p.fit_predictor().unwrap();
        assert!(r2p > 0.99 && r2d > 0.99);
        assert!((fitted.prefill.alpha - truth.prefill.alpha).abs() < 1e-6);
    }
}
