"""Pure-`jnp` oracles for the Pallas kernels.

These are the correctness references: deliberately naive, no blocking, no
running-softmax tricks — just masked softmax attention.  The pytest suite
(``python/tests/test_kernel.py``) sweeps shapes/dtypes with hypothesis and
asserts the Pallas kernels match these to tight tolerances.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

_NEG = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Reference attention over ``[B, H, S, D]`` tensors (see flash_attention)."""
    b, h, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        q_ids = jnp.arange(s)[:, None]
        k_ids = jnp.arange(s)[None, :]
        scores = jnp.where(k_ids <= q_ids, scores, _NEG)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Reference decode-step attention (see decode_attention).

    q: [B, H, D]; caches: [B, H, S, D]; pos: [B] — attends over keys 0..=pos.
    """
    b, h, s, d = k_cache.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bhd,bhkd->bhk", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    k_ids = jnp.arange(s)[None, None, :]
    mask = k_ids <= pos[:, None, None]
    scores = jnp.where(mask, scores, _NEG)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhk,bhkd->bhd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
