//! Integration: profiling → least-squares fit → prediction accuracy on the
//! simulated engine (the §4.2/§5.1 pipeline end to end).

use slo_serve::bench::fit_predictor_from_profile;
use slo_serve::config::profiles::{builtin_profiles, by_name};
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::{Engine, EngineRequest};

#[test]
fn fitted_predictor_predicts_engine_latency() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    let fitted = fit_predictor_from_profile(&profile, 3);
    let mut engine = SimEngine::new(profile, 4, 0);
    for (b, li, lo) in [(1usize, 300usize, 50usize), (2, 700, 120), (4, 1200, 200)] {
        let batch: Vec<EngineRequest> = (0..b)
            .map(|i| EngineRequest {
                id: i as u64,
                input_len: li,
                max_new_tokens: lo,
                prompt: None,
            })
            .collect();
        let t0 = engine.now_ms();
        let out = engine.run_batch(&batch).unwrap();
        let measured = out[0].finish_ms - t0;
        let predicted = fitted.predict(b, li, lo).exec_ms;
        let rel = (measured - predicted).abs() / measured;
        assert!(
            rel < 0.03,
            "b={b} li={li} lo={lo}: measured {measured:.1} predicted {predicted:.1} rel {rel:.3}"
        );
    }
}

#[test]
fn fit_works_for_every_builtin_profile() {
    for profile in builtin_profiles() {
        let fitted = fit_predictor_from_profile(&profile, 1);
        // fitted alpha must be within 20% of truth for all profiles
        let rel = (fitted.prefill.alpha - profile.truth.prefill.alpha).abs()
            / profile.truth.prefill.alpha.abs().max(1e-9);
        assert!(rel < 0.2, "{}: prefill alpha rel {rel}", profile.name);
    }
}

#[test]
fn ttft_tpot_decomposition_consistent() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    let truth = profile.truth;
    let mut engine = SimEngine::new(profile, 2, 0);
    let out = engine
        .run_batch(&[EngineRequest {
            id: 0,
            input_len: 500,
            max_new_tokens: 100,
            prompt: None,
        }])
        .unwrap();
    let item = &out[0];
    // TTFT == prefill time (no wait in an empty engine)
    let ttft = item.first_token_ms - item.start_ms;
    assert!((ttft - truth.prefill_ms(1, 500)).abs() / ttft < 0.01);
    // decode total == closed-form Eq. 16 over the 99 post-first tokens
    let decode = item.finish_ms - item.first_token_ms;
    let expected: f64 = (2..=100).map(|k| truth.tpot_at(1, 500 + k)).sum();
    assert!(
        (decode - expected).abs() / expected < 0.01,
        "decode {decode} vs {expected}"
    );
}
