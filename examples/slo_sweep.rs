//! SLO-strictness sweep: where does SLO-aware scheduling pay off?
//!
//! Sweeps a global SLO scale factor (0.25 = 4× stricter than the paper's
//! defaults … 2.0 = 2× looser) and compares SA vs the vLLM-FCFS baseline
//! on attainment and G. The gain concentrates in the contended-but-
//! feasible regime; at the loose end everything meets its SLO and the two
//! systems converge — exactly the paper's motivation (§3).
//!
//!     cargo run --release --example slo_sweep

use slo_serve::bench::run_scenario;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::metrics::Table;

fn run(policy: &str, scale: f64, seed: u64) -> (f64, f64) {
    let cfg = RunConfig {
        policy: policy.into(),
        n_requests: 16,
        max_batch: 2,
        seed,
        output_pred: OutputPrediction::Oracle { rel_err: 0.05 },
        slos: SloTargets::default().scaled(scale),
        ..Default::default()
    };
    let m = run_scenario(&cfg).unwrap().metrics;
    (m.attainment(), m.g_req_per_s)
}

fn main() {
    println!("SLO strictness sweep: SA vs vLLM-FCFS (16 requests, bs 2)\n");
    let seeds: Vec<u64> = (0..4).collect();
    let mut t = Table::new(&[
        "slo scale", "fcfs attainment", "sa attainment", "fcfs G", "sa G",
        "ΔG",
    ]);
    for &scale in &[0.25f64, 0.4, 0.6, 0.8, 1.0, 1.5, 2.0] {
        let mut fa = 0.0;
        let mut fg = 0.0;
        let mut sa = 0.0;
        let mut sg = 0.0;
        for &seed in &seeds {
            let (a, g) = run("fcfs", scale, seed);
            fa += a;
            fg += g;
            let (a, g) = run("slo-aware-sa", scale, seed);
            sa += a;
            sg += g;
        }
        let k = seeds.len() as f64;
        t.row(vec![
            format!("{scale}"),
            format!("{:.0}%", fa / k * 100.0),
            format!("{:.0}%", sa / k * 100.0),
            format!("{:.4}", fg / k),
            format!("{:.4}", sg / k),
            format!("{:+.1}%", (sg / fg - 1.0) * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("slo_sweep OK");
}
