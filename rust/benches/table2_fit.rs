//! Paper Table 2: latency-predictor fitting parameters from profiling.
//!
//! Runs the §5.1 profiling workflow (batch sizes 1–32, request lengths
//! 100–8000) against the simulated Qwen2.5-7B @ 2×V100 testbed and fits
//! Eqs. 14–15 by least squares, reporting the recovered coefficients and
//! R². The ground truth *is* the paper's Table 2, so recovered ≈ paper.

use slo_serve::config::profiles::by_name;
use slo_serve::coordinator::profiler::RequestProfiler;
use slo_serve::metrics::Table;
use slo_serve::util::rng::Rng;

fn main() {
    println!("== Table 2: fitted latency-predictor parameters ==\n");
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let mut profiler = RequestProfiler::new();
    let mut rng = Rng::new(42);
    // profiling rounds: batch 1..32, lengths 100..8000 (paper §5.1)
    for b in [1usize, 2, 4, 8, 16, 32] {
        for l in [100usize, 250, 500, 1000, 2000, 4000, 8000] {
            for _ in 0..5 {
                let np = rng.gaussian(1.0, profile.noise_std).max(0.05);
                let nd = rng.gaussian(1.0, profile.noise_std).max(0.05);
                profiler.observe_prefill(
                    b, l, profile.truth.prefill.eval(b as f64, l as f64) * np);
                profiler.observe_decode(
                    b, l, profile.truth.decode.eval(b as f64, l as f64) * nd);
            }
        }
    }
    let (fitted, r2p, r2d) = profiler.fit_predictor().unwrap();
    let mut t = Table::new(&["parameter", "alpha", "beta", "gamma", "delta", "R²"]);
    t.row(vec![
        "for prefill".into(),
        format!("{:.4}", fitted.prefill.alpha),
        format!("{:.2}", fitted.prefill.beta),
        format!("{:.4}", fitted.prefill.gamma),
        format!("{:.2}", fitted.prefill.delta),
        format!("{:.4}", r2p),
    ]);
    t.row(vec![
        "for decode".into(),
        format!("{:.5}", fitted.decode.alpha),
        format!("{:.3}", fitted.decode.beta),
        format!("{:.5}", fitted.decode.gamma),
        format!("{:.2}", fitted.decode.delta),
        format!("{:.4}", r2d),
    ]);
    print!("{}", t.render());
    println!("\npaper Table 2: prefill α=0.1 β=5.7 γ=0.01 δ=43.67;");
    println!("              decode  α=0.0002 β=0.275 γ=0.00088 δ=15.85");
    let (np, nd) = profiler.sample_counts();
    println!("(fitted from {np} prefill + {nd} decode profiling samples)");
}
