//! Simulated-annealing priority mapping (paper §4.3, Algorithm 1).
//!
//! Searches the joint space of (priority sequence, batch partition) for the
//! schedule maximizing `G`. Two starting solutions are considered:
//!
//! 1. the arrival order with all batches at the maximum size, and
//! 2. the order sorted by predicted solo e2e latency (shortest first) —
//!    if this one already meets *every* SLO it is provably optimal for `G`'s
//!    upper bound (smallest Σe2e with the largest achievable `n`) and the
//!    search exits early (Algorithm 1 lines 7–10).
//!
//! Otherwise, Metropolis-style annealing runs from the better seed.
//!
//! **Hot-path structure**: [`priority_mapping`] is the production path. It
//! precomputes a per-wave [`PredTable`] (every `(job, batch_size)`
//! prediction once), then drives the search through
//! [`IncrementalEval`] — moves are applied in-place against the
//! incremental state and either committed (free) or rolled back from
//! reused snapshot buffers, so the loop performs no per-iteration cloning
//! of `order`/`batches` and no heap allocation once warm. Candidate
//! evaluations recompute only the batches a move touched plus the
//! downstream suffix whose entry wait actually shifted; results are
//! bit-identical to the full evaluation (see `objective.rs` module docs and
//! `tests/incremental_eval_equivalence.rs`).
//! [`priority_mapping_full`] keeps the original full-evaluation loop as the
//! reference path for equivalence tests and the old-vs-new throughput bench
//! (`benches/sa_throughput.rs`).
//!
//! **Acceptance-rule note** (DESIGN.md §5): Algorithm 1 line 32 reads
//! `exp(-(f_new - f)/T) < rand(0,1)` which, taken literally, *rejects* worse
//! solutions almost always and accepts them *less* often at high
//! temperature — inverted from classical SA. We implement the standard
//! maximizing Metropolis rule: a worse solution is accepted with probability
//! `exp((f_new - f) / T_eff)`. Because `G` is tiny (~1e-3 for ms-scale
//! latencies) while the paper's temperatures are O(100), a raw ratio would
//! accept everything; `T_eff` therefore normalizes by the seed objective:
//! `T_eff = (T / T₀) · |f_seed|`. At `T = T₀` a candidate worse by the full
//! seed objective survives with p = e⁻¹, decaying as T cools — matching the
//! qualitative behaviour Fig. 8 reports (higher T₀ ⇒ more escapes).
//!
//! **KV feasibility** ([`SaParams::kv`], Eq. 20): the search carries each
//! batch's KV-block demand — footprint sums under
//! [`crate::coordinator::kv::KvPhaseModel::Reserve`], exact phase-aware
//! occupancy peaks under
//! [`crate::coordinator::kv::KvPhaseModel::Phased`]. Hard mode vetoes
//! overcommitting moves inside the generator and ranks candidates by
//! (excess, G); soft mode penalizes the score by `weight · excess`. The
//! default unlimited pool reproduces the pre-KV search bit for bit
//! (`tests/kv_feasibility.rs`). Under `Phased`, the generator veto
//! re-prices candidate batches at their exact occupancy peaks
//! ([`crate::coordinator::priority::moves::PhasedVeto`]), so hard-mode
//! searches can legally form batches the reserve model would refuse; the
//! `hard_repack` fallback still packs by footprint sums, which bound the
//! phased peak from above, so its feasibility guarantee carries over
//! unchanged.
//!
//! **Timeline** ([`crate::coordinator::objective::TimelineOrigin`]): the
//! evaluators place batches on an arrival-aware timeline; the search is
//! agnostic to it beyond evaluating candidates on whatever timeline the
//! caller's [`Evaluator`] carries. [`priority_mapping`] mirrors the
//! evaluator's arrival column into the [`PredTable`] it builds so the
//! incremental path is bit-identical to the full one, timelines included.
//!
//! **Parallel tempering** ([`SaParams::chains`]): `chains == K ≥ 2` runs K
//! Metropolis chains from the same seed schedule on scoped threads —
//! chain 0 at the configured temperature schedule and seed, chain c at
//! effective temperature ×[`TEMPER_STAGGER`]ᶜ under a [`chain_seed`]-
//! derived RNG stream. Chains run in lockstep rounds of
//! [`SaParams::exchange_period`] temperature levels; between rounds a
//! deterministic best-exchange installs the global champion's incumbent
//! into every chain whose walking state is strictly worse. The result is
//! deterministic for a fixed seed and exchange schedule regardless of
//! thread interleaving, and `chains == 1` (the default) replays the
//! pre-tempering single-chain stream bit for bit (invariant 11 in
//! `docs/ARCHITECTURE.md`).

use crate::coordinator::kv::{self, KvConfig, KvMode};
use crate::coordinator::objective::{
    batch_kv_blocks, Eval, Evaluator, IncrementalEval, Schedule,
};
use crate::coordinator::pred_table::PredTable;
use crate::coordinator::priority::moves;
use crate::util::rng::Rng;

/// Hyperparameters (paper §5.1 defaults: T₀=500, T_thres=20, iter=100,
/// τ=0.95) plus the KV-pool configuration the search must respect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SaParams {
    pub t0: f64,
    pub t_thres: f64,
    pub iters_per_temp: usize,
    pub decay: f64,
    pub max_batch: usize,
    pub seed: u64,
    /// KV-block feasibility (Eq. 20). The default,
    /// [`KvConfig::UNLIMITED`], reproduces the pre-KV search bit for bit;
    /// a finite pool under [`KvMode::Hard`] vetoes overcommitting moves
    /// and orders candidates by (excess, G), under [`KvMode::Soft`]
    /// penalizes the score by `weight · excess_blocks`.
    pub kv: KvConfig,
    /// Parallel-tempering chain count. `1` (the default) runs the classic
    /// single-chain search and replays its RNG stream bit for bit
    /// (invariant 11 in `docs/ARCHITECTURE.md`). `K ≥ 2` runs K chains on
    /// scoped threads: chain 0 at the configured temperature/seed, chain c
    /// at effective temperature ×[`TEMPER_STAGGER`]ᶜ under a derived seed
    /// ([`chain_seed`]), exchanging the global best every
    /// [`SaParams::exchange_period`] temperature levels. Deterministic for
    /// a fixed seed regardless of thread interleaving.
    pub chains: usize,
    /// Temperature levels between deterministic best-exchanges when
    /// `chains ≥ 2` (clamped to ≥ 1). Irrelevant at `chains == 1`.
    pub exchange_period: usize,
    /// Sliding-window width in batches: moves may only edit the first
    /// `window` batches beyond the frozen prefix, so the search plans the
    /// next W dispatches instead of the whole wave. `0` (the default) is
    /// unbounded and replays the unwindowed search bit for bit
    /// (invariant 15 in `docs/ARCHITECTURE.md`).
    pub window: usize,
    /// Chunked-prefill chunk size in tokens the evaluators price at (must
    /// mirror [`crate::engine::sim::SimEngine::with_chunk_tokens`] on the
    /// executing engine). `0` (the default) prices whole-prompt prefill
    /// and replays the unchunked stack bit for bit (invariant 15).
    pub chunk_tokens: usize,
}

impl Default for SaParams {
    fn default() -> Self {
        SaParams {
            t0: 500.0,
            t_thres: 20.0,
            iters_per_temp: 100,
            decay: 0.95,
            max_batch: 8,
            seed: 0,
            kv: KvConfig::UNLIMITED,
            chains: 1,
            exchange_period: 4,
            window: 0,
            chunk_tokens: 0,
        }
    }
}

impl SaParams {
    pub fn with_max_batch(max_batch: usize) -> Self {
        SaParams { max_batch, ..Default::default() }
    }

    /// Number of temperature levels until `t_thres` (the `t` in the paper's
    /// O(t·iter) complexity).
    pub fn temp_levels(&self) -> usize {
        if self.t0 <= self.t_thres {
            return 0;
        }
        ((self.t_thres / self.t0).ln() / self.decay.ln()).ceil() as usize
    }
}

/// Search diagnostics (Table 1 overhead, Fig. 8 sweeps). With tempering
/// (`chains ≥ 2`) the counters aggregate over every chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchStats {
    /// Objective evaluations performed (summed across chains).
    pub evals: usize,
    /// Candidate acceptances (better or Metropolis; summed across chains).
    pub accepted: usize,
    /// Strict improvements over a chain's incumbent best (summed).
    pub improved: usize,
    /// True if the sorted seed met all SLOs (lines 7–10 fast path).
    pub early_exit: bool,
    /// Wall-clock search time (ms): what the caller actually waited.
    pub overhead_ms: f64,
    /// CPU-time search cost (ms): `overhead_ms` plus the off-critical-path
    /// chain time when chains run in parallel. Equals `overhead_ms`
    /// exactly at `chains == 1` — the honest quantity to *sum* across
    /// instances for Fig. 11(B)-style comparisons.
    pub cpu_ms: f64,
    /// Accepted best-exchange adoptions across chains (0 at `chains == 1`).
    pub exchanges: usize,
    /// Temperature index of the chain that produced the returned best
    /// (0 = the base-temperature chain; always 0 at `chains == 1`).
    pub winner_chain: usize,
}

impl SearchStats {
    fn start() -> SearchStats {
        SearchStats {
            evals: 0,
            accepted: 0,
            improved: 0,
            early_exit: false,
            overhead_ms: 0.0,
            cpu_ms: 0.0,
            exchanges: 0,
            winner_chain: 0,
        }
    }
}

/// Result: the best schedule found plus its evaluation and stats.
#[derive(Debug, Clone)]
pub struct SaResult {
    pub schedule: Schedule,
    pub eval: Eval,
    pub stats: SearchStats,
}

/// Bit-level [`Eval`] equality (NaN-tolerant, unlike `PartialEq`): used by
/// the debug cross-check between the incremental and full seed evaluations.
#[allow(dead_code)] // used only under debug_assertions
fn eval_bits_equal(a: &Eval, b: &Eval) -> bool {
    a.g.to_bits() == b.g.to_bits()
        && a.met == b.met
        && a.total_e2e_ms.to_bits() == b.total_e2e_ms.to_bits()
        && a.makespan_ms.to_bits() == b.makespan_ms.to_bits()
}

/// Seeds shared by both search paths: the solo-e2e-sorted schedule
/// (Algorithm 1 line 3) and, when it does not meet every SLO, the FCFS
/// arrival order. Returns `(chosen schedule, its eval, early_exit)`.
fn seed_solution(
    ev: &Evaluator,
    n: usize,
    max_batch: usize,
    kv: &KvConfig,
    stats: &mut SearchStats,
) -> (Schedule, Eval, bool) {
    // Seed 2: sorted by predicted solo e2e (line 3). `total_cmp` so NaN
    // predictor coefficients (misconfigured fit) degrade instead of panic.
    let mut by_e2e: Vec<usize> = (0..n).collect();
    by_e2e.sort_by(|&a, &b| ev.solo_e2e_ms(a).total_cmp(&ev.solo_e2e_ms(b)));
    let sorted_seed = Schedule::from_order(by_e2e, max_batch);
    let sorted_eval = ev.eval(&sorted_seed);
    stats.evals += 1;

    // Lines 7–10: if the minimal-Σe2e sequence meets every SLO it
    // maximizes G — but only a KV-feasible plan may exit early (an
    // unlimited pool always is; the binding check is free there).
    if sorted_eval.met == n && ev.kv_excess(&sorted_seed, kv) == 0 {
        return (sorted_seed, sorted_eval, true);
    }

    // Seed 1: the arrival order (lines 12–15 pick the better start).
    let fcfs_seed = Schedule::fcfs(n, max_batch);
    let fcfs_eval = ev.eval(&fcfs_seed);
    stats.evals += 1;

    if sorted_eval.g >= fcfs_eval.g {
        (sorted_seed, sorted_eval, false)
    } else {
        (fcfs_seed, fcfs_eval, false)
    }
}

/// Deterministic hard-mode safety net: greedily repack `order`'s suffix
/// (everything past the `prefix_batches` frozen prefix, which is kept
/// verbatim) into batches respecting both `max_batch` and the block pool
/// (via the shared [`kv::pack_greedy`] rule). Whenever every job
/// individually fits the pool, the repacked suffix is feasible by
/// construction — so a hard-mode search that ran out of budget before
/// descending to zero excess still returns a plan the engine will
/// accept.
fn hard_repack(
    order: &[usize],
    prefix_batches: &[usize],
    job_blocks: &[u64],
    max_batch: usize,
    pool_blocks: u64,
) -> Schedule {
    let frozen_pos: usize = prefix_batches.iter().sum();
    let mut batches: Vec<usize> = prefix_batches.to_vec();
    kv::pack_greedy(order, frozen_pos, job_blocks, max_batch, pool_blocks, &mut batches);
    Schedule { order: order.to_vec(), batches }
}

/// Effective-temperature stagger between adjacent tempering chains: chain
/// `c` runs its Metropolis rule at `T_eff × TEMPER_STAGGER^c`, so higher
/// chains escape local optima more readily while chain 0 exploits at the
/// configured schedule.
pub const TEMPER_STAGGER: f64 = 1.5;

/// Per-chain RNG seed: chain 0 keeps the base seed verbatim (the K=1
/// bit-identity hinge), higher chains get SplitMix64-style mixed streams
/// so seeded multi-chain runs stay reproducible without replaying each
/// other.
pub fn chain_seed(base: u64, chain: usize) -> u64 {
    if chain == 0 {
        return base;
    }
    let mut z = base ^ (chain as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The exact temperature sequence the classic loop visits: seeded at `t0`,
/// multiplied by `decay` while `≥ t_thres`. Materialized so tempering
/// rounds can chunk it at exchange boundaries; iterating the returned
/// ladder reproduces `while t >= t_thres { …; t *= decay }` bit for bit.
fn temp_ladder(params: &SaParams) -> Vec<f64> {
    let mut temps = Vec::new();
    let mut t = params.t0;
    while t >= params.t_thres {
        temps.push(t);
        t *= params.decay;
    }
    temps
}

/// `(f_a, x_a)` strictly better than `(f_b, x_b)` under `kv`'s candidate
/// ordering — the exact comparison the acceptance loop uses for its
/// incumbent-best update, shared with the exchange step so adopting the
/// global best can never disagree with chain-local best tracking.
fn kv_better(kv: &KvConfig, f_a: &Eval, x_a: u64, f_b: &Eval, x_b: u64) -> bool {
    if kv.prices_preemption() {
        // Swap-priced ordering: overcommitment is a cost, not a veto.
        // At zero excess both scores are the raw g (same bits), so a
        // link-less config can never reach this branch with different
        // results — `prices_preemption` is false there.
        return kv.preempt_score(f_a.g, f_a.met, f_a.total_e2e_ms, x_a)
            > kv.preempt_score(f_b.g, f_b.met, f_b.total_e2e_ms, x_b);
    }
    match kv.mode {
        KvMode::Soft { weight } => {
            KvConfig::soft_score(f_a.g, x_a, weight)
                > KvConfig::soft_score(f_b.g, x_b, weight)
        }
        _ => x_a < x_b || (x_a == x_b && f_a.g > f_b.g),
    }
}

/// One Metropolis chain: the walking incremental state, its RNG stream,
/// and its incumbent best. At `stagger == 1.0` and the full temperature
/// ladder this replays the pre-tempering single-chain loop bit for bit
/// (`x * 1.0` is exact), which is how `chains == 1` keeps invariant 11.
struct ChainState<'e> {
    inc: IncrementalEval<'e>,
    rng: Rng,
    f_cur: Eval,
    x_cur: u64,
    best: Schedule,
    f_best: Eval,
    x_best: u64,
    /// Constant effective-temperature multiplier ([`TEMPER_STAGGER`]ᶜ).
    stagger: f64,
    evals: usize,
    accepted: usize,
    improved: usize,
    /// Wall time this chain spent inside [`ChainState::run_levels`] (ms).
    busy_ms: f64,
}

impl<'e> ChainState<'e> {
    fn new(
        ev: &'e Evaluator<'_>,
        table: &'e PredTable,
        kv: KvConfig,
        seed_schedule: Schedule,
        f_seed: Eval,
        rng: Rng,
        stagger: f64,
    ) -> Self {
        let inc = IncrementalEval::new_kv(
            ev.jobs(),
            table,
            seed_schedule,
            kv,
            ev.t0_ms(),
        );
        debug_assert!(
            eval_bits_equal(&inc.eval(), &f_seed),
            "incremental seed eval {:?} != full {:?}",
            inc.eval(),
            f_seed
        );
        let x_cur = inc.kv_excess();
        let best = inc.schedule().clone();
        ChainState {
            inc,
            rng,
            f_cur: f_seed,
            x_cur,
            best,
            f_best: f_seed,
            x_best: x_cur,
            stagger,
            evals: 0,
            accepted: 0,
            improved: 0,
            busy_ms: 0.0,
        }
    }

    /// Run the Metropolis loop over a slice of the temperature ladder —
    /// the chain-local section of one tempering round. The body is the
    /// classic acceptance loop verbatim, with the chain's stagger folded
    /// into the normalized temperature.
    fn run_levels(
        &mut self,
        temps: &[f64],
        params: &SaParams,
        max_batch: usize,
        frozen_batches: usize,
        f_scale: f64,
    ) {
        let kv = params.kv;
        let t_in = crate::util::now_ms();
        for &t in temps {
            for _ in 0..params.iters_per_temp {
                // Allocation-free move applied against the incremental
                // state; commit or rollback below. `params.window == 0`
                // keeps the classic whole-wave neighbourhood.
                let mv = self.inc.try_random_move_windowed(
                    max_batch,
                    frozen_batches,
                    params.window,
                    &mut self.rng,
                );
                let f_new = match mv {
                    Some(e) => e,
                    None => continue,
                };
                let x_new = self.inc.kv_excess();
                self.evals += 1;
                let accept = if kv.prices_preemption() {
                    // Metropolis on the swap-priced score (see
                    // `KvConfig::preempt_score`): overcommits pay their
                    // modeled swap round-trip instead of being ordered
                    // out lexicographically.
                    let s_new = kv.preempt_score(
                        f_new.g,
                        f_new.met,
                        f_new.total_e2e_ms,
                        x_new,
                    );
                    let s_cur = kv.preempt_score(
                        self.f_cur.g,
                        self.f_cur.met,
                        self.f_cur.total_e2e_ms,
                        self.x_cur,
                    );
                    if s_new > s_cur {
                        true
                    } else {
                        let t_eff = (t * self.stagger / params.t0) * f_scale;
                        self.rng.chance(((s_new - s_cur) / t_eff).exp())
                    }
                } else {
                    match kv.mode {
                    KvMode::Soft { weight } => {
                        let s_new = KvConfig::soft_score(f_new.g, x_new, weight);
                        let s_cur =
                            KvConfig::soft_score(self.f_cur.g, self.x_cur, weight);
                        if s_new > s_cur {
                            true
                        } else {
                            // Metropolis with normalized temperature
                            // (see module docs).
                            let t_eff =
                                (t * self.stagger / params.t0) * f_scale;
                            self.rng.chance(((s_new - s_cur) / t_eff).exp())
                        }
                    }
                    // Unlimited (x always 0) and Hard share one structure.
                    _ => {
                        if x_new != self.x_cur {
                            x_new < self.x_cur
                        } else if f_new.g > self.f_cur.g {
                            true
                        } else {
                            let t_eff =
                                (t * self.stagger / params.t0) * f_scale;
                            self.rng.chance(
                                ((f_new.g - self.f_cur.g) / t_eff).exp(),
                            )
                        }
                    }
                    }
                };
                if accept {
                    self.inc.commit();
                    self.f_cur = f_new;
                    self.x_cur = x_new;
                    self.accepted += 1;
                    if kv_better(
                        &kv,
                        &self.f_cur,
                        self.x_cur,
                        &self.f_best,
                        self.x_best,
                    ) {
                        self.best.order.clear();
                        self.best
                            .order
                            .extend_from_slice(&self.inc.schedule().order);
                        self.best.batches.clear();
                        self.best
                            .batches
                            .extend_from_slice(&self.inc.schedule().batches);
                        self.f_best = self.f_cur;
                        self.x_best = self.x_cur;
                        self.improved += 1;
                    }
                } else {
                    self.inc.rollback();
                }
            }
        }
        self.busy_ms += crate::util::now_ms() - t_in;
    }
}

/// Index of the chain holding the strictly best incumbent (ties keep the
/// lowest index — deterministic regardless of thread interleaving).
fn champion(chains: &[ChainState<'_>], kv: &KvConfig) -> usize {
    let mut champ = 0usize;
    for (c, chain) in chains.iter().enumerate().skip(1) {
        if kv_better(
            kv,
            &chain.f_best,
            chain.x_best,
            &chains[champ].f_best,
            chains[champ].x_best,
        ) {
            champ = c;
        }
    }
    champ
}

/// The shared Metropolis loop: anneal from `seed_schedule` against a
/// prebuilt prediction table, with the first `frozen_batches` batches
/// masked off from every move. `frozen_batches == 0` reproduces the
/// classic closed-wave search bit for bit.
///
/// **Parallel tempering** (`params.chains`): at `chains == 1` one chain
/// runs the classic loop — same RNG stream, same stats, same result as
/// the pre-tempering search (invariant 11). At `chains == K ≥ 2`, K
/// chains start from the same seed schedule with [`chain_seed`]-derived
/// RNG streams and [`TEMPER_STAGGER`]-staggered effective temperatures,
/// running in lockstep rounds of [`SaParams::exchange_period`]
/// temperature levels on scoped threads. Between rounds the driver
/// performs a deterministic best-exchange: every chain whose walking
/// state is strictly worse (under the same candidate ordering the
/// acceptance loop uses) than the global champion's incumbent adopts that
/// incumbent. The final result is the champion's best after the last
/// round — deterministic for a fixed seed and exchange schedule.
///
/// **KV acceptance** (`params.kv`): with an unlimited pool every excess
/// is zero and the rule collapses to the pre-KV comparison, drawing
/// the identical RNG stream. Under [`KvMode::Hard`] candidates are
/// ordered lexicographically by (excess, G) — the veto inside the move
/// generator already prevents excess from growing, and the lexicon lets a
/// search seeded infeasibly descend into feasibility first. Under
/// [`KvMode::Soft`] the Metropolis rule runs on the penalized score
/// `G − weight · excess`.
#[allow(clippy::too_many_arguments)]
fn anneal(
    ev: &Evaluator,
    table: &PredTable,
    params: &SaParams,
    max_batch: usize,
    frozen_batches: usize,
    seed_schedule: Schedule,
    f_seed: Eval,
    mut stats: SearchStats,
    t_start: f64,
) -> SaResult {
    let kv = params.kv;
    // Layer 2: incremental evaluators own the walking candidate state.
    // The table's arrival column must mirror the evaluator's timeline —
    // the two are the same storage on the online path, and
    // `priority_mapping` syncs them on the closed path.
    debug_assert!(
        if ev.arrivals().is_empty() {
            table.arrivals_all().iter().all(|&a| a == 0.0)
        } else {
            ev.arrivals() == table.arrivals_all()
        },
        "prediction-table arrival column diverges from the evaluator"
    );
    let f_scale = f_seed.g.abs().max(1e-12);
    let temps = temp_ladder(params);
    let n_chains = params.chains.max(1);

    let (mut best, mut f_best, x_best, extra_cpu_ms) = if n_chains == 1 {
        // Single chain: the pre-tempering search, bit for bit.
        let mut chain = ChainState::new(
            ev,
            table,
            kv,
            seed_schedule,
            f_seed,
            Rng::new(params.seed),
            1.0,
        );
        chain.run_levels(&temps, params, max_batch, frozen_batches, f_scale);
        stats.evals += chain.evals;
        stats.accepted += chain.accepted;
        stats.improved += chain.improved;
        stats.winner_chain = 0;
        (chain.best, chain.f_best, chain.x_best, 0.0)
    } else {
        let mut chains: Vec<ChainState> = (0..n_chains)
            .map(|c| {
                ChainState::new(
                    ev,
                    table,
                    kv,
                    seed_schedule.clone(),
                    f_seed,
                    Rng::new(chain_seed(params.seed, c)),
                    TEMPER_STAGGER.powi(c as i32),
                )
            })
            .collect();
        let period = params.exchange_period.max(1);
        let mut rounds_wall_ms = 0.0f64;
        let mut round_temps_iter = temps.chunks(period).peekable();
        while let Some(round_temps) = round_temps_iter.next() {
            let round_in = crate::util::now_ms();
            std::thread::scope(|scope| {
                let handles: Vec<_> = chains
                    .iter_mut()
                    .map(|chain| {
                        scope.spawn(move || {
                            chain.run_levels(
                                round_temps,
                                params,
                                max_batch,
                                frozen_batches,
                                f_scale,
                            )
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().expect("tempering chain panicked");
                }
            });
            rounds_wall_ms += crate::util::now_ms() - round_in;
            // Deterministic best-exchange between rounds (skipped after
            // the final round — the champion is extracted below anyway).
            if round_temps_iter.peek().is_none() {
                break;
            }
            let champ = champion(&chains, &kv);
            let champ_best = chains[champ].best.clone();
            let champ_f = chains[champ].f_best;
            let champ_x = chains[champ].x_best;
            for (c, chain) in chains.iter_mut().enumerate() {
                if c == champ {
                    continue;
                }
                if kv_better(&kv, &champ_f, champ_x, &chain.f_cur, chain.x_cur)
                {
                    // Adopt the global best as this chain's walking state
                    // (rebuilt aggregates keep the incremental == full
                    // guarantee; the chain's own RNG stream continues).
                    chain.inc.reset(champ_best.clone());
                    chain.f_cur = chain.inc.eval();
                    chain.x_cur = chain.inc.kv_excess();
                    stats.exchanges += 1;
                    if kv_better(
                        &kv,
                        &champ_f,
                        champ_x,
                        &chain.f_best,
                        chain.x_best,
                    ) {
                        chain.best.clone_from(&champ_best);
                        chain.f_best = champ_f;
                        chain.x_best = champ_x;
                    }
                }
            }
        }
        let champ = champion(&chains, &kv);
        stats.winner_chain = champ;
        let busy_ms: f64 = chains.iter().map(|c| c.busy_ms).sum();
        for chain in &chains {
            stats.evals += chain.evals;
            stats.accepted += chain.accepted;
            stats.improved += chain.improved;
        }
        let winner = chains.swap_remove(champ);
        // Off-critical-path chain time: what parallelism hid from wall
        // clock (clamped — spawn overhead can exceed tiny workloads).
        ((winner.best), winner.f_best, winner.x_best, {
            (busy_ms - rounds_wall_ms).max(0.0)
        })
    };

    // Hard-mode fallback: if the budgeted walk never reached zero excess,
    // repack the best order within the pool (feasible whenever every job
    // fits alone). Never fires with an unlimited pool (x_best == 0), so
    // the bit-identity contract is untouched; mirrored verbatim in
    // `priority_mapping_full` to keep the fast == full equivalence. A
    // swap-priced pool keeps its (deliberately) overcommitted winner:
    // the excess is an execution-time preemption plan, not a bug.
    if kv.vetoes_moves() && x_best > 0 {
        let repacked = hard_repack(
            &best.order,
            &best.batches[..frozen_batches],
            table.kv_blocks_all(),
            max_batch,
            kv.pool_blocks,
        );
        let f_re = ev.eval(&repacked);
        let x_re = ev.kv_excess(&repacked, &kv);
        stats.evals += 1;
        if x_re < x_best || (x_re == x_best && f_re.g > f_best.g) {
            best = repacked;
            f_best = f_re;
        }
    }

    stats.overhead_ms = crate::util::now_ms() - t_start;
    stats.cpu_ms = stats.overhead_ms + extra_cpu_ms;
    SaResult { schedule: best, eval: f_best, stats }
}

/// Algorithm 1: map jobs to a priority sequence + batch partition.
///
/// Production path: prediction-table + incremental-evaluation SA (see
/// module docs). Bit-identical evaluations to [`priority_mapping_full`]'s
/// per-candidate full evaluation, at a fraction of the cost.
pub fn priority_mapping(ev: &Evaluator, params: &SaParams) -> SaResult {
    let t_start = crate::util::now_ms();
    let n = ev.jobs().len();
    let max_batch = params.max_batch.max(1);
    let mut stats = SearchStats::start();

    if n == 0 {
        return SaResult {
            schedule: Schedule { order: vec![], batches: vec![] },
            eval: Eval::ZERO,
            stats,
        };
    }

    let (seed_schedule, f_seed, early_exit) =
        seed_solution(ev, n, max_batch, &params.kv, &mut stats);
    if early_exit {
        stats.early_exit = true;
        stats.overhead_ms = crate::util::now_ms() - t_start;
        stats.cpu_ms = stats.overhead_ms;
        return SaResult { schedule: seed_schedule, eval: f_seed, stats };
    }

    // Layer 1: precompute every (job, batch_size) prediction — and each
    // job's KV-block footprint — for the wave, mirroring the evaluator's
    // timeline arrivals into the table so the incremental path sees the
    // exact same per-job arrival column (zeros for closed waves). The
    // chunk column is computed at the evaluator's chunk size (the
    // authoritative one) so the incremental chunked pricing is
    // bit-identical to the full evaluation.
    let mut table = PredTable::build_kv_chunked(
        ev.jobs(),
        ev.predictor(),
        max_batch,
        &params.kv,
        ev.chunk_tokens(),
    );
    if !ev.arrivals().is_empty() {
        table.set_arrivals(ev.arrivals());
    }
    anneal(
        ev,
        &table,
        params,
        max_batch,
        0,
        seed_schedule,
        f_seed,
        stats,
        t_start,
    )
}

/// Algorithm 1 with **warm start** and **frozen-prefix masking** over a
/// caller-supplied prediction table — the online replanning entry point
/// ([`crate::coordinator::online::WaveController`]).
///
/// * `table` — grown in place across admissions ([`PredTable::extend`]);
///   must cover all `ev.jobs()` at `params.max_batch`.
/// * `warm` — the current best schedule (typically the previous plan with
///   newly admitted jobs appended). With `frozen_batches == 0` it competes
///   against Algorithm 1's two cold seeds and the best of the three starts
///   the search, so a warm search never starts below a cold one; with
///   `frozen_batches > 0` the cold seeds would reorder dispatched work, so
///   `warm` is required and seeds the search alone.
/// * `frozen_batches` — leading batches already dispatched: no move ever
///   changes their membership, order, or boundaries.
///
/// With `warm == None` and `frozen_batches == 0` this is bit-identical to
/// [`priority_mapping`] (same seeds, same RNG stream, same result) apart
/// from reusing the supplied table — the online-equals-offline guarantee.
pub fn priority_mapping_warm(
    ev: &Evaluator,
    table: &PredTable,
    params: &SaParams,
    warm: Option<&Schedule>,
    frozen_batches: usize,
) -> SaResult {
    let t_start = crate::util::now_ms();
    let n = ev.jobs().len();
    let max_batch = params.max_batch.max(1);
    let mut stats = SearchStats::start();

    if n == 0 {
        return SaResult {
            schedule: Schedule { order: vec![], batches: vec![] },
            eval: Eval::ZERO,
            stats,
        };
    }
    assert_eq!(table.len(), n, "prediction table does not cover the jobs");
    assert!(
        table.max_batch() >= max_batch,
        "prediction table built for max_batch {} < {}",
        table.max_batch(),
        max_batch
    );
    assert!(
        !params.kv.binding()
            || table.block_tokens() == params.kv.block_tokens,
        "prediction table footprints rounded at {} tokens/block but the \
         search enforces {} tokens/block",
        table.block_tokens(),
        params.kv.block_tokens
    );
    assert!(
        !params.kv.binding() || table.lo_mult() == params.kv.lo_mult,
        "prediction table reservation column computed at lo_mult {} but \
         the search enforces lo_mult {}",
        table.lo_mult(),
        params.kv.lo_mult
    );
    assert_eq!(
        table.chunk_tokens(),
        ev.chunk_tokens(),
        "prediction table chunk column computed at a different chunk size \
         than the evaluator prices at"
    );

    if frozen_batches > 0 {
        let warm = warm.expect("a frozen prefix requires a warm-start schedule");
        assert_eq!(warm.len(), n, "warm schedule does not cover the jobs");
        assert!(
            frozen_batches <= warm.batches.len(),
            "frozen prefix beyond the warm schedule"
        );
        let seed_schedule = warm.clone();
        let f_seed = ev.eval(&seed_schedule);
        stats.evals += 1;
        return anneal(
            ev,
            table,
            params,
            max_batch,
            frozen_batches,
            seed_schedule,
            f_seed,
            stats,
            t_start,
        );
    }

    let (mut seed_schedule, mut f_seed, early_exit) =
        seed_solution(ev, n, max_batch, &params.kv, &mut stats);
    if early_exit {
        stats.early_exit = true;
        stats.overhead_ms = crate::util::now_ms() - t_start;
        stats.cpu_ms = stats.overhead_ms;
        return SaResult { schedule: seed_schedule, eval: f_seed, stats };
    }
    if let Some(w) = warm {
        assert_eq!(w.len(), n, "warm schedule does not cover the jobs");
        let f_w = ev.eval(w);
        stats.evals += 1;
        if f_w.g > f_seed.g {
            seed_schedule = w.clone();
            f_seed = f_w;
        }
    }
    anneal(
        ev,
        table,
        params,
        max_batch,
        0,
        seed_schedule,
        f_seed,
        stats,
        t_start,
    )
}

/// Algorithm 1 with per-candidate **full** evaluation — the pre-table
/// reference path. Kept for the equivalence property tests and the
/// old-vs-new comparison in `benches/sa_throughput.rs`; use
/// [`priority_mapping`] everywhere else.
///
/// Always single-chain: `params.chains` is ignored, so this is the
/// untempered reference the `chains == 1` production path must match bit
/// for bit (invariant 11).
pub fn priority_mapping_full(ev: &Evaluator, params: &SaParams) -> SaResult {
    let t_start = crate::util::now_ms();
    let n = ev.jobs().len();
    let max_batch = params.max_batch.max(1);
    let mut stats = SearchStats::start();

    if n == 0 {
        return SaResult {
            schedule: Schedule { order: vec![], batches: vec![] },
            eval: Eval::ZERO,
            stats,
        };
    }

    let kv = params.kv;
    let (seed_schedule, f_seed, early_exit) =
        seed_solution(ev, n, max_batch, &kv, &mut stats);
    if early_exit {
        stats.early_exit = true;
        stats.overhead_ms = crate::util::now_ms() - t_start;
        stats.cpu_ms = stats.overhead_ms;
        return SaResult { schedule: seed_schedule, eval: f_seed, stats };
    }

    // KV mirror of the fast path: per-job footprints once, per-candidate
    // occupancy recomputed from scratch (this is the O(N) reference).
    let job_blocks: Vec<u64> = ev
        .jobs()
        .iter()
        .map(|j| kv.job_blocks(j.input_len, j.output_len))
        .collect();
    let mut bb: Vec<u64> = Vec::new();

    let mut current = seed_schedule;
    let mut f_cur = f_seed;
    let mut x_cur = ev.kv_excess(&current, &kv);
    let mut best = current.clone();
    let mut f_best = f_cur;
    let mut x_best = x_cur;

    let f_scale = f_cur.g.abs().max(1e-12);
    let mut rng = Rng::new(params.seed);
    let mut t = params.t0;
    let mut candidate = current.clone();

    while t >= params.t_thres {
        for _ in 0..params.iters_per_temp {
            candidate.order.clear();
            candidate.order.extend_from_slice(&current.order);
            candidate.batches.clear();
            candidate.batches.extend_from_slice(&current.batches);
            let moved = if kv.vetoes_moves() {
                batch_kv_blocks(&candidate, ev.jobs(), &job_blocks, &kv, &mut bb);
                let veto = moves::KvVeto {
                    job_blocks: &job_blocks,
                    batch_blocks: &bb,
                    pool_blocks: kv.pool_blocks,
                    phased: if kv.phased() {
                        Some(moves::PhasedVeto {
                            jobs: ev.jobs(),
                            block_tokens: kv.block_tokens,
                        })
                    } else {
                        None
                    },
                };
                moves::random_move_desc_win(
                    &mut candidate,
                    max_batch,
                    0,
                    params.window,
                    Some(&veto),
                    &mut rng,
                )
                .is_some()
            } else {
                // window = 0 replays `moves::random_move`'s stream exactly.
                moves::random_move_desc_win(
                    &mut candidate,
                    max_batch,
                    0,
                    params.window,
                    None,
                    &mut rng,
                )
                .is_some()
            };
            if !moved {
                continue;
            }
            let f_new = ev.eval(&candidate);
            let x_new = ev.kv_excess(&candidate, &kv);
            stats.evals += 1;
            let accept = if kv.prices_preemption() {
                // mirror of the fast path's swap-priced Metropolis rule
                let s_new =
                    kv.preempt_score(f_new.g, f_new.met, f_new.total_e2e_ms, x_new);
                let s_cur =
                    kv.preempt_score(f_cur.g, f_cur.met, f_cur.total_e2e_ms, x_cur);
                if s_new > s_cur {
                    true
                } else {
                    let t_eff = (t / params.t0) * f_scale;
                    rng.chance(((s_new - s_cur) / t_eff).exp())
                }
            } else {
                match kv.mode {
                    KvMode::Soft { weight } => {
                        let s_new = KvConfig::soft_score(f_new.g, x_new, weight);
                        let s_cur = KvConfig::soft_score(f_cur.g, x_cur, weight);
                        if s_new > s_cur {
                            true
                        } else {
                            let t_eff = (t / params.t0) * f_scale;
                            rng.chance(((s_new - s_cur) / t_eff).exp())
                        }
                    }
                    _ => {
                        if x_new != x_cur {
                            x_new < x_cur
                        } else if f_new.g > f_cur.g {
                            true
                        } else {
                            let t_eff = (t / params.t0) * f_scale;
                            rng.chance(((f_new.g - f_cur.g) / t_eff).exp())
                        }
                    }
                }
            };
            if accept {
                std::mem::swap(&mut current, &mut candidate);
                f_cur = f_new;
                x_cur = x_new;
                stats.accepted += 1;
                let improved = kv_better(&kv, &f_cur, x_cur, &f_best, x_best);
                if improved {
                    best.order.clear();
                    best.order.extend_from_slice(&current.order);
                    best.batches.clear();
                    best.batches.extend_from_slice(&current.batches);
                    f_best = f_cur;
                    x_best = x_cur;
                    stats.improved += 1;
                }
            }
        }
        t *= params.decay;
    }

    // Hard-mode fallback, mirroring `anneal` (see the comment there).
    if kv.vetoes_moves() && x_best > 0 {
        let repacked = hard_repack(
            &best.order,
            &best.batches[..0],
            &job_blocks,
            max_batch,
            kv.pool_blocks,
        );
        let f_re = ev.eval(&repacked);
        let x_re = ev.kv_excess(&repacked, &kv);
        stats.evals += 1;
        if x_re < x_best || (x_re == x_best && f_re.g > f_best.g) {
            best = repacked;
            f_best = f_re;
        }
    }

    stats.overhead_ms = crate::util::now_ms() - t_start;
    stats.cpu_ms = stats.overhead_ms;
    SaResult { schedule: best, eval: f_best, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::objective::Job;
    use crate::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
    use crate::coordinator::request::Slo;

    fn unit_predictor() -> LatencyPredictor {
        LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 1.0 },
        )
    }

    fn e2e_job(input: usize, bound: f64) -> Job {
        Job {
            req_idx: 0,
            input_len: input,
            output_len: 0,
            slo: Slo::E2e { e2e_ms: bound },
        }
    }

    fn params(max_batch: usize, seed: u64) -> SaParams {
        SaParams { max_batch, seed, ..Default::default() }
    }

    #[test]
    fn early_exit_when_sjf_meets_all() {
        let pred = unit_predictor();
        let jobs =
            vec![e2e_job(100, 1e6), e2e_job(300, 1e6), e2e_job(200, 1e6)];
        let ev = Evaluator::new(&jobs, &pred);
        let res = priority_mapping(&ev, &params(1, 0));
        assert!(res.stats.early_exit);
        assert_eq!(res.eval.met, 3);
        // order should be shortest-first
        assert_eq!(res.schedule.order, vec![0, 2, 1]);
    }

    #[test]
    fn solves_figure3() {
        // Fig. 3: SA must discover order (2,1,3) meeting all three SLOs.
        let pred = unit_predictor();
        let jobs = vec![
            e2e_job(300, 800.0),
            e2e_job(500, 500.0),
            e2e_job(800, 1800.0),
        ];
        let ev = Evaluator::new(&jobs, &pred);
        let res = priority_mapping(&ev, &params(1, 1));
        assert_eq!(res.eval.met, 3, "SA should meet all SLOs: {:?}", res.eval);
        assert_eq!(res.schedule.order, vec![1, 0, 2]);
    }

    #[test]
    fn solves_figure5_defers_impossible_job() {
        // Fig. 5: job 1 cannot meet its SLO; greedy strict-first ordering
        // sacrifices job 2. SA should defer job 1 and meet 2 of 3.
        let pred = unit_predictor();
        let jobs = vec![
            e2e_job(800, 500.0),  // impossible
            e2e_job(500, 600.0),
            e2e_job(1400, 2900.0),
        ];
        let ev = Evaluator::new(&jobs, &pred);
        let res = priority_mapping(&ev, &params(1, 2));
        assert_eq!(res.eval.met, 2, "{:?}", res.eval);
        // job 1 (idx 0) must not run first
        assert_ne!(res.schedule.order[0], 0);
    }

    #[test]
    fn batch_splitting_discovered() {
        // Fig. 4 analogue: with interaction-heavy costs, batching all three
        // requests together violates two strict SLOs; deferring the loose
        // one into a second iteration meets all three.
        let pred = LatencyPredictor::new(
            // prefill: strongly batch-sensitive
            PhaseCoeffs { alpha: 1.0, beta: 0.0, gamma: 0.0, delta: 0.0 },
            PhaseCoeffs::ZERO,
        );
        let jobs = vec![
            e2e_job(100, 220.0), // exec(b) = 100*b
            e2e_job(100, 220.0),
            e2e_job(100, 1000.0), // loose
        ];
        let ev = Evaluator::new(&jobs, &pred);
        // max batch 3: batching all -> exec 300 > 220 for strict jobs.
        let res = priority_mapping(&ev, &params(3, 3));
        assert_eq!(res.eval.met, 3, "{:?} {:?}", res.eval, res.schedule);
        assert!(res.schedule.batches.len() >= 2);
    }

    #[test]
    fn result_is_never_worse_than_seeds() {
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..5u64 {
            let mut rng = Rng::new(seed + 100);
            let jobs: Vec<Job> = (0..12)
                .map(|_| {
                    let input = rng.range(50, 1500) as usize;
                    let output = rng.range(20, 400) as usize;
                    let bound = rng.uniform(2_000.0, 60_000.0);
                    Job {
                        req_idx: 0,
                        input_len: input,
                        output_len: output,
                        slo: Slo::E2e { e2e_ms: bound },
                    }
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let res = priority_mapping(
                &ev,
                &params(4, seed),
            );
            let fcfs = ev.eval(&Schedule::fcfs(12, 4));
            assert!(
                res.eval.g >= fcfs.g - 1e-15,
                "seed {seed}: SA {:?} worse than FCFS {:?}",
                res.eval,
                fcfs
            );
            res.schedule.validate(4).unwrap();
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let pred = LatencyPredictor::paper_table2();
        let jobs: Vec<Job> =
            (0..8).map(|i| e2e_job(100 * (i + 1), 5_000.0)).collect();
        let ev = Evaluator::new(&jobs, &pred);
        let a = priority_mapping(&ev, &params(2, 9));
        let b = priority_mapping(&ev, &params(2, 9));
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.eval, b.eval);
    }

    #[test]
    fn incremental_path_matches_full_path_exactly() {
        // Same RNG stream + bit-identical evaluations => the two search
        // paths must walk the same trajectory and return the same result.
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..4u64 {
            let mut rng = Rng::new(seed ^ 0xA5A5);
            let jobs: Vec<Job> = (0..14)
                .map(|_| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(1200),
                    output_len: 1 + rng.below(300),
                    slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let p = SaParams {
                max_batch: 4,
                seed,
                t0: 100.0,
                iters_per_temp: 25,
                ..Default::default()
            };
            let fast = priority_mapping(&ev, &p);
            let full = priority_mapping_full(&ev, &p);
            assert_eq!(fast.schedule, full.schedule, "seed {seed}");
            assert_eq!(fast.eval, full.eval, "seed {seed}");
            assert_eq!(fast.stats.evals, full.stats.evals, "seed {seed}");
            assert_eq!(fast.stats.accepted, full.stats.accepted, "seed {seed}");
        }
    }

    #[test]
    fn warm_entry_without_warm_seed_matches_priority_mapping_exactly() {
        use crate::coordinator::pred_table::PredTable;
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed ^ 0x11CE);
            let jobs: Vec<Job> = (0..13)
                .map(|_| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(1400),
                    output_len: 1 + rng.below(350),
                    slo: Slo::E2e { e2e_ms: rng.uniform(800.0, 15_000.0) },
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let p = params(4, seed);
            let table = PredTable::build(&jobs, &pred, p.max_batch);
            let cold = priority_mapping(&ev, &p);
            let warm = priority_mapping_warm(&ev, &table, &p, None, 0);
            assert_eq!(cold.schedule, warm.schedule, "seed {seed}");
            assert_eq!(cold.eval, warm.eval, "seed {seed}");
            assert_eq!(cold.stats.evals, warm.stats.evals, "seed {seed}");
        }
    }

    #[test]
    fn warm_start_never_ends_below_its_seed_and_keeps_frozen_prefix() {
        use crate::coordinator::pred_table::PredTable;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xF00D);
        let jobs: Vec<Job> = (0..12)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1200),
                output_len: 1 + rng.below(300),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 10_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let p = params(3, 4);
        let table = PredTable::build(&jobs, &pred, p.max_batch);
        let warm = Schedule::fcfs(12, 3);
        let f_warm = ev.eval(&warm);
        let frozen = 2usize;
        let frozen_pos: usize = warm.batches[..frozen].iter().sum();
        let res = priority_mapping_warm(&ev, &table, &p, Some(&warm), frozen);
        res.schedule.validate(3).unwrap();
        assert!(
            res.eval.g >= f_warm.g,
            "warm result {:?} below its seed {:?}",
            res.eval,
            f_warm
        );
        assert_eq!(
            res.schedule.order[..frozen_pos],
            warm.order[..frozen_pos],
            "frozen prefix reordered"
        );
        assert_eq!(res.schedule.batches[..frozen], warm.batches[..frozen]);
    }

    #[test]
    fn hard_kv_mode_returns_feasible_plans() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xCAFE);
        for seed in 0..4u64 {
            let jobs: Vec<Job> = (0..14)
                .map(|_| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(120),
                    output_len: 1 + rng.below(60),
                    slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
                })
                .collect();
            // pool large enough for any single job (<= 12 blocks) but far
            // below max_batch * max job footprint
            let kv = KvConfig::hard(20);
            let p = SaParams { kv, ..params(6, seed) };
            let ev = Evaluator::new(&jobs, &pred);
            let res = priority_mapping(&ev, &p);
            res.schedule.validate(6).unwrap();
            assert_eq!(
                ev.kv_excess(&res.schedule, &kv),
                0,
                "seed {seed}: infeasible plan {:?}",
                res.schedule
            );
        }
    }

    #[test]
    fn soft_kv_mode_discourages_overcommit() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xBEEF);
        let jobs: Vec<Job> = (0..12)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(120),
                output_len: 1 + rng.below(60),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let kv = KvConfig::soft(20, 1.0); // 1 excess block ≫ any G gain
        let ev = Evaluator::new(&jobs, &pred);
        let res =
            priority_mapping(&ev, &SaParams { kv, ..params(6, 1) });
        assert_eq!(ev.kv_excess(&res.schedule, &kv), 0, "{:?}", res.schedule);
    }

    #[test]
    fn swap_priced_pool_prices_instead_of_vetoing() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x5A4B);
        let jobs: Vec<Job> = (0..12)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(120),
                output_len: 1 + rng.below(60),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        // A generous swap link makes overcommit cheap: the search may (or
        // may not) keep an overcommitted plan, but must stay well-formed,
        // deterministic, and never fall back to hard repack.
        let priced = KvConfig::hard(20).with_swap(8.0, 8.0, 64);
        assert!(priced.prices_preemption() && !priced.vetoes_moves());
        let p = SaParams { kv: priced, ..params(6, 7) };
        let res = priority_mapping(&ev, &p);
        res.schedule.validate(6).unwrap();
        let rerun = priority_mapping(&ev, &p);
        assert_eq!(res.schedule, rerun.schedule);
        assert_eq!(res.eval, rerun.eval);
        // fast == full equivalence holds on the priced branch too
        let full = priority_mapping_full(&ev, &p);
        assert_eq!(res.schedule, full.schedule);
        assert_eq!(res.stats.evals, full.stats.evals);
        assert_eq!(res.stats.accepted, full.stats.accepted);
        // escape hatch: a zero-bandwidth link is exactly plain Hard
        let plain = priority_mapping(&ev, &SaParams {
            kv: KvConfig::hard(20),
            ..params(6, 7)
        });
        let unpriced = priority_mapping(&ev, &SaParams {
            kv: KvConfig::hard(20).with_swap(0.0, 8.0, 64),
            ..params(6, 7)
        });
        assert_eq!(plain.schedule, unpriced.schedule);
        assert_eq!(plain.eval.g.to_bits(), unpriced.eval.g.to_bits());
        assert_eq!(plain.stats.evals, unpriced.stats.evals);
        assert_eq!(plain.stats.accepted, unpriced.stats.accepted);
    }

    #[test]
    fn fast_and_full_paths_agree_under_finite_pools() {
        use crate::coordinator::kv::{KvConfig, KvPhaseModel};
        let pred = LatencyPredictor::paper_table2();
        for (seed, kv) in [
            (0u64, KvConfig::hard(18)),
            (1, KvConfig::soft(18, 0.5)),
            (2, KvConfig::hard(6)),
            (3, KvConfig::hard(18).with_phase(KvPhaseModel::Phased)),
            (4, KvConfig::soft(12, 0.5).with_phase(KvPhaseModel::Phased)),
        ] {
            let mut rng = Rng::new(seed ^ 0x3A3A);
            let jobs: Vec<Job> = (0..13)
                .map(|_| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(90),
                    output_len: 1 + rng.below(40),
                    slo: Slo::E2e { e2e_ms: rng.uniform(800.0, 12_000.0) },
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let p = SaParams {
                max_batch: 4,
                seed,
                t0: 100.0,
                iters_per_temp: 25,
                kv,
                ..Default::default()
            };
            let fast = priority_mapping(&ev, &p);
            let full = priority_mapping_full(&ev, &p);
            assert_eq!(fast.schedule, full.schedule, "seed {seed}");
            assert_eq!(fast.eval, full.eval, "seed {seed}");
            assert_eq!(fast.stats.evals, full.stats.evals, "seed {seed}");
            assert_eq!(fast.stats.accepted, full.stats.accepted, "seed {seed}");
        }
    }

    #[test]
    fn phased_hard_mode_returns_feasible_plans() {
        use crate::coordinator::kv::{KvConfig, KvPhaseModel};
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x50A5);
        for seed in 0..4u64 {
            // mixed output lengths: short jobs free their blocks early,
            // so the phased peak sits well below the reserve sum.
            let jobs: Vec<Job> = (0..14)
                .map(|i| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(120),
                    output_len: 1 + 60 * (i % 3),
                    slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
                })
                .collect();
            let reserve = KvConfig::hard(20);
            let phased = reserve.with_phase(KvPhaseModel::Phased);
            let ev = Evaluator::new(&jobs, &pred);
            let res_r =
                priority_mapping(&ev, &SaParams { kv: reserve, ..params(6, seed) });
            let res_p =
                priority_mapping(&ev, &SaParams { kv: phased, ..params(6, seed) });
            // both feasible under their own demand model …
            assert_eq!(ev.kv_excess(&res_r.schedule, &reserve), 0, "seed {seed}");
            assert_eq!(ev.kv_excess(&res_p.schedule, &phased), 0, "seed {seed}");
            // … and every reserve-feasible plan is phased-feasible too
            assert_eq!(ev.kv_excess(&res_r.schedule, &phased), 0, "seed {seed}");
        }
    }

    #[test]
    fn timeline_evaluator_fast_equals_full() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x71AE);
        let jobs: Vec<Job> = (0..13)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1200),
                output_len: 1 + rng.below(300),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let arrivals: Vec<f64> =
            (0..13).map(|i| 150.0 * i as f64).collect();
        let ev = Evaluator::with_arrivals(&jobs, &pred, 40.0, &arrivals);
        for seed in 0..3u64 {
            let p = SaParams {
                max_batch: 4,
                seed,
                t0: 100.0,
                iters_per_temp: 25,
                ..Default::default()
            };
            let fast = priority_mapping(&ev, &p);
            let full = priority_mapping_full(&ev, &p);
            assert_eq!(fast.schedule, full.schedule, "seed {seed}");
            assert_eq!(fast.eval, full.eval, "seed {seed}");
            assert_eq!(fast.stats.evals, full.stats.evals, "seed {seed}");
        }
    }

    #[test]
    fn nan_predictor_coefficients_do_not_panic() {
        // A degenerate fit can produce NaN coefficients; the seed sort uses
        // total_cmp and the Metropolis rule rejects NaN objectives, so the
        // mapper must still return a structurally valid schedule.
        let pred = LatencyPredictor::new(
            PhaseCoeffs { alpha: f64::NAN, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: f64::NAN, gamma: 0.0, delta: 1.0 },
        );
        let jobs: Vec<Job> =
            (0..6).map(|i| e2e_job(100 * (i + 1), 5_000.0)).collect();
        let ev = Evaluator::new(&jobs, &pred);
        let res = priority_mapping(&ev, &params(3, 0));
        res.schedule.validate(3).unwrap();
        assert_eq!(res.schedule.len(), 6);
        let res_full = priority_mapping_full(&ev, &params(3, 0));
        res_full.schedule.validate(3).unwrap();
    }

    #[test]
    fn empty_input() {
        let pred = unit_predictor();
        let jobs: Vec<Job> = vec![];
        let ev = Evaluator::new(&jobs, &pred);
        let res = priority_mapping(&ev, &params(4, 0));
        assert!(res.schedule.is_empty());
        assert_eq!(res.eval.met, 0);
    }

    #[test]
    fn temp_levels_matches_paper_defaults() {
        let p = SaParams::default();
        // ln(20/500)/ln(0.95) ≈ 62.7 -> 63 levels
        assert_eq!(p.temp_levels(), 63);
    }

    #[test]
    fn temp_ladder_replays_the_classic_cooling_loop() {
        let p = SaParams::default();
        let temps = temp_ladder(&p);
        assert_eq!(temps.len(), 63);
        assert_eq!(temps[0].to_bits(), p.t0.to_bits());
        let mut t = p.t0;
        for &lt in &temps {
            assert_eq!(lt.to_bits(), t.to_bits());
            t *= p.decay;
        }
        assert!(t < p.t_thres);
    }

    #[test]
    fn chain_seed_keeps_chain_zero_and_mixes_the_rest() {
        assert_eq!(chain_seed(0xDEAD_BEEF, 0), 0xDEAD_BEEF);
        let mut seen = std::collections::HashSet::new();
        for c in 0..16 {
            assert!(seen.insert(chain_seed(7, c)), "chain {c} seed collides");
        }
    }

    #[test]
    fn single_chain_tempering_is_bit_identical_to_the_untempered_reference() {
        // Invariant 11: chains == 1 (explicit or default) must replay the
        // untempered search exactly — same schedule, eval, and RNG-driven
        // stats. priority_mapping_full ignores `chains`, so it is the
        // pre-tempering reference stream.
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..4u64 {
            let mut rng = Rng::new(seed ^ 0x7E3);
            let jobs: Vec<Job> = (0..15)
                .map(|_| Job {
                    req_idx: 0,
                    input_len: 1 + rng.below(1300),
                    output_len: 1 + rng.below(320),
                    slo: Slo::E2e { e2e_ms: rng.uniform(900.0, 18_000.0) },
                })
                .collect();
            let ev = Evaluator::new(&jobs, &pred);
            let base = SaParams {
                max_batch: 4,
                seed,
                t0: 100.0,
                iters_per_temp: 25,
                ..Default::default()
            };
            let explicit = SaParams { chains: 1, exchange_period: 2, ..base };
            let a = priority_mapping(&ev, &base);
            let b = priority_mapping(&ev, &explicit);
            let full = priority_mapping_full(&ev, &base);
            assert_eq!(a.schedule, b.schedule, "seed {seed}");
            assert_eq!(a.eval, b.eval, "seed {seed}");
            assert_eq!(a.schedule, full.schedule, "seed {seed}");
            assert_eq!(a.eval, full.eval, "seed {seed}");
            for (x, y) in [(&a.stats, &b.stats), (&a.stats, &full.stats)] {
                assert_eq!(x.evals, y.evals, "seed {seed}");
                assert_eq!(x.accepted, y.accepted, "seed {seed}");
                assert_eq!(x.improved, y.improved, "seed {seed}");
                assert_eq!(x.early_exit, y.early_exit, "seed {seed}");
                assert_eq!(x.exchanges, y.exchanges, "seed {seed}");
                assert_eq!(x.winner_chain, y.winner_chain, "seed {seed}");
            }
        }
    }

    #[test]
    fn tempered_search_is_deterministic_and_never_below_its_seeds() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x7E44);
        let jobs: Vec<Job> = (0..16)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1400),
                output_len: 1 + rng.below(350),
                slo: Slo::E2e { e2e_ms: rng.uniform(700.0, 12_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        for chains in [2usize, 4] {
            let p = SaParams {
                max_batch: 4,
                seed: 11,
                t0: 100.0,
                iters_per_temp: 25,
                chains,
                ..Default::default()
            };
            let a = priority_mapping(&ev, &p);
            let b = priority_mapping(&ev, &p);
            assert_eq!(a.schedule, b.schedule, "chains {chains}");
            assert_eq!(a.eval, b.eval, "chains {chains}");
            assert_eq!(a.stats.evals, b.stats.evals, "chains {chains}");
            assert_eq!(a.stats.exchanges, b.stats.exchanges, "chains {chains}");
            assert_eq!(
                a.stats.winner_chain, b.stats.winner_chain,
                "chains {chains}"
            );
            assert!(a.stats.winner_chain < chains);
            a.schedule.validate(4).unwrap();
            // never below the cold seeds the chains all start from
            let fcfs = ev.eval(&Schedule::fcfs(jobs.len(), 4));
            assert!(
                a.eval.g >= fcfs.g - 1e-15,
                "chains {chains}: {:?} below FCFS {:?}",
                a.eval,
                fcfs
            );
        }
    }

    #[test]
    fn tempered_hard_kv_mode_stays_feasible() {
        use crate::coordinator::kv::KvConfig;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x7E55);
        let jobs: Vec<Job> = (0..14)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(120),
                output_len: 1 + rng.below(60),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let kv = KvConfig::hard(20);
        let ev = Evaluator::new(&jobs, &pred);
        let p = SaParams { kv, chains: 3, ..params(6, 2) };
        let res = priority_mapping(&ev, &p);
        res.schedule.validate(6).unwrap();
        assert_eq!(ev.kv_excess(&res.schedule, &kv), 0, "{:?}", res.schedule);
    }

    #[test]
    fn windowed_search_is_valid_deterministic_and_off_means_off() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x31D0);
        let jobs: Vec<Job> = (0..14)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1200),
                output_len: 1 + rng.below(300),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let base = SaParams {
            max_batch: 4,
            seed: 5,
            t0: 100.0,
            iters_per_temp: 25,
            ..Default::default()
        };
        // explicit window = 0 is the default path, bit for bit
        let a = priority_mapping(&ev, &base);
        let b = priority_mapping(&ev, &SaParams { window: 0, ..base });
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.eval, b.eval);
        assert_eq!(a.stats.evals, b.stats.evals);
        assert_eq!(a.stats.accepted, b.stats.accepted);
        // finite windows: valid, deterministic, fast == full (both paths
        // share the windowed generator and the same RNG stream)
        for w in [1usize, 3] {
            let p = SaParams { window: w, ..base };
            let res = priority_mapping(&ev, &p);
            res.schedule.validate(4).unwrap();
            let rerun = priority_mapping(&ev, &p);
            assert_eq!(res.schedule, rerun.schedule, "window {w}");
            assert_eq!(res.eval, rerun.eval, "window {w}");
            let full = priority_mapping_full(&ev, &p);
            assert_eq!(res.schedule, full.schedule, "window {w}");
            assert_eq!(res.eval, full.eval, "window {w}");
            assert_eq!(res.stats.evals, full.stats.evals, "window {w}");
            assert_eq!(res.stats.accepted, full.stats.accepted, "window {w}");
        }
    }

    #[test]
    fn chunked_pricing_fast_equals_full_and_beats_fcfs() {
        // A chunk-priced evaluator drives the same search machinery: the
        // incremental path (chunk column in the PredTable) must stay
        // bit-identical to the full evaluation, and the result can never
        // fall below the FCFS baseline under the same pricing.
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xC41C);
        let jobs: Vec<Job> = (0..13)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1400),
                output_len: 1 + rng.below(300),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 20_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred).with_chunk_tokens(256);
        for seed in 0..3u64 {
            let p = SaParams {
                max_batch: 4,
                seed,
                t0: 100.0,
                iters_per_temp: 25,
                chunk_tokens: 256,
                ..Default::default()
            };
            let fast = priority_mapping(&ev, &p);
            let full = priority_mapping_full(&ev, &p);
            assert_eq!(fast.schedule, full.schedule, "seed {seed}");
            assert_eq!(fast.eval, full.eval, "seed {seed}");
            assert_eq!(fast.stats.evals, full.stats.evals, "seed {seed}");
            let fcfs = ev.eval(&Schedule::fcfs(jobs.len(), 4));
            assert!(
                fast.eval.g >= fcfs.g - 1e-15,
                "seed {seed}: {:?} below FCFS {:?}",
                fast.eval,
                fcfs
            );
        }
    }

    #[test]
    fn tempered_warm_start_keeps_the_frozen_prefix() {
        use crate::coordinator::pred_table::PredTable;
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0x7E66);
        let jobs: Vec<Job> = (0..12)
            .map(|_| Job {
                req_idx: 0,
                input_len: 1 + rng.below(1200),
                output_len: 1 + rng.below(300),
                slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 10_000.0) },
            })
            .collect();
        let ev = Evaluator::new(&jobs, &pred);
        let p = SaParams { chains: 4, ..params(3, 4) };
        let table = PredTable::build(&jobs, &pred, p.max_batch);
        let warm = Schedule::fcfs(12, 3);
        let f_warm = ev.eval(&warm);
        let frozen = 2usize;
        let frozen_pos: usize = warm.batches[..frozen].iter().sum();
        let res = priority_mapping_warm(&ev, &table, &p, Some(&warm), frozen);
        res.schedule.validate(3).unwrap();
        assert!(res.eval.g >= f_warm.g, "{:?} < {f_warm:?}", res.eval);
        assert_eq!(res.schedule.order[..frozen_pos], warm.order[..frozen_pos]);
        assert_eq!(res.schedule.batches[..frozen], warm.batches[..frozen]);
    }
}
