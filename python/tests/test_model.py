"""L2 correctness: TinyLM prefill/decode semantics.

The crucial invariant is *teacher-forcing consistency*: decoding token-by-
token from a prefilled cache must reproduce exactly the logits that a longer
prefill would produce.  This is what guarantees the Rust serving loop
(prefill bucket → decode steps) computes the same function as the model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(max_seq=128)
PARAMS = M.init_params(CFG, seed=7)
TOL = dict(rtol=1e-3, atol=1e-3)


def _tokens(seed, b, s):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, CFG.vocab)


def test_config_validates():
    with pytest.raises(AssertionError):
        M.ModelConfig(d_model=128, n_heads=3, head_dim=32)


def test_param_count_matches_shapes():
    shapes = M.param_shapes(CFG)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == CFG.param_count


def test_param_order_covers_all_shapes():
    order = M.param_order(CFG)
    assert sorted(order) == sorted(M.param_shapes(CFG).keys())
    assert len(set(order)) == len(order)


def test_flatten_roundtrip():
    flat = M.flatten_params(CFG, PARAMS)
    back = M.unflatten_params(CFG, flat)
    for name in M.param_order(CFG):
        np.testing.assert_array_equal(back[name], PARAMS[name])


def test_prefill_shapes():
    logits, kc, vc = M.prefill(CFG, PARAMS, _tokens(0, 2, 32), "ref")
    assert logits.shape == (2, 32, CFG.vocab)
    assert kc.shape == (CFG.n_layers, 2, CFG.max_seq, CFG.n_heads, CFG.head_dim)
    assert vc.shape == kc.shape


def test_prefill_rejects_overlong():
    with pytest.raises(ValueError):
        M.prefill(CFG, PARAMS, _tokens(0, 1, CFG.max_seq + 1), "ref")


def test_prefill_pallas_matches_ref():
    toks = _tokens(1, 2, 64)
    lp, kp, vp = M.prefill(CFG, PARAMS, toks, "pallas")
    lr, kr, vr = M.prefill(CFG, PARAMS, toks, "ref")
    np.testing.assert_allclose(lp, lr, **TOL)
    np.testing.assert_allclose(kp, kr, **TOL)
    np.testing.assert_allclose(vp, vr, **TOL)


def test_decode_pallas_matches_ref():
    toks = _tokens(2, 2, 32)
    _, kc, vc = M.prefill(CFG, PARAMS, toks, "ref")
    nxt = jnp.array([5, 77], jnp.int32)
    pos = jnp.array([32, 32], jnp.int32)
    lp, kp, vp = M.decode_step(CFG, PARAMS, kc, vc, nxt, pos, "pallas")
    lr, kr, vr = M.decode_step(CFG, PARAMS, kc, vc, nxt, pos, "ref")
    np.testing.assert_allclose(lp, lr, **TOL)
    np.testing.assert_allclose(kp, kr, **TOL)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([8, 16, 31]), steps=st.sampled_from([1, 3]),
       seed=st.integers(0, 2**16))
def test_teacher_forcing_consistency(s, steps, seed):
    """prefill(s) + `steps` decode steps == prefill(s + steps) logits."""
    b = 2
    full = _tokens(seed, b, s + steps)
    logits, kc, vc = M.prefill(CFG, PARAMS, full[:, :s], "ref")
    got = [logits[:, s - 1]]
    for t in range(steps):
        pos = jnp.full((b,), s + t, jnp.int32)
        lg, kc, vc = M.decode_step(CFG, PARAMS, kc, vc, full[:, s + t], pos,
                                   "ref")
        got.append(lg)
    ref_logits, _, _ = M.prefill(CFG, PARAMS, full, "ref")
    for t in range(steps):
        np.testing.assert_allclose(got[t + 1], ref_logits[:, s + t], **TOL)


def test_right_padding_invariance():
    """Garbage right-padding must not perturb logits at real positions —
    this is what lets the Rust engine pad prompts up to a bucket."""
    b, real, bucket = 2, 20, 32
    toks = _tokens(3, b, real)
    pad_a = jnp.concatenate(
        [toks, jnp.zeros((b, bucket - real), jnp.int32)], axis=1)
    pad_b = jnp.concatenate(
        [toks, jnp.full((b, bucket - real), 199, jnp.int32)], axis=1)
    la, _, _ = M.prefill(CFG, PARAMS, pad_a, "ref")
    lb, _, _ = M.prefill(CFG, PARAMS, pad_b, "ref")
    np.testing.assert_allclose(la[:, :real], lb[:, :real], **TOL)


def test_batch_row_independence():
    """Rows in a batch must not talk to each other (batching invariant the
    scheduler relies on when packing unrelated requests)."""
    t1 = _tokens(4, 1, 16)
    t2 = _tokens(5, 1, 16)
    both = jnp.concatenate([t1, t2], axis=0)
    l_both, _, _ = M.prefill(CFG, PARAMS, both, "ref")
    l1, _, _ = M.prefill(CFG, PARAMS, t1, "ref")
    np.testing.assert_allclose(l_both[:1], l1, **TOL)


def test_decode_per_row_positions():
    """Different rows may sit at different sequence positions."""
    b = 2
    toks = _tokens(6, b, 24)
    _, kc, vc = M.prefill(CFG, PARAMS, toks, "ref")
    # row 0 has length 10, row 1 has length 24
    pos = jnp.array([10, 24], jnp.int32)
    nxt = jnp.array([1, 2], jnp.int32)
    lg, _, _ = M.decode_step(CFG, PARAMS, kc, vc, nxt, pos, "ref")
    # row 0 must match a batch-1 decode from a length-10 prefill
    _, kc0, vc0 = M.prefill(CFG, PARAMS, toks[:1, :10], "ref")
    lg0, _, _ = M.decode_step(CFG, PARAMS, kc0, vc0, nxt[:1],
                              jnp.array([10], jnp.int32), "ref")
    np.testing.assert_allclose(lg[:1], lg0, **TOL)


def test_flat_wrappers_match_dict_api():
    toks = _tokens(7, 1, 16)
    flat = M.flatten_params(CFG, PARAMS)
    l1, k1, v1 = M.prefill_flat(CFG, "ref")(*flat, toks)
    l2, k2, v2 = M.prefill(CFG, PARAMS, toks, "ref")
    np.testing.assert_array_equal(l1, l2)
    nxt = jnp.array([9], jnp.int32)
    pos = jnp.array([16], jnp.int32)
    d1 = M.decode_flat(CFG, "ref")(*flat, k1, v1, nxt, pos)
    d2 = M.decode_step(CFG, PARAMS, k2, v2, nxt, pos, "ref")
    np.testing.assert_array_equal(d1[0], d2[0])
