//! Priority mapping: the paper's core contribution (§4.3).
//!
//! * [`annealing`]  — simulated-annealing search (Algorithm 1), the
//!   production path (~1 ms overhead). Optimizes `G = n / Σ t_e2e`
//!   (Eqs. 2–13) under the Eq. 20 KV-block feasibility model
//!   ([`crate::coordinator::kv`]).
//! * [`exhaustive`] — `O(N!·2^N)` strawman used as the optimality baseline.
//! * [`moves`]      — the neighbourhood operators shared by the search
//!   (Algorithm 1 line 20), each with a frozen-prefix-masked and a
//!   KV-vetoed variant.
//!
//! **Frozen-prefix masking contract** (online admission): a move invoked
//! with `frozen_batches = f` must not change the membership, order, or
//! boundaries of the first `f` batches, and with `f = 0` must draw the
//! exact RNG stream of the unmasked move. The KV veto composes the same
//! way: with no veto (or an unlimited pool) the `*_kv` variants are
//! bit-identical to the masked ones. See [`moves`] for the operator-level
//! statement and `tests/online_admission.rs` / `tests/kv_feasibility.rs`
//! for the enforcing tests.

pub mod annealing;
pub mod exhaustive;
pub mod moves;

pub use annealing::{
    priority_mapping, priority_mapping_full, priority_mapping_warm, SaParams,
    SaResult, SearchStats,
};
pub use exhaustive::{exhaustive_mapping, ExhaustiveResult, MAX_EXHAUSTIVE_N};
