//! Branch-and-bound optimality certificates for the scheduling objective.
//!
//! The exhaustive strawman ([`crate::coordinator::priority::exhaustive`])
//! enumerates `O(N!·2^N)` candidates and stops being feasible around
//! N = 11. This module searches the same solution space as a depth-first
//! branch-and-bound over *canonical* schedules — batches are built left to
//! right, members within a batch in a fixed heuristic rank order — pruned
//! by an **admissible upper bound** on the objective `G` (derived below).
//! That pushes exact closure to N ≈ 12–14, and for instances the node
//! budget cannot close it still returns a *certified* bound: the true
//! optimum is guaranteed to lie in `[eval.g, bound_g]`.
//!
//! ## Search space
//!
//! A node is a prefix of closed batches (their timeline contribution —
//! engine-free time, met count, Σ t_e2e — maintained incrementally with
//! exactly the arithmetic and accumulation order of
//! [`Evaluator::eval`]'s inner loop) plus one open batch and the set of
//! unplaced jobs. Children either extend the open batch with an unplaced
//! job of higher heuristic rank (canonical within-batch order) or close
//! it and start a new batch with any unplaced job. Because [`Eval`] is
//! symmetric in within-batch membership order up to floating-point
//! summation order — and *bit-identical* for batches of ≤ 2 members,
//! where `f64` addition is commutative — the canonical tree covers every
//! distinct objective value that full permutation enumeration covers at
//! `max_batch ≤ 2`, and matches it to one ulp of Σ t_e2e above that.
//!
//! ## The admissible bound
//!
//! For a node with closed-prefix attainment `met_p`, latency mass
//! `total_p`, and engine-free time `free`, every completion satisfies:
//!
//! * **numerator ≤** `met_p` + the count of open/unplaced jobs that could
//!   meet their SLO at their *minimum possible wait* (`max(free − arr, 0)`;
//!   waits only grow down any branch since batch starts are monotone) for
//!   *some* admissible batch size — open members range over
//!   `open_size..=max_batch`, unplaced jobs over `1..=max_batch`;
//! * **denominator ≥** `total_p` + Σ per-job `(min wait + min exec)` +,
//!   for closed waves (empty arrival column), a queueing term: sorting
//!   unplaced minimum execs ascending `e₀ ≤ e₁ ≤ …`, the job at rank `p`
//!   lands at the earliest in the `q(p)`-th future batch
//!   (`q = 0` for the first `max_batch − open_size` ranks, then
//!   `1 + (p − cap)/max_batch`) and must additionally wait for `q`
//!   disjoint earlier batches whose total duration is at least the open
//!   batch's smallest member exec plus the `q−1` smallest unplaced execs.
//!   With arrivals present the queueing term is dropped (a later start
//!   can be absorbed by an idle gap, so it is not a valid wait bound).
//!
//! `bound = num_ub / den_lb` then dominates the `G` of every leaf under
//! the node (`f64` division is monotone, so the real-arithmetic dominance
//! survives rounding), and a node is pruned only when `bound ≤ best.g`.
//! The incumbent is replaced on strictly greater `g` — the exhaustive
//! search's tie rule — so at full budget the returned optimum reproduces
//! the exhaustive golden's `Eval` **byte for byte** at `max_batch ≤ 2`
//! (invariant 13 in `docs/ARCHITECTURE.md`).
//!
//! ## KV feasibility
//!
//! Under a hard KV pool ([`KvConfig::vetoes_moves`]) the search rejects
//! infeasible batches at construction time (footprint sums for
//! `Reserve`, exact occupancy peaks at batch close for `Phased`), so the
//! optimum is exact for the *constrained* problem SA-with-hard-KV
//! solves. If any single job overflows the pool the filter is disabled
//! (the constrained problem is infeasible) and the result reverts to the
//! KV-relaxed bound, which still upper-bounds every KV mode. Soft and
//! unlimited modes always search the relaxed space.

use crate::coordinator::kv::{self, KvConfig, KvPhaseModel};
use crate::coordinator::objective::{Eval, Evaluator, Schedule, TimelineOrigin};
use crate::coordinator::request::Slo;

/// Branch-and-bound knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct BnbParams {
    /// Maximum batch size (same meaning as everywhere else).
    pub max_batch: usize,
    /// Node expansion budget. When exhausted the search returns its
    /// incumbent plus a certified `bound_g` folded over every abandoned
    /// subtree instead of the exact optimum.
    pub node_budget: usize,
    /// KV configuration. Hard mode constrains the search (see module
    /// docs); soft/unlimited modes search the KV-relaxed space.
    pub kv: KvConfig,
}

impl Default for BnbParams {
    fn default() -> Self {
        BnbParams {
            max_batch: 8,
            node_budget: 2_000_000,
            kv: KvConfig::UNLIMITED,
        }
    }
}

/// Outcome of a branch-and-bound run.
#[derive(Debug, Clone)]
pub struct BnbResult {
    /// Best schedule found (the optimum when `closed`).
    pub schedule: Schedule,
    /// Full evaluation of `schedule` via [`Evaluator::eval`] — the same
    /// code path the exhaustive golden and SA report through.
    pub eval: Eval,
    /// Certified upper bound on the optimal `G`. Equals `eval.g` when
    /// `closed`; otherwise `max(eval.g, bound of every abandoned node)`.
    pub bound_g: f64,
    /// Whether the search ran to completion within the node budget.
    pub closed: bool,
    /// Nodes expanded.
    pub nodes: usize,
    /// Nodes pruned by the admissible bound.
    pub pruned: usize,
    pub overhead_ms: f64,
}

impl BnbResult {
    /// Certified optimality gap of a competitor objective `g` against
    /// this run's bound: `max(0, (bound_g − g)/bound_g)`.
    pub fn gap_of(&self, g: f64) -> f64 {
        certified_gap(g, self.bound_g)
    }
}

/// Relative gap of objective `g` against a certified upper bound:
/// `max(0, (bound_g − g)/bound_g)`, 0 when the bound is degenerate.
pub fn certified_gap(g: f64, bound_g: f64) -> f64 {
    if !(bound_g > 0.0) || !g.is_finite() {
        return 0.0;
    }
    ((bound_g - g) / bound_g).max(0.0)
}

/// Per-job predictions at every admissible batch size, plus suffix
/// minima of exec over batch-size ranges (the bound's relaxation table).
struct PredGrid {
    /// `exec[j * (mb+1) + b]`, b in 1..=mb (index 0 unused).
    exec: Vec<f64>,
    prefill: Vec<f64>,
    tpot: Vec<f64>,
    /// `min_from[j * (mb+2) + s]` = min over b in s..=mb of exec(b, j);
    /// `s = mb+1` slot is +inf (loop sentinel).
    min_from: Vec<f64>,
    mb: usize,
}

impl PredGrid {
    fn build(ev: &Evaluator, mb: usize) -> PredGrid {
        let n = ev.jobs().len();
        let mut exec = vec![0.0; n * (mb + 1)];
        let mut prefill = vec![0.0; n * (mb + 1)];
        let mut tpot = vec![0.0; n * (mb + 1)];
        let mut min_from = vec![f64::INFINITY; n * (mb + 2)];
        for (j, job) in ev.jobs().iter().enumerate() {
            for b in 1..=mb {
                let p = ev.predictor().predict(b, job.input_len, job.output_len);
                exec[j * (mb + 1) + b] = p.exec_ms;
                prefill[j * (mb + 1) + b] = p.prefill_ms;
                tpot[j * (mb + 1) + b] = p.tpot_ms;
            }
            for s in (1..=mb).rev() {
                let next = min_from[j * (mb + 2) + s + 1];
                let e = exec[j * (mb + 1) + s];
                min_from[j * (mb + 2) + s] = if e < next { e } else { next };
            }
        }
        PredGrid { exec, prefill, tpot, min_from, mb }
    }

    #[inline]
    fn exec(&self, j: usize, b: usize) -> f64 {
        self.exec[j * (self.mb + 1) + b]
    }

    #[inline]
    fn min_exec_from(&self, j: usize, s: usize) -> f64 {
        self.min_from[j * (self.mb + 2) + s]
    }

    /// Whether job `j` could meet its SLO at wait `w` for some batch
    /// size in `s_min..=max_batch` (met is monotone in wait, so this is
    /// exact feasibility at the relaxed wait).
    fn can_meet(&self, slo: &Slo, j: usize, w: f64, s_min: usize) -> bool {
        for b in s_min..=self.mb {
            let idx = j * (self.mb + 1) + b;
            if slo.met(w + self.exec[idx], w + self.prefill[idx], self.tpot[idx])
            {
                return true;
            }
        }
        false
    }
}

struct Searcher<'a, 'b> {
    ev: &'a Evaluator<'b>,
    grid: PredGrid,
    mb: usize,
    node_budget: usize,
    /// Job indices in heuristic (EDF deadline, then index) order.
    heur: Vec<usize>,
    /// `rank[j]` = position of job j in `heur`.
    rank: Vec<usize>,
    /// Job indices sorted by `min_exec_from(j, 1)` ascending (queue term).
    by_min_exec: Vec<usize>,
    remaining: Vec<bool>,
    remaining_count: usize,
    /// Execution order under construction: closed members then open.
    order: Vec<usize>,
    batches: Vec<usize>,
    best: Option<(Schedule, Eval)>,
    nodes: usize,
    pruned: usize,
    exhausted: bool,
    open_bound: f64,
    // KV hard-mode filter (disabled when not binding or infeasible-alone).
    kv_filter: bool,
    kv: KvConfig,
    /// Per-job reserve footprint (`KvConfig::job_blocks`).
    job_blocks: Vec<u64>,
    /// Scratch for the queue term (min execs of remaining, ascending).
    scratch_execs: Vec<f64>,
    /// Scratch for phased-peak member lengths.
    scratch_members: Vec<(usize, usize)>,
}

impl<'a, 'b> Searcher<'a, 'b> {
    fn best_g(&self) -> f64 {
        self.best.as_ref().map(|(_, e)| e.g).unwrap_or(f64::NEG_INFINITY)
    }

    #[inline]
    fn arrival(&self, j: usize) -> f64 {
        let arr = self.ev.arrivals();
        if arr.is_empty() {
            0.0
        } else {
            arr[j]
        }
    }

    /// Latest arrival among the current open-batch members (mirrors
    /// `Evaluator::batch_arrival_max`: 0.0 for an empty arrival column).
    fn open_arrival_max(&self, open_size: usize) -> f64 {
        if self.ev.arrivals().is_empty() {
            return 0.0;
        }
        let open = &self.order[self.order.len() - open_size..];
        let mut arr = f64::NEG_INFINITY;
        for &j in open {
            let a = self.ev.arrivals()[j];
            if a > arr {
                arr = a;
            }
        }
        arr
    }

    /// Admissible upper bound on the `G` of every completion of this
    /// node (module docs).
    fn bound(&mut self, open_size: usize, free: f64, met: usize, total: f64) -> f64 {
        let mut num = met as f64;
        let mut den = total;
        // --- open-batch members at their relaxed start
        let mut e_open_min = f64::INFINITY;
        if open_size > 0 {
            let begin = TimelineOrigin::batch_start(free, self.open_arrival_max(open_size));
            let lo = self.order.len() - open_size;
            for i in lo..self.order.len() {
                let j = self.order[i];
                let w = begin - self.arrival(j);
                let me = self.grid.min_exec_from(j, open_size);
                den += w + me;
                if me < e_open_min {
                    e_open_min = me;
                }
                if self.grid.can_meet(&self.ev.jobs()[j].slo, j, w, open_size) {
                    num += 1.0;
                }
            }
        }
        // --- unplaced jobs at their relaxed wait
        self.scratch_execs.clear();
        for idx in 0..self.by_min_exec.len() {
            let j = self.by_min_exec[idx];
            if !self.remaining[j] {
                continue;
            }
            let w = (free - self.arrival(j)).max(0.0);
            let me = self.grid.min_exec_from(j, 1);
            den += w + me;
            self.scratch_execs.push(me);
            if self.grid.can_meet(&self.ev.jobs()[j].slo, j, w, 1) {
                num += 1.0;
            }
        }
        // --- closed-wave queueing term (see module docs for validity)
        if self.ev.arrivals().is_empty() && !self.scratch_execs.is_empty() {
            let cap0 = if open_size > 0 { self.mb - open_size } else { self.mb };
            let mut prefix = 0.0f64; // Σ of the first q-ish smallest execs
            let mut covered = 0usize; // ranks whose prefix is accumulated
            for p in 0..self.scratch_execs.len() {
                let q = if p < cap0 { 0 } else { 1 + (p - cap0) / self.mb };
                if q == 0 {
                    continue;
                }
                let need = if open_size > 0 { q - 1 } else { q };
                while covered < need {
                    prefix += self.scratch_execs[covered];
                    covered += 1;
                }
                den += prefix;
                if open_size > 0 {
                    den += e_open_min;
                }
            }
        }
        if den <= 0.0 {
            return if num > 0.0 { f64::INFINITY } else { 0.0 };
        }
        num / den
    }

    /// Close the open batch: returns `(free', met', total')` computed
    /// with exactly the arithmetic of `Evaluator::eval`'s inner loop, or
    /// `None` when the hard-KV filter rejects the batch.
    fn close_open(
        &mut self,
        open_size: usize,
        free: f64,
        met: usize,
        total: f64,
    ) -> Option<(f64, usize, f64)> {
        let lo = self.order.len() - open_size;
        if self.kv_filter {
            let demand = match self.kv.phase {
                KvPhaseModel::Reserve => {
                    self.order[lo..].iter().map(|&j| self.job_blocks[j]).sum()
                }
                KvPhaseModel::Phased => {
                    self.scratch_members.clear();
                    for &j in &self.order[lo..] {
                        let job = &self.ev.jobs()[j];
                        self.scratch_members.push((job.input_len, job.output_len));
                    }
                    kv::phased_peak_blocks(&self.scratch_members, self.kv.block_tokens)
                }
            };
            if self.kv.batch_excess(demand) > 0 {
                return None;
            }
        }
        let begin =
            TimelineOrigin::batch_start(free, self.open_arrival_max(open_size));
        let mut batch_max = 0.0f64;
        let mut batch_sum = 0.0f64;
        let mut batch_met = 0usize;
        for i in lo..self.order.len() {
            let j = self.order[i];
            let job = &self.ev.jobs()[j];
            let exec = self.grid.exec(j, open_size);
            let idx = j * (self.mb + 1) + open_size;
            let wait = begin - self.arrival(j);
            let e2e = wait + exec;
            let ttft = wait + self.grid.prefill[idx];
            batch_sum += e2e;
            if job.slo.met(e2e, ttft, self.grid.tpot[idx]) {
                batch_met += 1;
            }
            if exec > batch_max {
                batch_max = exec;
            }
        }
        Some((begin + batch_max, met + batch_met, total + batch_sum))
    }

    fn record_leaf(&mut self, open_size: usize, free: f64, met: usize, total: f64) {
        let Some((end, met_f, total_f)) = self.close_open(open_size, free, met, total)
        else {
            return;
        };
        let g = if total_f > 0.0 { met_f as f64 / total_f } else { 0.0 };
        if g > self.best_g() {
            let mut batches = self.batches.clone();
            batches.push(open_size);
            let schedule = Schedule { order: self.order.clone(), batches };
            let eval = Eval {
                g,
                met: met_f,
                total_e2e_ms: total_f,
                makespan_ms: end,
            };
            debug_assert_eq!(eval, self.ev.eval(&schedule));
            self.best = Some((schedule, eval));
        }
    }

    /// Expand one node: the open batch holds `open_size ≥ 1` members.
    fn dfs(&mut self, open_size: usize, free: f64, met: usize, total: f64) {
        self.nodes += 1;
        if self.nodes > self.node_budget {
            self.exhausted = true;
        }
        if self.exhausted {
            // Abandoned: fold this subtree's certificate into the bound.
            let b = self.bound(open_size, free, met, total);
            if b > self.open_bound {
                self.open_bound = b;
            }
            return;
        }
        if self.bound(open_size, free, met, total) <= self.best_g() {
            self.pruned += 1;
            return;
        }
        if self.remaining_count == 0 {
            self.record_leaf(open_size, free, met, total);
            return;
        }
        // (a) extend the open batch with a higher-rank unplaced job.
        if open_size < self.mb {
            let last_rank = self.rank[self.order[self.order.len() - 1]];
            for hi in (last_rank + 1)..self.heur.len() {
                let j = self.heur[hi];
                if !self.remaining[j] {
                    continue;
                }
                if self.kv_filter
                    && self.kv.phase == KvPhaseModel::Reserve
                    && self.reserve_demand(open_size) + self.job_blocks[j]
                        > self.kv.pool_blocks
                {
                    continue;
                }
                self.place(j);
                self.dfs(open_size + 1, free, met, total);
                self.unplace(j);
            }
        }
        // (b) close the open batch, start a new one with any unplaced job.
        if let Some((free2, met2, total2)) =
            self.close_open(open_size, free, met, total)
        {
            self.batches.push(open_size);
            for hi in 0..self.heur.len() {
                let j = self.heur[hi];
                if !self.remaining[j] {
                    continue;
                }
                self.place(j);
                self.dfs(1, free2, met2, total2);
                self.unplace(j);
            }
            self.batches.pop();
        }
    }

    fn reserve_demand(&self, open_size: usize) -> u64 {
        let lo = self.order.len() - open_size;
        self.order[lo..].iter().map(|&j| self.job_blocks[j]).sum()
    }

    #[inline]
    fn place(&mut self, j: usize) {
        self.order.push(j);
        self.remaining[j] = false;
        self.remaining_count -= 1;
    }

    #[inline]
    fn unplace(&mut self, j: usize) {
        self.order.pop();
        self.remaining[j] = true;
        self.remaining_count += 1;
    }
}

/// Depth-first branch-and-bound over canonical schedules (module docs).
///
/// Always returns a result: the exact optimum (with `closed == true` and
/// `bound_g == eval.g`) when the node budget suffices, otherwise the
/// incumbent plus a certified upper bound on the optimum.
pub fn branch_and_bound(ev: &Evaluator, params: &BnbParams) -> BnbResult {
    let t_start = crate::util::now_ms();
    let n = ev.jobs().len();
    let mb = params.max_batch.max(1);
    if n == 0 {
        return BnbResult {
            schedule: Schedule { order: vec![], batches: vec![] },
            eval: Eval::ZERO,
            bound_g: 0.0,
            closed: true,
            nodes: 0,
            pruned: 0,
            overhead_ms: crate::util::now_ms() - t_start,
        };
    }

    // EDF-deadline heuristic order (child generation + canonical ranks).
    let deadline = |j: usize| match ev.jobs()[j].slo {
        Slo::E2e { e2e_ms } => e2e_ms,
        Slo::Interactive { ttft_ms, .. } => ttft_ms,
    };
    let mut heur: Vec<usize> = (0..n).collect();
    heur.sort_by(|&a, &b| deadline(a).total_cmp(&deadline(b)));
    let mut rank = vec![0usize; n];
    for (r, &j) in heur.iter().enumerate() {
        rank[j] = r;
    }

    let grid = PredGrid::build(ev, mb);
    let mut by_min_exec: Vec<usize> = (0..n).collect();
    by_min_exec.sort_by(|&a, &b| {
        grid.min_exec_from(a, 1).total_cmp(&grid.min_exec_from(b, 1))
    });

    let job_blocks: Vec<u64> = ev
        .jobs()
        .iter()
        .map(|j| params.kv.job_blocks(j.input_len, j.output_len))
        .collect();
    // Hard KV constrains the search — unless some job cannot fit alone,
    // in which case the constrained problem is infeasible and the run
    // reverts to the KV-relaxed space (module docs).
    let kv_filter = params.kv.vetoes_moves()
        && job_blocks.iter().all(|&b| params.kv.fits_alone(b));

    let mut s = Searcher {
        ev,
        grid,
        mb,
        node_budget: params.node_budget,
        heur,
        rank,
        by_min_exec,
        remaining: vec![true; n],
        remaining_count: n,
        order: Vec::with_capacity(n),
        batches: Vec::new(),
        best: None,
        nodes: 0,
        pruned: 0,
        exhausted: false,
        open_bound: f64::NEG_INFINITY,
        kv_filter,
        kv: params.kv,
        job_blocks,
        scratch_execs: Vec::with_capacity(n),
        scratch_members: Vec::with_capacity(mb),
    };

    // Root: start the first batch with each job in heuristic order.
    for hi in 0..n {
        let j = s.heur[hi];
        s.place(j);
        s.dfs(1, ev.t0_ms(), 0, 0.0);
        s.unplace(j);
    }

    let closed = !s.exhausted;
    let (schedule, eval) = match s.best.take() {
        Some(be) => be,
        // Budget too small to even reach one leaf (or every leaf was
        // KV-rejected before the first feasible one): report the FCFS
        // packing so callers always get a valid schedule.
        None => {
            let fallback = Schedule::fcfs(n, mb);
            let e = ev.eval(&fallback);
            (fallback, e)
        }
    };
    let bound_g = if closed {
        eval.g
    } else {
        let ob = s.open_bound;
        if ob > eval.g {
            ob
        } else {
            eval.g
        }
    };
    BnbResult {
        schedule,
        eval,
        bound_g,
        closed,
        nodes: s.nodes,
        pruned: s.pruned,
        overhead_ms: crate::util::now_ms() - t_start,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::objective::Job;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::priority::exhaustive::exhaustive_mapping;
    use crate::util::rng::Rng;

    fn random_jobs(rng: &mut Rng, n: usize) -> Vec<Job> {
        (0..n)
            .map(|i| Job {
                req_idx: i,
                input_len: 1 + rng.below(1500),
                output_len: 1 + rng.below(400),
                slo: if rng.chance(0.5) {
                    Slo::E2e { e2e_ms: rng.uniform(1_000.0, 60_000.0) }
                } else {
                    Slo::Interactive {
                        ttft_ms: rng.uniform(500.0, 15_000.0),
                        tpot_ms: rng.uniform(15.0, 60.0),
                    }
                },
            })
            .collect()
    }

    #[test]
    fn matches_exhaustive_byte_for_byte_at_small_n() {
        // Invariant 13: at full budget and max_batch ≤ 2 (where Eval is
        // bit-invariant to within-batch order) the B&B optimum
        // reproduces the exhaustive golden's Eval byte for byte.
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..8u64 {
            let mut rng = Rng::new(seed ^ 0xB0B);
            let n = 4 + (seed as usize % 4); // 4..=7
            let jobs = random_jobs(&mut rng, n);
            let ev = Evaluator::new(&jobs, &pred);
            let ex = exhaustive_mapping(&ev, 2).unwrap();
            let bnb = branch_and_bound(
                &ev,
                &BnbParams { max_batch: 2, ..Default::default() },
            );
            assert!(bnb.closed, "seed {seed}: budget must close n={n}");
            assert_eq!(
                bnb.eval.g.to_bits(),
                ex.eval.g.to_bits(),
                "seed {seed}: g mismatch {} vs {}",
                bnb.eval.g,
                ex.eval.g
            );
            assert_eq!(bnb.eval.met, ex.eval.met, "seed {seed}");
            assert_eq!(
                bnb.eval.total_e2e_ms.to_bits(),
                ex.eval.total_e2e_ms.to_bits(),
                "seed {seed}"
            );
            assert_eq!(
                bnb.eval.makespan_ms.to_bits(),
                ex.eval.makespan_ms.to_bits(),
                "seed {seed}"
            );
            assert_eq!(bnb.bound_g.to_bits(), bnb.eval.g.to_bits());
            // and it does so with far fewer evaluations than O(N!·2^N)
            assert!(bnb.nodes < ex.evals, "seed {seed}: no pruning win");
        }
    }

    #[test]
    fn closes_n12_within_budget() {
        let pred = LatencyPredictor::paper_table2();
        for seed in [1u64, 7] {
            let mut rng = Rng::new(seed ^ 0x6A9);
            let jobs = random_jobs(&mut rng, 12);
            let ev = Evaluator::new(&jobs, &pred);
            let bnb = branch_and_bound(
                &ev,
                &BnbParams { max_batch: 3, ..Default::default() },
            );
            assert!(
                bnb.closed,
                "seed {seed}: n=12 did not close in {} nodes",
                bnb.nodes
            );
            assert_eq!(bnb.bound_g.to_bits(), bnb.eval.g.to_bits());
            bnb.schedule.validate(3).unwrap();
            // sanity: the optimum dominates the FCFS packing
            let fcfs = ev.eval(&Schedule::fcfs(12, 3));
            assert!(bnb.eval.g >= fcfs.g - 1e-12);
        }
    }

    #[test]
    fn root_bound_dominates_exhaustive_optimum() {
        // With a zero node budget the search abandons every root child
        // immediately; the folded bound must still dominate the true
        // optimum (admissibility).
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..10u64 {
            let mut rng = Rng::new(seed ^ 0xADA);
            let jobs = random_jobs(&mut rng, 6);
            let ev = Evaluator::new(&jobs, &pred);
            for mb in [1usize, 2, 3] {
                let ex = exhaustive_mapping(&ev, mb).unwrap();
                let bnb = branch_and_bound(
                    &ev,
                    &BnbParams {
                        max_batch: mb,
                        node_budget: 0,
                        ..Default::default()
                    },
                );
                assert!(!bnb.closed);
                assert!(
                    bnb.bound_g >= ex.eval.g,
                    "seed {seed} mb {mb}: bound {} < optimum {}",
                    bnb.bound_g,
                    ex.eval.g
                );
            }
        }
    }

    #[test]
    fn exhausted_budget_reports_valid_bracket() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xFACE);
        let jobs = random_jobs(&mut rng, 7);
        let ev = Evaluator::new(&jobs, &pred);
        let ex = exhaustive_mapping(&ev, 2).unwrap();
        let bnb = branch_and_bound(
            &ev,
            &BnbParams { max_batch: 2, node_budget: 40, ..Default::default() },
        );
        assert!(!bnb.closed);
        // the incumbent and bound bracket the true optimum
        assert!(bnb.eval.g <= ex.eval.g + 1e-15);
        assert!(bnb.bound_g >= ex.eval.g);
        assert!(bnb.bound_g >= bnb.eval.g);
        bnb.schedule.validate(2).unwrap();
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let pred = LatencyPredictor::paper_table2();
        let none: Vec<Job> = vec![];
        let ev = Evaluator::new(&none, &pred);
        let r = branch_and_bound(&ev, &BnbParams::default());
        assert!(r.closed);
        assert_eq!(r.eval, Eval::ZERO);
        assert_eq!(r.nodes, 0);

        let one = vec![Job {
            req_idx: 0,
            input_len: 100,
            output_len: 10,
            slo: Slo::E2e { e2e_ms: 1e9 },
        }];
        let ev = Evaluator::new(&one, &pred);
        let r = branch_and_bound(&ev, &BnbParams::default());
        assert!(r.closed);
        assert_eq!(r.schedule.order, vec![0]);
        assert_eq!(r.schedule.batches, vec![1]);
        assert_eq!(r.eval.met, 1);
    }

    #[test]
    fn hard_kv_search_is_feasible_and_relaxed_bound_dominates() {
        let pred = LatencyPredictor::paper_table2();
        let mut rng = Rng::new(0xCAFE);
        let jobs = random_jobs(&mut rng, 8);
        let ev = Evaluator::new(&jobs, &pred);
        let relaxed = branch_and_bound(
            &ev,
            &BnbParams { max_batch: 3, ..Default::default() },
        );
        // size the pool so singles fit but a full batch is tight
        let max_single = jobs
            .iter()
            .map(|j| KvConfig::hard(1).job_blocks(j.input_len, j.output_len))
            .max()
            .unwrap();
        let hard = KvConfig::hard(max_single + max_single / 2);
        let constrained = branch_and_bound(
            &ev,
            &BnbParams { max_batch: 3, kv: hard, ..Default::default() },
        );
        assert!(constrained.closed);
        assert_eq!(
            ev.kv_excess(&constrained.schedule, &hard),
            0,
            "hard-KV optimum must be feasible"
        );
        // the KV-relaxed optimum dominates the constrained one
        assert!(relaxed.eval.g >= constrained.eval.g - 1e-15);
    }

    #[test]
    fn arrivals_still_certify() {
        // With arrivals the queueing term is dropped; the bound must
        // still dominate the optimum found by exhaustive enumeration.
        let pred = LatencyPredictor::paper_table2();
        for seed in 0..5u64 {
            let mut rng = Rng::new(seed ^ 0x777);
            let jobs = random_jobs(&mut rng, 5);
            let arrivals: Vec<f64> =
                (0..5).map(|_| rng.uniform(0.0, 3_000.0)).collect();
            let ev = Evaluator::with_arrivals(&jobs, &pred, 50.0, &arrivals);
            let ex = exhaustive_mapping(&ev, 2).unwrap();
            let bnb = branch_and_bound(
                &ev,
                &BnbParams { max_batch: 2, ..Default::default() },
            );
            assert!(bnb.closed);
            assert_eq!(
                bnb.eval.g.to_bits(),
                ex.eval.g.to_bits(),
                "seed {seed}: arrival-aware optimum mismatch"
            );
            let starved = branch_and_bound(
                &ev,
                &BnbParams {
                    max_batch: 2,
                    node_budget: 0,
                    ..Default::default()
                },
            );
            assert!(starved.bound_g >= ex.eval.g, "seed {seed}");
        }
    }

    #[test]
    fn certified_gap_basics() {
        assert_eq!(certified_gap(1.0, 1.0), 0.0);
        assert!((certified_gap(0.95, 1.0) - 0.05).abs() < 1e-12);
        // better-than-bound (only possible via fp slack) clamps to zero
        assert_eq!(certified_gap(1.1, 1.0), 0.0);
        assert_eq!(certified_gap(0.5, 0.0), 0.0);
        assert_eq!(certified_gap(f64::NAN, 1.0), 0.0);
    }
}
