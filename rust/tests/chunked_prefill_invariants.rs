//! Chunked-prefill invariant grid (ISSUE 10 acceptance, invariant 15):
//!
//! * **chunking-off byte-identity** — `chunk_tokens = 0` is the default
//!   and replays the whole-prompt stack bit for bit: engine completions
//!   (plain, divergent, divergent+preempt, phased), `run_online_opts`
//!   outcomes, and `schedule` plans are identical between a
//!   default-constructed stack and one with the knob set to 0 explicitly.
//!   A window wide enough to cover every batch is likewise identical to
//!   the unwindowed search (both run the same windowed generator on the
//!   same RNG stream, so `window ≥ m` degenerates exactly).
//! * **knob liveness** — chunking on actually changes execution: in a
//!   mixed-length batch the short member's first token lands at its own
//!   final chunk, strictly before the long member's, where whole-prompt
//!   prefill emits every first token together.
//! * **no-KV-leak / exactly-once grid** — under chunking ×
//!   {Reserve, Phased} × divergence σ = 0.5 × {off, recompute, swap}
//!   every request completes exactly once, the pool drains to empty, and
//!   preemption resumes pair 1:1 with suspensions; runs are
//!   bit-reproducible.
//! * **TTFT attainment** — on a long-prompt + interactive mix the
//!   chunked sliding-window stack strictly improves interactive-class
//!   attainment over whole-prompt prefill with e2e-class attainment no
//!   worse (the tentpole's reason to exist).

use slo_serve::config::profiles::HardwareProfile;
use slo_serve::coordinator::kv::KvPhaseModel;
use slo_serve::coordinator::online::{
    run_online_opts, OnlineOpts, OnlineOutcome, ReplanStrategy,
};
use slo_serve::coordinator::predictor::{LatencyPredictor, PhaseCoeffs};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::profiler::MemoryModel;
use slo_serve::coordinator::request::{Request, Slo, TaskType};
use slo_serve::coordinator::scheduler::{schedule, InstanceInfo};
use slo_serve::engine::sim::{
    DivergenceModel, PreemptConfig, SimEngine,
};
use slo_serve::engine::{Engine, EngineRequest, ItemResult};
use slo_serve::util::rng::Rng;

fn req(id: u64, input: usize, out: usize) -> EngineRequest {
    EngineRequest { id, input_len: input, max_new_tokens: out, prompt: None }
}

/// Paper-model profile with timing noise: the noise stream is what makes
/// byte-identity assertions sharp (any extra or missing draw shifts every
/// later sample).
fn noisy_profile(kv_pool_mb: f64) -> HardwareProfile {
    HardwareProfile {
        name: "chunk-grid".into(),
        truth: LatencyPredictor::paper_table2(),
        kv_pool_mb,
        mem: MemoryModel { utility: 1.0, mb_per_token: 0.5 },
        noise_std: 0.1,
        max_total_tokens: 4096,
    }
}

fn assert_items_equal(a: &[ItemResult], b: &[ItemResult], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.start_ms.to_bits(), y.start_ms.to_bits(), "{tag} id {}", x.id);
        assert_eq!(
            x.first_token_ms.to_bits(),
            y.first_token_ms.to_bits(),
            "{tag} id {}",
            x.id
        );
        assert_eq!(
            x.finish_ms.to_bits(),
            y.finish_ms.to_bits(),
            "{tag} id {}",
            x.id
        );
        assert_eq!(x.generated, y.generated, "{tag} id {}", x.id);
        assert_eq!(x.batch_size, y.batch_size, "{tag} id {}", x.id);
    }
}

/// Invariant 15, engine half: a default-constructed engine and one with
/// `chunk_tokens` set to 0 explicitly draw the same noise stream and
/// produce bit-identical completions across the plain, divergent,
/// divergent+preempt, and phased configurations — two successive batches
/// each, so stream continuation is covered too.
#[test]
fn chunking_off_is_default_and_replays_legacy_engine() {
    let mk_batches = || {
        vec![
            vec![req(0, 300, 40), req(1, 80, 12), req(2, 550, 25)],
            vec![req(3, 120, 60), req(4, 400, 8)],
        ]
    };
    type Cfg = (
        &'static str,
        f64,
        DivergenceModel,
        PreemptConfig,
        KvPhaseModel,
    );
    let configs: Vec<Cfg> = vec![
        (
            "plain",
            2_000.0,
            DivergenceModel::Off,
            PreemptConfig::OFF,
            KvPhaseModel::Reserve,
        ),
        (
            "divergent",
            2_000.0,
            DivergenceModel::Lognormal { sigma: 0.5 },
            PreemptConfig::OFF,
            KvPhaseModel::Reserve,
        ),
        (
            "divergent+preempt",
            2_000.0,
            DivergenceModel::Lognormal { sigma: 0.5 },
            PreemptConfig::recompute(),
            KvPhaseModel::Reserve,
        ),
        (
            "phased",
            2_000.0,
            DivergenceModel::Lognormal { sigma: 0.5 },
            PreemptConfig::OFF,
            KvPhaseModel::Phased,
        ),
    ];
    for (tag, pool, div, pre, phase) in configs {
        let profile = noisy_profile(pool);
        let mut default_engine = SimEngine::new(profile.clone(), 8, 11)
            .with_divergence(div)
            .with_preemption(pre)
            .with_kv_phase(phase);
        let mut explicit_off = SimEngine::new(profile, 8, 11)
            .with_divergence(div)
            .with_preemption(pre)
            .with_kv_phase(phase)
            .with_chunk_tokens(0);
        assert_eq!(default_engine.chunk_tokens(), 0, "{tag}: default is off");
        for batch in mk_batches() {
            let a = default_engine.run_batch(&batch).unwrap();
            let b = explicit_off.run_batch(&batch).unwrap();
            assert_items_equal(&a, &b, tag);
        }
    }
}

/// Knob liveness: with chunking on, a mixed-length batch's short member
/// gets its first token at its own final chunk — strictly before the
/// long member's — where whole-prompt prefill emits both together.
#[test]
fn chunking_on_changes_first_token_times() {
    // γ-only prefill, free decode, one token each: first token == finish,
    // so the whole batch timing is the prefill timing and noise is off.
    let profile = HardwareProfile {
        name: "gamma-liveness".into(),
        truth: LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs::ZERO,
        ),
        kv_pool_mb: 2_000.0,
        mem: MemoryModel { utility: 1.0, mb_per_token: 0.5 },
        noise_std: 0.0,
        max_total_tokens: 4096,
    };
    let batch = vec![req(0, 32, 1), req(1, 200, 1)];
    let mut off = SimEngine::new(profile.clone(), 2, 0);
    let mut on = SimEngine::new(profile, 2, 0).with_chunk_tokens(16);
    let r_off = off.run_batch(&batch).unwrap();
    let r_on = on.run_batch(&batch).unwrap();
    // whole-prompt: both first tokens at the batch prefill (γ · max_in)
    assert_eq!(
        r_off[0].first_token_ms.to_bits(),
        r_off[1].first_token_ms.to_bits(),
        "whole-prompt prefill must emit first tokens together"
    );
    // chunked: member 0 finishes its 2 chunks (32 tokens) before member
    // 1's 13 chunks complete
    assert!(
        r_on[0].first_token_ms < r_on[1].first_token_ms,
        "chunked prefill must emit the short member's first token early \
         ({} vs {})",
        r_on[0].first_token_ms,
        r_on[1].first_token_ms
    );
    assert!(
        r_on[0].first_token_ms < r_off[0].first_token_ms,
        "chunking must strictly improve the short member's TTFT"
    );
}

fn online_trace(seed: u64, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(seed ^ 0xC0FF);
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += rng.uniform(0.0, 40.0);
            let slo = if i % 3 == 0 {
                Slo::Interactive {
                    ttft_ms: rng.uniform(500.0, 5_000.0),
                    tpot_ms: rng.uniform(20.0, 80.0),
                }
            } else {
                Slo::E2e { e2e_ms: rng.uniform(2_000.0, 30_000.0) }
            };
            let mut r = Request::synthetic(
                i as u64,
                if i % 2 == 0 { TaskType::Chat } else { TaskType::Code },
                1 + rng.below(400),
                1 + rng.below(30),
                slo,
            );
            r.arrival_ms = t;
            r
        })
        .collect()
}

fn run_stack(trace: &[Request], sa: &SaParams) -> OnlineOutcome {
    let profile = noisy_profile(2_000.0);
    let outs: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let mut engine = SimEngine::new(profile.clone(), sa.max_batch, 0)
        .with_chunk_tokens(sa.chunk_tokens);
    run_online_opts(
        trace,
        &outs,
        &mut engine,
        &profile.truth,
        sa,
        ReplanStrategy::Warm,
        OnlineOpts { arrival_aware: true, ..Default::default() },
    )
    .unwrap()
}

fn assert_outcomes_equal(a: &OnlineOutcome, b: &OnlineOutcome, tag: &str) {
    assert_eq!(a.completions.len(), b.completions.len(), "{tag}");
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits(), "{tag} id {}", x.id);
        assert_eq!(
            x.ttft_ms.to_bits(),
            y.ttft_ms.to_bits(),
            "{tag} id {}",
            x.id
        );
        assert_eq!(
            x.wait_ms.to_bits(),
            y.wait_ms.to_bits(),
            "{tag} id {}",
            x.id
        );
        assert_eq!(x.batch_size, y.batch_size, "{tag} id {}", x.id);
    }
    assert_eq!(a.predicted.len(), b.predicted.len(), "{tag}");
    for (x, y) in a.predicted.iter().zip(&b.predicted) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits(), "{tag} id {}", x.id);
        assert_eq!(
            x.ttft_ms.to_bits(),
            y.ttft_ms.to_bits(),
            "{tag} id {}",
            x.id
        );
    }
    assert_eq!(a.final_eval.g.to_bits(), b.final_eval.g.to_bits(), "{tag}");
}

/// Invariant 15, stack half: `run_online_opts` with the default params,
/// with `chunk_tokens`/`window` set to 0 explicitly, and with a window
/// wider than any wave all produce bit-identical completions, predicted
/// timelines, and objective — the windowed move generator degenerates
/// exactly when the window covers every batch, on the same RNG stream.
#[test]
fn default_stack_replays_explicit_off_and_saturated_window() {
    for seed in 0..3u64 {
        let trace = online_trace(seed, 16);
        let base = SaParams {
            max_batch: 4,
            seed,
            t0: 100.0,
            iters_per_temp: 15,
            ..Default::default()
        };
        let a = run_stack(&trace, &base);
        let b = run_stack(
            &trace,
            &SaParams { chunk_tokens: 0, window: 0, ..base },
        );
        let c = run_stack(&trace, &SaParams { window: 1_000, ..base });
        assert_eq!(a.completions.len(), trace.len(), "seed {seed}");
        assert_outcomes_equal(&a, &b, &format!("seed {seed} explicit-off"));
        assert_outcomes_equal(&a, &c, &format!("seed {seed} wide-window"));
    }
}

/// The multi-instance `schedule` outcome is equally unchanged by an
/// explicit zero chunk size or a saturated window.
#[test]
fn schedule_outcome_unchanged_by_off_knobs() {
    let pred = LatencyPredictor::paper_table2();
    let reqs: Vec<Request> = (0..12)
        .map(|i| {
            Request::synthetic(
                i as u64,
                TaskType::Code,
                120 + 50 * i as usize,
                8 + 6 * i as usize,
                Slo::E2e { e2e_ms: 25_000.0 },
            )
        })
        .collect();
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    let instances: Vec<InstanceInfo> =
        (0..2).map(|id| InstanceInfo { id, mem_mb: 16_000.0 }).collect();
    let mem = MemoryModel::default();
    let base = SaParams::with_max_batch(4);
    let legacy = schedule(&reqs, &outs, &instances, &pred, &mem, &base).unwrap();
    for (tag, sa) in [
        ("explicit-off", SaParams { chunk_tokens: 0, window: 0, ..base }),
        ("wide-window", SaParams { window: 1_000, ..base }),
    ] {
        let got = schedule(&reqs, &outs, &instances, &pred, &mem, &sa).unwrap();
        assert_eq!(legacy.plans.len(), got.plans.len(), "{tag}");
        for (x, y) in legacy.plans.iter().zip(&got.plans) {
            assert_eq!(x.instance, y.instance, "{tag}");
            assert_eq!(x.schedule, y.schedule, "{tag} instance {}", x.instance);
            assert_eq!(x.request_order(), y.request_order(), "{tag}");
        }
    }
}

/// Pick `n` request ids whose quantile-trace actuals all overrun the
/// nominal into the next KV block, so the whole batch crosses a block
/// boundary in lockstep and pool exhaustion is guaranteed on tight
/// pools (`nominal = 24`, overrun ≥ 28 crosses the 64-token boundary of
/// a 40-token prompt with everyone still active).
fn overrun_ids(model: &DivergenceModel, n: usize) -> Vec<u64> {
    let mut probe = Rng::new(0);
    let mut ids = Vec::new();
    for id in 0..400u64 {
        let actual = model.actual_lo(id, 24, &mut probe);
        if (28..=120).contains(&actual) {
            ids.push(id);
            if ids.len() == n {
                break;
            }
        }
    }
    assert_eq!(ids.len(), n, "probe range exhausted");
    ids
}

/// The no-KV-leak / exactly-once grid: chunked prefill × {Reserve,
/// Phased} × divergence σ = 0.5 × {no-preempt (ample pool), recompute,
/// swap (tight pool)}. Every id completes exactly once with ≥ 1 token,
/// the pool drains to empty, resumes pair 1:1 with suspensions (and the
/// tight cells really do preempt), and a rerun is bit-identical.
#[test]
fn chunked_no_leak_exactly_once_grid() {
    let model = DivergenceModel::QuantileTrace { sigma: 0.5 };
    let ids = overrun_ids(&model, 12);
    let batches: Vec<Vec<EngineRequest>> = ids
        .chunks(6)
        .map(|c| c.iter().map(|&id| req(id, 40, 24)).collect())
        .collect();
    // 24 blocks: 6 members × blocks_for(40 + 24 tokens) — the Reserve
    // pre-check passes exactly, and the lockstep boundary crossing at
    // token 65 finds the pool full.
    const TIGHT_MB: f64 = 192.0;
    let cells: Vec<(&'static str, f64, PreemptConfig)> = vec![
        ("no-preempt", 2_000.0, PreemptConfig::OFF),
        ("recompute", TIGHT_MB, PreemptConfig::recompute()),
        ("swap", TIGHT_MB, PreemptConfig::swap(8.0, 64)),
    ];
    for phase in [KvPhaseModel::Reserve, KvPhaseModel::Phased] {
        for (tag, pool, pre) in &cells {
            let tag = format!("{phase:?}/{tag}");
            let run = || {
                let mut e =
                    SimEngine::new(noisy_profile(*pool), 8, 0xA5)
                        .with_divergence(model)
                        .with_preemption(*pre)
                        .with_kv_phase(phase)
                        .with_chunk_tokens(16);
                let mut results = Vec::new();
                for b in &batches {
                    results.extend(e.run_batch(b).unwrap());
                }
                let ps = e.preemption_stats();
                assert_eq!(e.kv().active_seqs(), 0, "{tag}: live seqs left");
                assert_eq!(
                    e.kv().free_blocks(),
                    e.kv().config().total_blocks,
                    "{tag}: pool did not drain"
                );
                assert!(
                    (e.peak_used_blocks() as u64)
                        <= e.kv().config().total_blocks,
                    "{tag}: peak exceeded pool"
                );
                (results, ps)
            };
            let (results, ps) = run();
            assert_eq!(results.len(), ids.len(), "{tag}: completion count");
            let mut seen = ids.clone();
            seen.sort_unstable();
            let mut got: Vec<u64> = results.iter().map(|r| r.id).collect();
            got.sort_unstable();
            assert_eq!(got, seen, "{tag}: each id completes exactly once");
            assert!(
                results.iter().all(|r| r.generated >= 1),
                "{tag}: empty completion"
            );
            if pre.enabled() {
                assert_eq!(
                    ps.kv_truncations, 0,
                    "{tag}: preemption must replace truncation"
                );
                assert!(
                    ps.preemptions >= 1,
                    "{tag}: tight pool never exhausted — dead cell"
                );
                assert_eq!(
                    ps.recompute_resumes + ps.swap_ins,
                    ps.preemptions,
                    "{tag}: resumes must pair with suspensions"
                );
            } else {
                assert_eq!(ps.preemptions, 0, "{tag}");
            }
            // bit-reproducible under chunking
            let (rerun, ps2) = run();
            assert_items_equal(&results, &rerun, &tag);
            assert_eq!(ps.preemptions, ps2.preemptions, "{tag}");
            assert_eq!(ps.swap_outs, ps2.swap_outs, "{tag}");
        }
    }
}

/// The tentpole's payoff, pinned: a long-prompt + interactive mix where
/// whole-prompt prefill cannot meet the interactive TTFT (the G-optimal
/// plan co-batches both jobs, so the short prompt's first token waits on
/// the long prompt's prefill) but the chunked sliding-window stack meets
/// it (the short member's final chunk completes first) with e2e-class
/// attainment no worse.
///
/// Geometry, exact under the γ-prefill/δ-decode model (noise 0, oracle
/// outputs): I = (100 in, 100 out, TTFT ≤ 450); L = (1000 in, 100 out,
/// e2e ≤ 2500); both arrive at t = 0, max_batch 2.
/// Whole-prompt: co-batched first tokens land at γ·1000 = 1000 → I
/// misses TTFT; separated, L's e2e is 3080 → misses; the G-optimum is
/// the co-batch (met 1, Σe2e 3080 predicted) → interactive attainment 0.
/// Chunked [I, L]: I's first token at 100, both finish at 2090 → both
/// met (G = 2/4180 beats every alternative) → interactive attainment 1,
/// e2e attainment unchanged.
#[test]
fn chunked_window_improves_interactive_attainment() {
    let profile = HardwareProfile {
        name: "ttft-mix".into(),
        truth: LatencyPredictor::new(
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 1.0, delta: 0.0 },
            PhaseCoeffs { alpha: 0.0, beta: 0.0, gamma: 0.0, delta: 10.0 },
        ),
        kv_pool_mb: 4_000.0,
        mem: MemoryModel { utility: 1.0, mb_per_token: 0.5 },
        noise_std: 0.0,
        max_total_tokens: 4096,
    };
    let trace = vec![
        Request::synthetic(
            0,
            TaskType::Chat,
            100,
            100,
            Slo::Interactive { ttft_ms: 450.0, tpot_ms: 1e9 },
        ),
        Request::synthetic(
            1,
            TaskType::Code,
            1000,
            100,
            Slo::E2e { e2e_ms: 2_500.0 },
        ),
    ];
    let outs: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let met_by_class = |out: &OnlineOutcome| {
        let mut interactive = 0usize;
        let mut e2e = 0usize;
        for c in &out.completions {
            if c.slo_met() {
                match c.slo {
                    Slo::Interactive { .. } => interactive += 1,
                    Slo::E2e { .. } => e2e += 1,
                }
            }
        }
        (interactive, e2e)
    };
    for seed in 1..=3u64 {
        let base = SaParams {
            max_batch: 2,
            seed,
            t0: 100.0,
            iters_per_temp: 30,
            ..Default::default()
        };
        let run = |sa: &SaParams| {
            let mut engine = SimEngine::new(profile.clone(), 2, 0)
                .with_chunk_tokens(sa.chunk_tokens);
            run_online_opts(
                &trace,
                &outs,
                &mut engine,
                &profile.truth,
                sa,
                ReplanStrategy::Warm,
                OnlineOpts::default(),
            )
            .unwrap()
        };
        let whole = run(&base);
        let chunked =
            run(&SaParams { chunk_tokens: 128, window: 2, ..base });
        assert_eq!(whole.completions.len(), 2, "seed {seed}");
        assert_eq!(chunked.completions.len(), 2, "seed {seed}");
        let (i_whole, e_whole) = met_by_class(&whole);
        let (i_chunk, e_chunk) = met_by_class(&chunked);
        assert_eq!(
            i_whole, 0,
            "seed {seed}: whole-prompt prefill cannot meet the \
             interactive TTFT here"
        );
        assert_eq!(
            i_chunk, 1,
            "seed {seed}: chunked prefill must meet the interactive TTFT"
        );
        assert!(
            e_chunk >= e_whole,
            "seed {seed}: e2e attainment regressed ({e_chunk} < {e_whole})"
        );
        let first = &chunked.completions[0];
        assert_eq!(first.id, 0, "seed {seed}");
        assert!(
            first.ttft_ms <= 450.0,
            "seed {seed}: interactive ttft {} > 450",
            first.ttft_ms
        );
    }
}
