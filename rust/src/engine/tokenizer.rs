//! Byte-level tokenizer substrate.
//!
//! TinyLM uses a byte vocabulary (0–255) plus BOS (256) and EOS (257) —
//! mirrored from python/compile/model.py. One byte = one token keeps the
//! substrate honest (real prompt lengths drive real compute) without
//! requiring a trained BPE merge table.

/// Byte-level tokenizer with BOS/EOS specials.
#[derive(Debug, Clone, Copy)]
pub struct ByteTokenizer {
    pub bos: i32,
    pub eos: i32,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        ByteTokenizer { bos: 256, eos: 257 }
    }
}

impl ByteTokenizer {
    pub fn new(bos: i32, eos: i32) -> Self {
        ByteTokenizer { bos, eos }
    }

    /// Encode raw bytes (no specials added).
    pub fn encode(&self, bytes: &[u8]) -> Vec<i32> {
        bytes.iter().map(|&b| b as i32).collect()
    }

    /// Encode with a leading BOS.
    pub fn encode_with_bos(&self, bytes: &[u8]) -> Vec<i32> {
        let mut out = Vec::with_capacity(bytes.len() + 1);
        out.push(self.bos);
        out.extend(bytes.iter().map(|&b| b as i32));
        out
    }

    /// Decode token ids back to bytes, stopping at EOS; specials and
    /// out-of-range ids are dropped.
    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(tokens.len());
        for &t in tokens {
            if t == self.eos {
                break;
            }
            if (0..=255).contains(&t) {
                out.push(t as u8);
            }
        }
        out
    }

    /// Generate a deterministic printable synthetic prompt of `len` tokens
    /// (used when a scheduler-level request carries only a length).
    pub fn synthetic_prompt(&self, seed: u64, len: usize) -> Vec<u8> {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x70_6B_6E);
        (0..len)
            .map(|_| {
                // printable ASCII 32..=126
                (32 + rng.below(95)) as u8
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let tok = ByteTokenizer::default();
        let text = b"def fib(n):\n    return n".to_vec();
        let ids = tok.encode(&text);
        assert_eq!(ids.len(), text.len());
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn bos_prepended() {
        let tok = ByteTokenizer::default();
        let ids = tok.encode_with_bos(b"ab");
        assert_eq!(ids, vec![256, 97, 98]);
    }

    #[test]
    fn decode_stops_at_eos_and_skips_specials() {
        let tok = ByteTokenizer::default();
        assert_eq!(tok.decode(&[104, 105, 257, 106]), b"hi".to_vec());
        assert_eq!(tok.decode(&[256, 104, 300, 105]), b"hi".to_vec());
    }

    #[test]
    fn synthetic_prompt_deterministic_printable() {
        let tok = ByteTokenizer::default();
        let a = tok.synthetic_prompt(5, 64);
        let b = tok.synthetic_prompt(5, 64);
        assert_eq!(a, b);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&c| (32..=126).contains(&c)));
        assert_ne!(a, tok.synthetic_prompt(6, 64));
    }
}
