//! Serving-side latency histograms: fixed-memory geometric buckets.
//!
//! The offline bench path keeps raw samples ([`crate::util::stats`]); a
//! serving front door cannot — admission latencies arrive per request at
//! load-test rates and the report wants p50/p99 over the whole run. A
//! [`Histogram`] records into ~120 geometrically spaced buckets (1 µs to
//! ~10⁵ s at 25% relative width), so percentiles cost O(buckets) with a
//! bounded ~1 KiB footprint per histogram and O(1) recording. Quantile
//! error is bounded by the bucket width (≤ 25% relative), which is ample
//! for latency reporting; exact `count`/`mean`/`max` are tracked on the
//! side.

use crate::util::json::Json;

/// Smallest bucket upper bound (ms): 1 µs.
const MIN_BOUND_MS: f64 = 1e-3;
/// Geometric growth factor between bucket bounds.
const GROWTH: f64 = 1.25;
/// Bucket count: covers `MIN_BOUND_MS · GROWTH^(N-2)` ≈ 1.6e8 ms (~44 h)
/// before the final catch-all bucket.
const N_BUCKETS: usize = 120;

/// Fixed-memory latency histogram with geometric buckets (module docs).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: vec![0; N_BUCKETS], count: 0, sum: 0.0, max: 0.0 }
    }

    /// Bucket index for a value: bucket `i` covers
    /// `(MIN_BOUND·G^(i-1), MIN_BOUND·G^i]`, bucket 0 everything at or
    /// below `MIN_BOUND`, the last bucket everything above the range.
    fn bucket(v: f64) -> usize {
        if v.is_nan() || v <= MIN_BOUND_MS {
            // NaN and non-positive values land in the smallest bucket
            // rather than poisoning percentiles.
            return 0;
        }
        let i = ((v / MIN_BOUND_MS).ln() / GROWTH.ln()).ceil();
        (i as usize).min(N_BUCKETS - 1)
    }

    /// Upper bound (ms) of bucket `i` — the value percentiles report.
    fn bound(i: usize) -> f64 {
        MIN_BOUND_MS * GROWTH.powi(i as i32)
    }

    /// Record one sample (ms).
    pub fn record(&mut self, v_ms: f64) {
        self.counts[Self::bucket(v_ms)] += 1;
        self.count += 1;
        if v_ms.is_finite() {
            self.sum += v_ms;
            if v_ms > self.max {
                self.max = v_ms;
            }
        }
    }

    /// Merge another histogram into this one (shard aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Largest recorded finite sample (ms).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile `q ∈ [0, 1]` (ms): the upper bound of the bucket holding
    /// the ⌈q·count⌉-th sample, clamped to the exact max so the tail never
    /// over-reports past an observed value. 0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bound(i).min(self.max.max(MIN_BOUND_MS));
            }
        }
        self.max
    }

    /// Standard report object: count/mean/p50/p90/p99/max (ms).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(0.50))),
            ("p90", Json::num(self.percentile(0.90))),
            ("p99", Json::num(self.percentile(0.99))),
            ("max", Json::num(self.max())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
    }

    #[test]
    fn percentiles_are_bucket_accurate() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64); // 1..1000 ms uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        // 25% relative bucket width bounds the quantile error
        assert!((400.0..=650.0).contains(&p50), "p50 {p50}");
        assert!((900.0..=1250.0).contains(&p99), "p99 {p99}");
        assert!(h.percentile(1.0) <= 1000.0 + 1e-9);
        assert!((h.mean() - 500.5).abs() < 1e-6);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn extremes_clamp_into_range() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(1e12); // beyond the last bound: catch-all bucket
        assert_eq!(h.count(), 4);
        assert!(h.percentile(0.25) <= MIN_BOUND_MS + 1e-12);
        assert_eq!(h.max(), 1e12);
        // tail percentile is clamped to the observed max
        assert!(h.percentile(1.0) <= 1e12 + 1e-3);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for i in 0..200 {
            let v = 0.5 + 7.3 * i as f64;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.max(), c.max());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(q), c.percentile(q));
        }
    }

    #[test]
    fn json_report_shape() {
        let mut h = Histogram::new();
        h.record(10.0);
        let v = h.to_json();
        assert_eq!(v.get("count").as_usize(), Some(1));
        assert!(v.get("p99").as_f64().unwrap() > 0.0);
    }
}
