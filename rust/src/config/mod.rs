//! Configuration system: hardware profiles, run configuration, JSON loading.
//!
//! Every experiment (benches, examples, the CLI) is described by a
//! [`RunConfig`]; hardware/framework combinations from the paper's testbeds
//! are described by [`profiles::HardwareProfile`]s.

pub mod profiles;

use anyhow::{anyhow, Result};

use crate::coordinator::priority::annealing::SaParams;
use crate::coordinator::request::Slo;
use crate::util::json::Json;

/// How the scheduler obtains output-length predictions (Fig. 9 knob).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OutputPrediction {
    /// Running per-task Gaussian from the profiler (the shipped default).
    Profiler,
    /// Oracle with a relative error band: truth × U(1−err, 1+err).
    Oracle { rel_err: f64 },
}

impl OutputPrediction {
    pub fn name(&self) -> String {
        match self {
            OutputPrediction::Profiler => "profiler".into(),
            OutputPrediction::Oracle { rel_err } => {
                format!("oracle±{:.1}%", rel_err * 100.0)
            }
        }
    }
}

/// SLO targets for the two task classes (paper §5.1 defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    /// Code-generation e2e bound (ms). Paper: 10× mean solo latency = 30 s.
    pub code_e2e_ms: f64,
    /// Chat TTFT bound (ms). Paper: 10 s.
    pub chat_ttft_ms: f64,
    /// Chat TPOT bound (ms). Paper: 50 ms.
    pub chat_tpot_ms: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets {
            code_e2e_ms: 30_000.0,
            chat_ttft_ms: 10_000.0,
            chat_tpot_ms: 50.0,
        }
    }
}

impl SloTargets {
    pub fn code_slo(&self) -> Slo {
        Slo::E2e { e2e_ms: self.code_e2e_ms }
    }

    pub fn chat_slo(&self) -> Slo {
        Slo::Interactive {
            ttft_ms: self.chat_ttft_ms,
            tpot_ms: self.chat_tpot_ms,
        }
    }

    /// Uniformly scale all bounds (strictness sweeps).
    pub fn scaled(&self, factor: f64) -> SloTargets {
        SloTargets {
            code_e2e_ms: self.code_e2e_ms * factor,
            chat_ttft_ms: self.chat_ttft_ms * factor,
            chat_tpot_ms: self.chat_tpot_ms * factor,
        }
    }
}

/// Complete description of one experiment run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub seed: u64,
    pub n_requests: usize,
    pub max_batch: usize,
    pub n_instances: usize,
    /// Hardware/framework profile name (see [`profiles::by_name`]).
    pub profile: String,
    /// Policy name: fcfs | sjf | edf | mlfq | slo-aware-sa |
    /// slo-aware-exhaustive.
    pub policy: String,
    pub sa: SaParams,
    pub output_pred: OutputPrediction,
    pub slos: SloTargets,
    /// Actual-vs-predicted output-length divergence in the simulated
    /// engines ([`crate::engine::sim::DivergenceModel`]); `Off` (the
    /// default) replays the pre-divergence engines bit for bit.
    pub divergence: crate::engine::sim::DivergenceModel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            seed: 42,
            n_requests: 10,
            max_batch: 4,
            n_instances: 1,
            profile: "qwen7b-v100x2-vllm".into(),
            policy: "slo-aware-sa".into(),
            sa: SaParams::default(),
            output_pred: OutputPrediction::Profiler,
            slos: SloTargets::default(),
            divergence: crate::engine::sim::DivergenceModel::Off,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document; missing fields keep defaults.
    pub fn from_json(v: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(s) = v.get("seed").as_i64() {
            cfg.seed = s as u64;
        }
        if let Some(n) = v.get("n_requests").as_usize() {
            cfg.n_requests = n;
        }
        if let Some(b) = v.get("max_batch").as_usize() {
            if b == 0 {
                return Err(anyhow!("max_batch must be >= 1"));
            }
            cfg.max_batch = b;
        }
        if let Some(i) = v.get("n_instances").as_usize() {
            if i == 0 {
                return Err(anyhow!("n_instances must be >= 1"));
            }
            cfg.n_instances = i;
        }
        if let Some(p) = v.get("profile").as_str() {
            cfg.profile = p.to_string();
        }
        if let Some(p) = v.get("policy").as_str() {
            cfg.policy = p.to_string();
        }
        let sa = v.get("sa");
        if !sa.is_null() {
            if let Some(t0) = sa.get("t0").as_f64() {
                cfg.sa.t0 = t0;
            }
            if let Some(t) = sa.get("t_thres").as_f64() {
                cfg.sa.t_thres = t;
            }
            if let Some(i) = sa.get("iters_per_temp").as_usize() {
                cfg.sa.iters_per_temp = i;
            }
            if let Some(d) = sa.get("decay").as_f64() {
                if !(0.0 < d && d < 1.0) {
                    return Err(anyhow!("sa.decay must be in (0,1)"));
                }
                cfg.sa.decay = d;
            }
        }
        if let Some(spec) = v.get("divergence").as_str() {
            cfg.divergence = crate::engine::sim::DivergenceModel::parse(spec)
                .map_err(|e| anyhow!(e))?;
        }
        let op = v.get("output_pred");
        if let Some(kind) = op.get("kind").as_str() {
            cfg.output_pred = match kind {
                "profiler" => OutputPrediction::Profiler,
                "oracle" => OutputPrediction::Oracle {
                    rel_err: op.get("rel_err").as_f64().unwrap_or(0.0),
                },
                other => return Err(anyhow!("unknown output_pred {other}")),
            };
        }
        let slos = v.get("slos");
        if !slos.is_null() {
            if let Some(x) = slos.get("code_e2e_ms").as_f64() {
                cfg.slos.code_e2e_ms = x;
            }
            if let Some(x) = slos.get("chat_ttft_ms").as_f64() {
                cfg.slos.chat_ttft_ms = x;
            }
            if let Some(x) = slos.get("chat_tpot_ms").as_f64() {
                cfg.slos.chat_tpot_ms = x;
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        RunConfig::from_json(&v)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("n_requests", Json::num(self.n_requests as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("n_instances", Json::num(self.n_instances as f64)),
            ("profile", Json::str(self.profile.clone())),
            ("policy", Json::str(self.policy.clone())),
            ("divergence", Json::str(self.divergence.spec())),
            (
                "sa",
                Json::obj(vec![
                    ("t0", Json::num(self.sa.t0)),
                    ("t_thres", Json::num(self.sa.t_thres)),
                    (
                        "iters_per_temp",
                        Json::num(self.sa.iters_per_temp as f64),
                    ),
                    ("decay", Json::num(self.sa.decay)),
                ]),
            ),
            (
                "slos",
                Json::obj(vec![
                    ("code_e2e_ms", Json::num(self.slos.code_e2e_ms)),
                    ("chat_ttft_ms", Json::num(self.slos.chat_ttft_ms)),
                    ("chat_tpot_ms", Json::num(self.slos.chat_tpot_ms)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = RunConfig::default();
        assert_eq!(c.slos.code_e2e_ms, 30_000.0);
        assert_eq!(c.slos.chat_ttft_ms, 10_000.0);
        assert_eq!(c.slos.chat_tpot_ms, 50.0);
        assert_eq!(c.sa.t0, 500.0);
        assert_eq!(c.sa.t_thres, 20.0);
        assert_eq!(c.sa.iters_per_temp, 100);
        assert_eq!(c.sa.decay, 0.95);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default();
        c.seed = 7;
        c.n_requests = 40;
        c.max_batch = 2;
        c.policy = "fcfs".into();
        c.sa.t0 = 200.0;
        c.divergence =
            crate::engine::sim::DivergenceModel::Lognormal { sigma: 0.5 };
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.seed, 7);
        assert_eq!(back.n_requests, 40);
        assert_eq!(back.max_batch, 2);
        assert_eq!(back.policy, "fcfs");
        assert_eq!(back.sa.t0, 200.0);
        assert_eq!(back.divergence, c.divergence);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let v = Json::parse(r#"{"n_requests": 6}"#).unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.n_requests, 6);
        assert_eq!(c.max_batch, RunConfig::default().max_batch);
    }

    #[test]
    fn invalid_values_rejected() {
        for bad in [
            r#"{"max_batch": 0}"#,
            r#"{"n_instances": 0}"#,
            r#"{"sa": {"decay": 1.5}}"#,
            r#"{"output_pred": {"kind": "magic"}}"#,
            r#"{"divergence": "gamma:0.5"}"#,
            r#"{"divergence": "lognormal:-1"}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(RunConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn oracle_output_pred_parses() {
        let v = Json::parse(
            r#"{"output_pred": {"kind": "oracle", "rel_err": 0.05}}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.output_pred, OutputPrediction::Oracle { rel_err: 0.05 });
        assert_eq!(c.output_pred.name(), "oracle±5.0%");
    }

    #[test]
    fn slo_scaling() {
        let s = SloTargets::default().scaled(0.5);
        assert_eq!(s.code_e2e_ms, 15_000.0);
        assert_eq!(s.chat_tpot_ms, 25.0);
    }
}
