//! Quickstart: load the AOT-compiled TinyLM and serve one batch.
//!
//! Build artifacts first (`make artifacts`), then:
//!     cargo run --release --example quickstart
//!
//! Demonstrates the full three-layer stack in ~30 lines: artifacts
//! (Pallas kernels inside a JAX model, lowered to HLO text) are loaded by
//! the Rust PJRT runtime and executed as a planned batch.

use slo_serve::engine::real::RealEngine;
use slo_serve::engine::{Engine, EngineRequest};

fn main() -> anyhow::Result<()> {
    let mut engine = RealEngine::load("artifacts")?;
    println!("engine: {} (max batch {}, max tokens {})",
             engine.name(), engine.max_batch(), engine.max_total_tokens());

    let batch = vec![
        EngineRequest {
            id: 0,
            input_len: 0,
            max_new_tokens: 16,
            prompt: Some(b"def fibonacci(n):".to_vec()),
        },
        EngineRequest {
            id: 1,
            input_len: 0,
            max_new_tokens: 12,
            prompt: Some(b"Hello, how are you?".to_vec()),
        },
    ];
    let results = engine.run_batch(&batch)?;
    for r in &results {
        println!(
            "request {}: {} tokens, ttft {:.1} ms, tpot {:.2} ms, e2e {:.1} ms",
            r.id,
            r.generated,
            r.first_token_ms - r.start_ms,
            r.tpot_ms(),
            r.finish_ms - r.start_ms,
        );
        if let Some(text) = &r.text {
            println!("  bytes: {:?}", String::from_utf8_lossy(text));
        }
    }
    println!("quickstart OK");
    Ok(())
}
