"""L2: TinyLM — a GPT-style decoder-only transformer in JAX.

This is the model the Rust serving stack executes.  It is deliberately small
(≈1 M parameters by default) so the whole three-layer stack — Pallas kernel →
JAX graph → HLO text → Rust PJRT runtime — runs quickly on the CPU testbed,
while keeping the *structure* of a production LLM: RMSNorm, rotary position
embeddings, multi-head attention with an explicit KV cache, and a SwiGLU MLP.

Two entry points mirror the two phases of inference (paper §2.1):

* :func:`prefill` — process a right-padded prompt batch ``[B, S]`` in one
  shot, producing logits for every position and a KV cache padded to
  ``max_seq`` (slots ≥ the row's true length hold garbage; decode masks them
  by position).
* :func:`decode_step` — extend each row by one token at a per-row position,
  updating the cache in place (functionally).

The KV caches are explicit *arguments and results* — never module state — so
the AOT-compiled executables are pure functions and the Rust runtime can keep
the cache as opaque device buffers between steps (see rust/src/engine/real.rs).

Attention is computed by the L1 Pallas kernels (``attn_impl="pallas"``) or by
the pure-jnp oracle (``attn_impl="ref"``); tests assert both paths agree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import attention as attn_kernels
from .kernels import ref as attn_ref

# Token conventions (byte-level tokenizer; mirrored in rust engine/tokenizer.rs).
BOS_ID = 256
EOS_ID = 257
VOCAB_SIZE = 258


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """TinyLM hyperparameters.  Defaults are the shipped serving model."""

    vocab: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ffn: int = 256
    max_seq: int = 384
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    def __post_init__(self):
        assert self.n_heads * self.head_dim == self.d_model, \
            "d_model must equal n_heads * head_dim"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def param_count(self) -> int:
        per_layer = (4 * self.d_model * self.d_model
                     + 3 * self.d_model * self.d_ffn
                     + 2 * self.d_model)
        return (self.vocab * self.d_model            # embed
                + self.n_layers * per_layer
                + self.d_model                       # final norm
                + self.d_model * self.vocab)         # unembed


def param_order(cfg: ModelConfig) -> List[str]:
    """Canonical flat parameter order — the AOT argument order.

    The Rust runtime feeds weight buffers in exactly this order; keep in sync
    with ``artifacts/manifest.json`` (written by aot.py from this function).
    """
    names = ["embed"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        names += [p + "attn_norm", p + "attn.wq", p + "attn.wk",
                  p + "attn.wv", p + "attn.wo",
                  p + "mlp_norm", p + "mlp.w_gate", p + "mlp.w_up",
                  p + "mlp.w_down"]
    names += ["final_norm", "unembed"]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    shapes = {"embed": (v, d), "final_norm": (d,), "unembed": (d, v)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "attn_norm"] = (d,)
        shapes[p + "attn.wq"] = (d, d)
        shapes[p + "attn.wk"] = (d, d)
        shapes[p + "attn.wv"] = (d, d)
        shapes[p + "attn.wo"] = (d, d)
        shapes[p + "mlp_norm"] = (d,)
        shapes[p + "mlp.w_gate"] = (d, f)
        shapes[p + "mlp.w_up"] = (d, f)
        shapes[p + "mlp.w_down"] = (f, d)
    return shapes


def init_params(cfg: ModelConfig, seed: int = 42) -> Dict[str, jax.Array]:
    """Scaled-normal initialisation (untrained weights — the serving benches
    measure latency, not quality; generation length is driven by max_tokens)."""
    shapes = param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    params = {}
    for name in param_order(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[n] for n in param_order(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    return dict(zip(param_order(cfg), flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps) * w).astype(x.dtype)


def _rope_angles(positions, head_dim: int, theta: float):
    """positions [...]->angles [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float):
    """Rotary embedding.  x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, x.shape[-1], theta)       # [..., seq, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., seq, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _attention_prefill(x, layer, cfg: ModelConfig, positions, attn_impl: str):
    """x: [B, S, D] -> (out [B, S, D], k [B, S, H, Dh], v [B, S, H, Dh])."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ layer["attn.wq"]).reshape(b, s, h, dh)
    k = (x @ layer["attn.wk"]).reshape(b, s, h, dh)
    v = (x @ layer["attn.wv"]).reshape(b, s, h, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # kernels want [B, H, S, Dh]
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    if attn_impl == "pallas":
        ot = attn_kernels.flash_attention(qt, kt, vt, causal=True)
    else:
        ot = attn_ref.flash_attention_ref(qt, kt, vt, causal=True)
    out = ot.transpose(0, 2, 1, 3).reshape(b, s, d) @ layer["attn.wo"]
    return out, k, v


def _attention_decode(x, layer, cfg: ModelConfig, k_cache, v_cache, pos,
                      attn_impl: str):
    """One-token attention.

    x: [B, D] (the new token's hidden state);
    k_cache/v_cache: [B, max_seq, H, Dh] for this layer; pos: [B] int32.
    Returns (out [B, D], k_cache', v_cache').
    """
    b, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ layer["attn.wq"]).reshape(b, h, dh)
    k = (x @ layer["attn.wk"]).reshape(b, h, dh)
    v = (x @ layer["attn.wv"]).reshape(b, h, dh)
    # rope at per-row position: treat as seq len 1
    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    # write the new K/V into the cache at pos (per row)
    def write(cache_row, new_row, p):
        return lax.dynamic_update_slice(cache_row, new_row[None], (p, 0, 0))

    k_cache = jax.vmap(write)(k_cache, k, pos)
    v_cache = jax.vmap(write)(v_cache, v, pos)

    # kernels want caches as [B, H, S, Dh]
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    if attn_impl == "pallas":
        ot = attn_kernels.decode_attention(q, kt, vt, pos)
    else:
        ot = attn_ref.decode_attention_ref(q, kt, vt, pos)
    out = ot.reshape(b, d) @ layer["attn.wo"]
    return out, k_cache, v_cache


def _mlp(x, layer):
    gate = jax.nn.silu(x @ layer["mlp.w_gate"])
    up = x @ layer["mlp.w_up"]
    return (gate * up) @ layer["mlp.w_down"]


def _layer_params(params: Dict[str, jax.Array], i: int) -> Dict[str, jax.Array]:
    p = f"layers.{i}."
    return {k[len(p):]: v for k, v in params.items() if k.startswith(p)}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Dict[str, jax.Array], tokens,
            attn_impl: str = "pallas"):
    """Prompt processing.

    tokens: [B, S] int32 (right-padded; padded tail is garbage but harmless —
    causal attention keeps real positions clean and decode masks by pos).

    Returns (logits [B, S, V], k_caches [L, B, max_seq, H, Dh], v_caches same).
    """
    b, s = tokens.shape
    if s > cfg.max_seq:
        raise ValueError(f"prefill seq {s} > max_seq {cfg.max_seq}")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        layer = _layer_params(params, i)
        a_in = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        a_out, k, v = _attention_prefill(a_in, layer, cfg, positions, attn_impl)
        x = x + a_out
        m_in = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _mlp(m_in, layer)
        pad = cfg.max_seq - s
        k_caches.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(cfg: ModelConfig, params: Dict[str, jax.Array],
                k_caches, v_caches, tokens, pos, attn_impl: str = "pallas"):
    """One decode iteration.

    k_caches/v_caches: [L, B, max_seq, H, Dh]; tokens: [B] int32 (the tokens
    being fed this step); pos: [B] int32 (slot each token occupies — i.e. the
    row's current length).  Rows that are inactive padding in the batch can
    use pos pointing at a scratch slot; their outputs are ignored upstream.

    Returns (logits [B, V], k_caches', v_caches').
    """
    x = params["embed"][tokens]          # [B, D]
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        layer = _layer_params(params, i)
        a_in = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        a_out, kc, vc = _attention_decode(
            a_in, layer, cfg, k_caches[i], v_caches[i], pos, attn_impl)
        x = x + a_out
        m_in = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _mlp(m_in, layer)
        new_k.append(kc)
        new_v.append(vc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# Convenience: flat-argument wrappers (the AOT lowering surface; aot.py uses
# these so the HLO signature is (param_0, ..., param_n, data...) ).

def prefill_flat(cfg: ModelConfig, attn_impl: str = "pallas"):
    n = len(param_order(cfg))

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        tokens = args[n]
        return prefill(cfg, params, tokens, attn_impl)

    return fn


def decode_flat(cfg: ModelConfig, attn_impl: str = "pallas"):
    n = len(param_order(cfg))

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        k_caches, v_caches, tokens, pos = args[n:n + 4]
        return decode_step(cfg, params, k_caches, v_caches, tokens, pos,
                           attn_impl)

    return fn
