//! # slo-serve
//!
//! A reproduction of *"SLO-Aware Scheduling for Large Language Model
//! Inferences"* (Huang et al., CS.DC 2025) as a three-layer Rust + JAX +
//! Pallas serving framework:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas attention kernels
//!   (prefill flash-attention + decode-step KV-cache attention).
//! * **L2** (`python/compile/model.py`) — TinyLM, a GPT-style decoder in
//!   JAX, AOT-lowered to HLO text per (batch, seq) bucket.
//! * **L3** (this crate) — the serving system: the paper's simulated-
//!   annealing SLO-aware scheduler ([`coordinator`]), LLM engines
//!   ([`engine`]: a PJRT-backed real engine and a calibrated simulator),
//!   the PJRT runtime (`runtime`, feature-gated), workload generators
//!   ([`workload`]),
//!   metrics ([`metrics`]), a TCP serving front-end ([`server`]), and the
//!   bench harness ([`bench`]) that regenerates every table/figure of the
//!   paper's evaluation.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust. See DESIGN.md for the architecture and the experiment index,
//! EXPERIMENTS.md for measured results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod metrics;
/// PJRT runtime — requires the external `xla` crate; gated behind the
/// `real-engine` feature so the default (offline/CI) build stays
/// self-contained.
#[cfg(feature = "real-engine")]
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::config::profiles::{by_name, HardwareProfile};
    pub use crate::config::{OutputPrediction, RunConfig, SloTargets};
    pub use crate::coordinator::kv::{KvConfig, KvMode, KvPhaseModel};
    pub use crate::coordinator::objective::{
        Evaluator, Job, Schedule, TimelineOrigin,
    };
    pub use crate::coordinator::online::{
        run_online, run_online_fleet, run_online_fleet_opts, run_online_opts,
        OnlineOpts, PredictedJob, ReplanStrategy, WaveController,
    };
    pub use crate::coordinator::policies::Policy;
    pub use crate::coordinator::predictor::LatencyPredictor;
    pub use crate::coordinator::priority::annealing::{
        priority_mapping, priority_mapping_warm, SaParams,
    };
    pub use crate::coordinator::profiler::RequestProfiler;
    pub use crate::coordinator::request::{Request, Slo, TaskType};
    pub use crate::coordinator::scheduler::{
        instance_seed, schedule, InstanceInfo,
    };
    pub use crate::engine::sim::{DivergenceModel, SimEngine};
    pub use crate::engine::{Engine, EngineRequest};
    pub use crate::metrics::RunMetrics;
    pub use crate::util::rng::Rng;
    pub use crate::workload::dataset::RequestFactory;
    pub use crate::workload::trace::{ArrivalProcess, ClassMix, TraceSpec};
}
