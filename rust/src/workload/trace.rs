//! Arrival processes: turn request waves into timed traces.
//!
//! The paper's evaluation submits waves of concurrent requests (arrival at
//! t=0); production front-ends see Poisson or bursty arrivals. All three
//! are supported so the serving example and ablations can exercise the
//! continuous-batching path under load.

use crate::coordinator::request::Request;
use crate::util::rng::Rng;

/// Arrival-time process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// All requests arrive at t = 0 (the paper's wave methodology).
    Concurrent,
    /// Poisson arrivals at `rps` requests/second.
    Poisson { rps: f64 },
    /// Bursts of `burst` concurrent requests every `period_ms`.
    Bursty { burst: usize, period_ms: f64 },
}

/// Trace spec: how many requests and how they arrive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    pub n: usize,
    pub arrivals: ArrivalProcess,
}

impl ArrivalProcess {
    /// Stamp arrival times onto a request wave (in place, preserving order).
    pub fn apply(&self, requests: &mut [Request], rng: &mut Rng) {
        match *self {
            ArrivalProcess::Concurrent => {
                for r in requests.iter_mut() {
                    r.arrival_ms = 0.0;
                }
            }
            ArrivalProcess::Poisson { rps } => {
                assert!(rps > 0.0, "Poisson rate must be positive");
                let mut t = 0.0;
                for r in requests.iter_mut() {
                    t += rng.exponential(rps / 1000.0); // gaps in ms
                    r.arrival_ms = t;
                }
            }
            ArrivalProcess::Bursty { burst, period_ms } => {
                assert!(burst > 0);
                for (i, r) in requests.iter_mut().enumerate() {
                    r.arrival_ms = (i / burst) as f64 * period_ms;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SloTargets;
    use crate::workload::dataset::RequestFactory;

    fn wave(n: usize) -> Vec<Request> {
        RequestFactory::new(0, SloTargets::default()).mixed_wave(n)
    }

    #[test]
    fn concurrent_zeroes_arrivals() {
        let mut reqs = wave(5);
        let mut rng = Rng::new(0);
        ArrivalProcess::Concurrent.apply(&mut reqs, &mut rng);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
    }

    #[test]
    fn poisson_is_monotone_with_correct_rate() {
        let mut reqs = wave(2000);
        let mut rng = Rng::new(1);
        ArrivalProcess::Poisson { rps: 10.0 }.apply(&mut reqs, &mut rng);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        // 2000 requests at 10 rps ≈ 200 s span
        let span_s = reqs.last().unwrap().arrival_ms / 1000.0;
        assert!((span_s - 200.0).abs() < 20.0, "span {span_s}");
    }

    #[test]
    fn bursty_groups() {
        let mut reqs = wave(10);
        let mut rng = Rng::new(2);
        ArrivalProcess::Bursty { burst: 4, period_ms: 100.0 }
            .apply(&mut reqs, &mut rng);
        assert_eq!(reqs[0].arrival_ms, 0.0);
        assert_eq!(reqs[3].arrival_ms, 0.0);
        assert_eq!(reqs[4].arrival_ms, 100.0);
        assert_eq!(reqs[9].arrival_ms, 200.0);
    }
}
