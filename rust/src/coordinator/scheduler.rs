//! Multi-instance SLO-aware scheduling (paper §4.4, Algorithm 2).
//!
//! The scheduling solution decomposes into **instance assignment** followed
//! by **per-instance priority mapping** (run independently — the paper
//! notes the mappings are parallelizable across instances, which this
//! implementation exploits with scoped threads):
//!
//! 1. predict request latencies;
//! 2. assign requests round-robin to the instance with the largest
//!    remaining memory — accounted in KV blocks via Eq. 20
//!    ([`InstanceInfo::pool_blocks`]); when the largest remaining capacity
//!    cannot host the next request, remaining capacities are reset — a new
//!    "iteration" of assignments begins. A request no instance can ever
//!    host is a hard scheduling error;
//! 3. run Algorithm 1 inside each instance — one scoped thread per
//!    instance, since the searches share nothing but the immutable
//!    predictor and their own job slices. With KV enforcement on
//!    ([`crate::coordinator::kv::KvMode`]), each instance's search is
//!    additionally bound to its own block pool, so planned batches never
//!    overcommit at execution time;
//! 4. enqueue each instance's priority sequence for execution.
//!
//! [`ScheduleOutcome`] reports the scheduling overhead both ways: wall
//! clock (what the parallel mapping actually costs) and CPU time (the sum
//! of per-instance mapping times — the quantity comparable to the paper's
//! Fig. 11(B), whose instances are mapped sequentially on one server).

use anyhow::{bail, Result};

use crate::coordinator::kv::{self, KvConfig, KvMode, KvPhaseModel};
use crate::coordinator::objective::{Evaluator, Job, Schedule};
use crate::coordinator::policies::{slack_key, slo_deadline_ms};
use crate::coordinator::predictor::LatencyPredictor;
use crate::coordinator::priority::annealing::{
    priority_mapping, SaParams, SaResult, SearchStats,
};
use crate::coordinator::profiler::MemoryModel;
use crate::coordinator::request::Request;

/// Static description of one LLM inference instance.
#[derive(Debug, Clone, Copy)]
pub struct InstanceInfo {
    pub id: usize,
    /// KV-cache memory pool size (MB).
    pub mem_mb: f64,
}

impl InstanceInfo {
    /// This instance's KV pool in blocks, through Eq. 20
    /// (`token_num(m) = m·μ/σ`) at `block_tokens` granularity.
    pub fn pool_blocks(&self, mem: &MemoryModel, block_tokens: usize) -> u64 {
        kv::pool_blocks_from_mb(self.mem_mb, mem, block_tokens)
    }
}

/// Per-instance execution plan produced by the scheduler.
#[derive(Debug, Clone)]
pub struct InstancePlan {
    pub instance: usize,
    /// Scheduler's job views (with predicted output lengths); `req_idx`
    /// points into the request slice given to [`schedule`].
    pub jobs: Vec<Job>,
    /// Priority sequence + batch partition over `jobs` (local indices).
    pub schedule: Schedule,
    pub stats: SearchStats,
}

impl InstancePlan {
    /// Request indices in execution order.
    pub fn request_order(&self) -> Vec<usize> {
        self.schedule.order.iter().map(|&j| self.jobs[j].req_idx).collect()
    }
}

/// Result of Algorithm 2 over one wave of requests.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub plans: Vec<InstancePlan>,
    /// Wall-clock scheduling overhead (ms): assignment plus the *parallel*
    /// per-instance mapping section. This is what a caller actually waits.
    pub overhead_ms: f64,
    /// CPU-time scheduling overhead (ms): assignment plus the *sum* of
    /// per-instance [`SearchStats::cpu_ms`] — which itself sums busy time
    /// across that instance's tempered chains, so with `chains > 1` this
    /// is Σ over chains × instances. Comparable to the paper's Fig. 11(B)
    /// numbers, whose instances are mapped sequentially on one server —
    /// report this, not `overhead_ms`, when reproducing that figure.
    pub cpu_ms: f64,
    /// Accepted best-exchanges summed across every instance's tempered
    /// search ([`SearchStats::exchanges`]); 0 at `chains == 1`.
    pub exchanges: usize,
    /// Base RNG seed the wave was planned with (each instance searches at
    /// [`instance_seed`] of it). Recorded so a plan — and the bench JSON
    /// rows derived from it — can be reproduced exactly.
    pub seed: u64,
}

/// Per-instance search seed derived from the wave's base seed: instances
/// explore independently, and the derivation is shared with the online
/// path ([`crate::coordinator::online`]) so a single-instance online run
/// with t=0 arrivals replays the closed-wave search bit for bit.
pub fn instance_seed(base: u64, inst: usize) -> u64 {
    base.wrapping_add(inst as u64).wrapping_mul(0x9E3779B9)
}

/// Instance assignment (Algorithm 2 line 4, "Instance Assignment" ¶).
///
/// Requests are considered in arrival order; each goes to the instance
/// with the largest remaining memory. All accounting is in **KV blocks**
/// (the same Eq. 20 conversion plus block rounding the SA search and the
/// engine allocator use): a request's footprint is its total token count
/// (input + predicted output) rounded up to blocks, and an instance's
/// capacity is [`InstanceInfo::pool_blocks`]. If even the largest-
/// remaining instance lacks room, all remaining capacities reset (a
/// maximum-capacity wave has been packed) and assignment continues.
///
/// # Errors
/// A request whose footprint alone exceeds **every** instance's pool can
/// never execute; assignment fails with a descriptive error instead of
/// silently overcommitting (the pre-KV behaviour let the remaining-memory
/// counter go negative).
pub fn assign_instances(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    mem: &MemoryModel,
    block_tokens: usize,
) -> Result<Vec<Vec<usize>>> {
    assert_eq!(requests.len(), predicted_out.len());
    assert!(!instances.is_empty());
    let block_tokens = block_tokens.max(1);
    let pools: Vec<u64> = instances
        .iter()
        .map(|i| i.pool_blocks(mem, block_tokens))
        .collect();
    let mut remaining: Vec<u64> = pools.clone();
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); instances.len()];

    // Integer blocks: NaN/negative capacities became empty pools in the
    // Eq. 20 conversion, so a plain max suffices (ties keep the previous
    // float-path behaviour of picking the last maximal instance).
    fn largest(remaining: &[u64]) -> usize {
        remaining
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    }

    for (ri, req) in requests.iter().enumerate() {
        let tokens = req.input_len + predicted_out[ri];
        let need = kv::blocks_for(tokens, block_tokens);
        // pick instance with the largest remaining capacity
        let mut best = largest(&remaining);
        if remaining[best] < need {
            // reset: a full wave has been packed (§4.4); re-scan since the
            // globally-largest instance may differ from the current one
            remaining.copy_from_slice(&pools);
            best = largest(&remaining);
            if remaining[best] < need {
                bail!(
                    "request {ri} (id {}): KV footprint of {need} blocks \
                     ({tokens} tokens at {block_tokens} tokens/block) \
                     exceeds every instance's pool (largest: {} blocks) — \
                     the request can never be scheduled",
                    req.id,
                    remaining[best],
                );
            }
        }
        remaining[best] -= need;
        out[best].push(ri);
    }
    Ok(out)
}

/// Algorithm 2: full SLO-aware scheduling across instances.
///
/// `predicted_out[i]` is the predicted output length for `requests[i]`
/// (from the profiler or an oracle — the Fig. 9 knob). Per-instance
/// priority mappings run on scoped threads (one per non-trivial instance);
/// plan order is deterministic (by instance index) and each instance's
/// search keeps its own derived RNG seed, so results are identical to the
/// sequential execution.
///
/// **KV threading**: instance assignment always accounts in Eq. 20 blocks.
/// When `sa.kv` enforces a pool ([`KvMode::Hard`]/[`KvMode::Soft`]), each
/// instance's search additionally runs against *its own* pool — the
/// smaller of the instance's [`InstanceInfo::pool_blocks`] and any
/// engine-level cap in `sa.kv.pool_blocks` — replacing the old standalone
/// Eq. 20 check with end-to-end feasibility. `sa.kv.phase` flows into the
/// per-instance searches unchanged, so a
/// [`crate::coordinator::kv::KvPhaseModel::Phased`] config prices each
/// planned batch at its occupancy peak; *assignment* itself keeps the
/// conservative full-footprint accounting (requests from one wave may
/// coexist across batches, and reserve sums bound every phased peak).
/// With the default unlimited config the searches are bit-identical to
/// the pre-KV scheduler.
///
/// # Errors
/// Fails when a request's KV footprint exceeds every instance's pool
/// (see [`assign_instances`]).
pub fn schedule(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    predictor: &LatencyPredictor,
    mem: &MemoryModel,
    sa: &SaParams,
) -> Result<ScheduleOutcome> {
    let t0 = crate::util::now_ms();
    let assignment = assign_instances(
        requests,
        predicted_out,
        instances,
        mem,
        sa.kv.block_tokens,
    )?;
    let assign_ms = crate::util::now_ms() - t0;

    // Materialize per-instance job sets first so the mapping threads borrow
    // only immutable data.
    let job_sets: Vec<Vec<Job>> = assignment
        .iter()
        .map(|req_indices| {
            req_indices
                .iter()
                .map(|&ri| {
                    Job::from_request(ri, &requests[ri], predicted_out[ri])
                })
                .collect()
        })
        .collect();
    // Derive a per-instance seed so instances explore independently, and
    // bind each search to its instance's KV pool when enforcement is on.
    let params: Vec<SaParams> = (0..job_sets.len())
        .map(|inst| SaParams {
            seed: instance_seed(sa.seed, inst),
            kv: match sa.kv.mode {
                KvMode::Unlimited => sa.kv,
                _ => KvConfig {
                    pool_blocks: sa.kv.pool_blocks.min(
                        instances[inst].pool_blocks(mem, sa.kv.block_tokens),
                    ),
                    ..sa.kv
                },
            },
            ..*sa
        })
        .collect();

    let busy = job_sets.iter().filter(|jobs| !jobs.is_empty()).count();
    let results: Vec<SaResult> = if busy <= 1 {
        // Thread spawn costs more than a trivial mapping; stay inline.
        job_sets
            .iter()
            .zip(&params)
            .map(|(jobs, p)| {
                let ev = Evaluator::new(jobs, predictor)
                    .with_chunk_tokens(p.chunk_tokens);
                priority_mapping(&ev, p)
            })
            .collect()
    } else {
        std::thread::scope(|scope| {
            // Threads only for instances with work; empty mappings return
            // immediately and are cheaper than a spawn.
            let handles: Vec<_> = job_sets
                .iter()
                .zip(&params)
                .map(|(jobs, p)| {
                    if jobs.is_empty() {
                        None
                    } else {
                        Some(scope.spawn(move || {
                            let ev = Evaluator::new(jobs, predictor)
                                .with_chunk_tokens(p.chunk_tokens);
                            priority_mapping(&ev, p)
                        }))
                    }
                })
                .collect();
            handles
                .into_iter()
                .zip(job_sets.iter().zip(&params))
                .map(|(h, (jobs, p))| match h {
                    Some(h) => {
                        h.join().expect("priority-mapping thread panicked")
                    }
                    None => {
                        let ev = Evaluator::new(jobs, predictor)
                            .with_chunk_tokens(p.chunk_tokens);
                        priority_mapping(&ev, p)
                    }
                })
                .collect()
        })
    };

    // cpu_ms (not overhead_ms): each instance's figure already folds in
    // the busy time of its concurrent tempered chains.
    let mapping_cpu_ms: f64 = results.iter().map(|r| r.stats.cpu_ms).sum();
    let exchanges: usize = results.iter().map(|r| r.stats.exchanges).sum();
    let plans: Vec<InstancePlan> = job_sets
        .into_iter()
        .zip(results)
        .enumerate()
        .map(|(inst, (jobs, result))| InstancePlan {
            instance: inst,
            jobs,
            schedule: result.schedule,
            stats: result.stats,
        })
        .collect();

    Ok(ScheduleOutcome {
        plans,
        overhead_ms: crate::util::now_ms() - t0,
        cpu_ms: assign_ms + mapping_cpu_ms,
        exchanges,
        seed: sa.seed,
    })
}

/// Outcome of one [`rebalance_overcommit`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Jobs moved to a peer instance.
    pub moved_jobs: usize,
    /// KV blocks those jobs carried (Eq. 20 footprints).
    pub moved_blocks: u64,
    /// Instances that shed at least one job.
    pub source_instances: usize,
}

/// Per-instance enforcement pool: the smaller of the engine-level cap and
/// the instance's own Eq. 20 pool — the same bound the per-instance
/// searches in [`schedule`] run against.
fn enforce_pool(
    sa: &SaParams,
    instances: &[InstanceInfo],
    mem: &MemoryModel,
    inst: usize,
) -> u64 {
    sa.kv
        .pool_blocks
        .min(instances[inst].pool_blocks(mem, sa.kv.block_tokens))
}

/// Deterministic cross-instance **work-stealing repair pass** over a
/// planned wave: while an instance's plan overcommits its KV pool
/// ([`Evaluator::kv_excess`] > 0 under the configured phase model), the
/// most-slack job of its worst-overflowing batch is moved to the
/// least-loaded peer whose whole wave still fits its pool, where it lands
/// as a trailing singleton batch in that peer's queue. Victims are chosen
/// by descending [`slack_key`] (ties to the later plan position — the
/// engine's own preemption-victim rule), targets by ascending block load
/// (ties to the lowest instance index), so repeated runs over the same
/// outcome make identical choices.
///
/// Overcommitted plans exist by design: a Soft pool prices excess instead
/// of forbidding it, and a Hard pool with preemption pricing
/// ([`KvConfig::prices_preemption`]) deliberately keeps overcommitted
/// plans whose cost model says the engine-side suspend/resume is worth
/// it. This pass converts that residual overcommit into peer capacity
/// when a peer has any — jobs no peer can host simply stay, and the
/// engine's preemption model absorbs them at execution time.
///
/// Returns what moved; zero stats (and an untouched outcome) when the
/// pool is unlimited, the fleet has one instance, or nothing overcommits.
pub fn rebalance_overcommit(
    outcome: &mut ScheduleOutcome,
    instances: &[InstanceInfo],
    predictor: &LatencyPredictor,
    mem: &MemoryModel,
    sa: &SaParams,
) -> MigrationStats {
    let mut stats = MigrationStats::default();
    let n = outcome.plans.len();
    if !sa.kv.binding() || n <= 1 {
        return stats;
    }
    assert_eq!(n, instances.len());
    let pools: Vec<u64> =
        (0..n).map(|i| enforce_pool(sa, instances, mem, i)).collect();
    // Reserve-style total load: what a peer's whole wave pins if every
    // job coexists — the conservative bound the assignment pass also
    // uses, so a target absorbing `need` more blocks never overcommits.
    fn load(plan: &InstancePlan, kvc: &KvConfig) -> u64 {
        plan.jobs
            .iter()
            .map(|j| kvc.job_blocks(j.input_len, j.output_len))
            .sum()
    }

    for src in 0..n {
        let mut shed_any = false;
        loop {
            let kv_src = KvConfig { pool_blocks: pools[src], ..sa.kv };
            let excess = {
                let plan = &outcome.plans[src];
                if plan.jobs.is_empty() {
                    0
                } else {
                    Evaluator::new(&plan.jobs, predictor)
                        .kv_excess(&plan.schedule, &kv_src)
                }
            };
            if excess == 0 {
                break;
            }
            // Victim batch: the largest per-batch overflow under the
            // active phase model (ties to the earliest batch).
            let (pos, lj, job, need) = {
                let plan = &outcome.plans[src];
                let mut vb: Option<(u64, usize, usize)> = None;
                for (_, start, size) in plan.schedule.batch_spans() {
                    let blocks = match sa.kv.phase {
                        KvPhaseModel::Reserve => plan.schedule.order
                            [start..start + size]
                            .iter()
                            .map(|&j| {
                                sa.kv.job_blocks(
                                    plan.jobs[j].input_len,
                                    plan.jobs[j].output_len,
                                )
                            })
                            .sum::<u64>(),
                        KvPhaseModel::Phased => {
                            let members: Vec<(usize, usize)> = plan
                                .schedule
                                .order[start..start + size]
                                .iter()
                                .map(|&j| {
                                    (
                                        plan.jobs[j].input_len,
                                        plan.jobs[j].output_len,
                                    )
                                })
                                .collect();
                            kv::phased_peak_blocks(
                                &members,
                                sa.kv.block_tokens,
                            )
                        }
                    };
                    let over = blocks.saturating_sub(pools[src]);
                    if over > 0 {
                        let better = match vb {
                            None => true,
                            Some((bo, ..)) => over > bo,
                        };
                        if better {
                            vb = Some((over, start, size));
                        }
                    }
                }
                let Some((_, start, size)) = vb else { break };
                // Victim job: most slack within the batch — the work that
                // can best afford a fresh queue — ties to the later
                // position, mirroring the engine's victim rule.
                let mut victim: Option<(f64, usize)> = None;
                for pos in start..start + size {
                    let j = plan.schedule.order[pos];
                    let job = &plan.jobs[j];
                    let exec = predictor
                        .predict(1, job.input_len, job.output_len)
                        .exec_ms;
                    let s = slack_key(slo_deadline_ms(&job.slo), exec);
                    let better = match victim {
                        None => true,
                        Some((vs, _)) => s >= vs,
                    };
                    if better {
                        victim = Some((s, pos));
                    }
                }
                let (_, pos) = victim.expect("overflowing batch is nonempty");
                let lj = plan.schedule.order[pos];
                let job = plan.jobs[lj];
                (pos, lj, job, sa.kv.job_blocks(job.input_len, job.output_len))
            };
            // Target: least-loaded peer whose whole wave still fits its
            // pool after absorbing the job (ties to the lowest index).
            let mut tgt: Option<(u64, usize)> = None;
            for j in 0..n {
                if j == src {
                    continue;
                }
                let l = load(&outcome.plans[j], &sa.kv);
                if l + need > pools[j] {
                    continue;
                }
                let better = match tgt {
                    None => true,
                    Some((bl, _)) => l < bl,
                };
                if better {
                    tgt = Some((l, j));
                }
            }
            let Some((_, tgt)) = tgt else { break };
            // Move: drop the victim from the source plan (its batch
            // shrinks in place; an emptied batch disappears) and append
            // it to the target as a trailing singleton batch.
            {
                let plan = &mut outcome.plans[src];
                let k = {
                    // batch containing `pos`
                    let mut k = 0;
                    let mut end = plan.schedule.batches[0];
                    while pos >= end {
                        k += 1;
                        end += plan.schedule.batches[k];
                    }
                    k
                };
                plan.schedule.order.remove(pos);
                plan.schedule.batches[k] -= 1;
                if plan.schedule.batches[k] == 0 {
                    plan.schedule.batches.remove(k);
                }
                plan.jobs.remove(lj);
                for o in plan.schedule.order.iter_mut() {
                    if *o > lj {
                        *o -= 1;
                    }
                }
            }
            {
                let plan = &mut outcome.plans[tgt];
                let nl = plan.jobs.len();
                plan.jobs.push(job);
                plan.schedule.order.push(nl);
                plan.schedule.batches.push(1);
            }
            stats.moved_jobs += 1;
            stats.moved_blocks += need;
            shed_any = true;
        }
        if shed_any {
            stats.source_instances += 1;
        }
    }
    stats
}

/// [`schedule`] followed by [`rebalance_overcommit`]: Algorithm 2 plus a
/// cross-instance decode-migration repair pass. [`schedule`] itself is
/// untouched — callers wanting the paper's independent per-instance plans
/// keep calling it — and with an unlimited or never-overcommitted pool
/// this wrapper returns the identical outcome with zeroed
/// [`MigrationStats`].
pub fn schedule_with_migration(
    requests: &[Request],
    predicted_out: &[usize],
    instances: &[InstanceInfo],
    predictor: &LatencyPredictor,
    mem: &MemoryModel,
    sa: &SaParams,
) -> Result<(ScheduleOutcome, MigrationStats)> {
    let mut outcome =
        schedule(requests, predicted_out, instances, predictor, mem, sa)?;
    let stats =
        rebalance_overcommit(&mut outcome, instances, predictor, mem, sa);
    Ok((outcome, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Slo, TaskType};
    use crate::util::prop::check;

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request::synthetic(
            id,
            TaskType::Code,
            input,
            output,
            Slo::E2e { e2e_ms: 30_000.0 },
        )
    }

    fn instances(n: usize, mem_mb: f64) -> Vec<InstanceInfo> {
        (0..n).map(|id| InstanceInfo { id, mem_mb }).collect()
    }

    #[test]
    fn assignment_balances_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, 100, 0)).collect();
        let outs = vec![0usize; 6];
        let asg =
            assign_instances(&reqs, &outs, &instances(2, 10_000.0), &mem, 16)
                .unwrap();
        // equal-size requests alternate between equal instances
        assert_eq!(asg[0].len(), 3);
        assert_eq!(asg[1].len(), 3);
    }

    #[test]
    fn assignment_prefers_larger_memory() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: 100.0 },
            InstanceInfo { id: 1, mem_mb: 10_000.0 },
        ];
        let asg = assign_instances(&reqs, &outs, &inst, &mem, 16).unwrap();
        // the big instance keeps winning until its remaining dips below
        assert!(asg[1].len() >= 3, "{asg:?}");
    }

    #[test]
    fn assignment_resets_when_full() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // each request needs 5 blocks; the instance holds 6 (100 tokens at
        // 16 tokens/block) -> the pool resets on every second request
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 80, 0)).collect();
        let outs = vec![0usize; 5];
        let asg = assign_instances(&reqs, &outs, &instances(1, 100.0), &mem, 16)
            .unwrap();
        assert_eq!(asg[0].len(), 5); // all still assigned (across waves)
    }

    #[test]
    fn assignment_rejects_request_larger_than_every_pool() {
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // 100-token pool (6 blocks); a 200-token request needs 13 blocks
        let reqs = vec![req(0, 150, 50)];
        let outs = vec![50usize];
        let err =
            assign_instances(&reqs, &outs, &instances(2, 100.0), &mem, 16)
                .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("KV footprint"), "unhelpful error: {msg}");
        assert!(msg.contains("13 blocks"), "unhelpful error: {msg}");
    }

    #[test]
    fn assignment_covers_all_requests() {
        check("assignment partitions requests", 100, |rng| {
            let n_req = 1 + rng.below(40);
            let n_inst = 1 + rng.below(4);
            let reqs: Vec<Request> = (0..n_req)
                .map(|i| {
                    req(i as u64, 1 + rng.below(2000), rng.below(500))
                })
                .collect();
            let outs: Vec<usize> =
                reqs.iter().map(|r| r.output_len).collect();
            let mem = MemoryModel::default();
            let asg = assign_instances(
                &reqs,
                &outs,
                &instances(n_inst, 16_000.0),
                &mem,
                16,
            )
            .map_err(|e| e.to_string())?;
            let mut seen = vec![false; n_req];
            for list in &asg {
                for &ri in list {
                    if seen[ri] {
                        return Err(format!("request {ri} assigned twice"));
                    }
                    seen[ri] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("request dropped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_survives_nan_capacity() {
        // a NaN pool converts to zero blocks (Eq. 20 derivation): the
        // broken instance must neither panic nor absorb the wave.
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 10, 0)).collect();
        let outs = vec![0usize; 4];
        let inst = vec![
            InstanceInfo { id: 0, mem_mb: f64::NAN },
            InstanceInfo { id: 1, mem_mb: 1_000.0 },
        ];
        assert_eq!(inst[0].pool_blocks(&mem, 16), 0);
        let asg = assign_instances(&reqs, &outs, &inst, &mem, 16).unwrap();
        assert_eq!(asg.iter().map(Vec::len).sum::<usize>(), 4);
        assert_eq!(asg[1].len(), 4, "{asg:?}");
    }

    #[test]
    fn schedule_produces_valid_plans() {
        let reqs: Vec<Request> = (0..12)
            .map(|i| req(i, 100 + 50 * i as usize, 20 + 10 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(3, 16_000.0),
            &predictor,
            &mem,
            &sa,
        )
        .unwrap();
        assert_eq!(outcome.plans.len(), 3);
        let mut all: Vec<usize> = Vec::new();
        for plan in &outcome.plans {
            plan.schedule.validate(4).unwrap();
            assert_eq!(plan.schedule.len(), plan.jobs.len());
            all.extend(plan.request_order());
        }
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert!(outcome.overhead_ms >= 0.0);
        assert!(outcome.cpu_ms >= 0.0);
        assert_eq!(outcome.seed, sa.seed); // reproducibility record
        // cpu time covers every instance's mapping; each one individually
        // can never exceed the total
        for plan in &outcome.plans {
            assert!(plan.stats.overhead_ms <= outcome.cpu_ms + 1e-9);
        }
    }

    #[test]
    fn parallel_mapping_is_deterministic() {
        let reqs: Vec<Request> = (0..16)
            .map(|i| req(i, 100 + 37 * i as usize, 10 + 9 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let a = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa)
            .unwrap();
        let b = schedule(&reqs, &outs, &instances(4, 16_000.0), &predictor, &mem, &sa)
            .unwrap();
        assert_eq!(a.plans.len(), b.plans.len());
        for (pa, pb) in a.plans.iter().zip(&b.plans) {
            assert_eq!(pa.instance, pb.instance);
            assert_eq!(pa.schedule, pb.schedule);
        }
    }

    #[test]
    fn single_instance_gets_everything() {
        let reqs: Vec<Request> = (0..5).map(|i| req(i, 100, 10)).collect();
        let outs = vec![10usize; 5];
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(1, 16_000.0),
            &LatencyPredictor::paper_table2(),
            &MemoryModel::default(),
            &SaParams::with_max_batch(2),
        )
        .unwrap();
        assert_eq!(outcome.plans[0].jobs.len(), 5);
    }

    #[test]
    fn hard_kv_schedule_binds_each_instance_to_its_pool() {
        use crate::coordinator::kv::{KvConfig, KvMode};
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        // 1024-token pools -> 64 blocks each; requests of ~200 tokens
        // (13 blocks) so a max_batch of 8 would overcommit (104 blocks)
        // without KV-aware search.
        let reqs: Vec<Request> =
            (0..12).map(|i| req(i, 150, 50)).collect();
        let outs = vec![50usize; 12];
        let kv = KvConfig::from_pool_mb(1024.0, &mem, 16, KvMode::Hard);
        assert_eq!(kv.pool_blocks, 64);
        let sa = SaParams { kv, ..SaParams::with_max_batch(8) };
        let outcome = schedule(
            &reqs,
            &outs,
            &instances(2, 1024.0),
            &LatencyPredictor::paper_table2(),
            &mem,
            &sa,
        )
        .unwrap();
        for plan in &outcome.plans {
            let ev = Evaluator::new(
                &plan.jobs,
                &LatencyPredictor::paper_table2(),
            );
            assert_eq!(
                ev.kv_excess(&plan.schedule, &kv),
                0,
                "instance {} overcommits: {:?}",
                plan.instance,
                plan.schedule
            );
        }
    }

    fn zero_stats() -> SearchStats {
        SearchStats {
            evals: 0,
            accepted: 0,
            improved: 0,
            early_exit: false,
            overhead_ms: 0.0,
            cpu_ms: 0.0,
            exchanges: 0,
            winner_chain: 0,
        }
    }

    /// A hand-built overcommitted wave: instance 0 plans one batch of
    /// three 4-block jobs (12 blocks on a 10-block pool — excess 2),
    /// instance 1 holds one 4-block job. Job deadlines differ, so the
    /// slack order is unambiguous.
    fn overcommitted_outcome() -> ScheduleOutcome {
        let job = |req_idx: usize, e2e_ms: f64| Job {
            req_idx,
            input_len: 48,
            output_len: 16, // 64 tokens = 4 blocks at 16 tokens/block
            slo: Slo::E2e { e2e_ms },
        };
        ScheduleOutcome {
            plans: vec![
                InstancePlan {
                    instance: 0,
                    jobs: vec![
                        job(0, 1_000.0),
                        job(1, 50_000.0), // most slack — the victim
                        job(2, 10_000.0),
                    ],
                    schedule: Schedule {
                        order: vec![0, 1, 2],
                        batches: vec![3],
                    },
                    stats: zero_stats(),
                },
                InstancePlan {
                    instance: 1,
                    jobs: vec![job(3, 5_000.0)],
                    schedule: Schedule { order: vec![0], batches: vec![1] },
                    stats: zero_stats(),
                },
            ],
            overhead_ms: 0.0,
            cpu_ms: 0.0,
            exchanges: 0,
            seed: 0,
        }
    }

    #[test]
    fn rebalance_moves_most_slack_job_and_clears_excess() {
        use crate::coordinator::kv::KvConfig;
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let sa =
            SaParams { kv: KvConfig::hard(10), ..SaParams::with_max_batch(4) };
        let inst = instances(2, 1_000_000.0);
        let mut outcome = overcommitted_outcome();
        let kv = sa.kv;
        let before = Evaluator::new(&outcome.plans[0].jobs, &predictor)
            .kv_excess(&outcome.plans[0].schedule, &kv);
        assert_eq!(before, 2, "scenario must overcommit by 2 blocks");
        let stats =
            rebalance_overcommit(&mut outcome, &inst, &predictor, &mem, &sa);
        assert_eq!(
            stats,
            MigrationStats {
                moved_jobs: 1,
                moved_blocks: 4,
                source_instances: 1
            }
        );
        // the loosest-deadline job moved; tighter deadlines stayed put
        let src_reqs: Vec<usize> = outcome.plans[0].request_order();
        assert_eq!(src_reqs, vec![0, 2]);
        let tgt_reqs: Vec<usize> = outcome.plans[1].request_order();
        assert_eq!(tgt_reqs, vec![3, 1]);
        // the migrated job lands as a trailing singleton batch
        assert_eq!(outcome.plans[1].schedule.batches, vec![1, 1]);
        // both plans are valid and overcommit-free afterwards
        for plan in &outcome.plans {
            plan.schedule.validate(4).unwrap();
            let ev = Evaluator::new(&plan.jobs, &predictor);
            assert_eq!(ev.kv_excess(&plan.schedule, &kv), 0);
        }
        // exactly-once across the fleet
        let mut all: Vec<usize> = outcome
            .plans
            .iter()
            .flat_map(|p| p.request_order())
            .collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
        // deterministic: a fresh copy makes identical choices
        let mut again = overcommitted_outcome();
        let stats2 =
            rebalance_overcommit(&mut again, &inst, &predictor, &mem, &sa);
        assert_eq!(stats, stats2);
        for (a, b) in outcome.plans.iter().zip(&again.plans) {
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(a.jobs, b.jobs);
        }
    }

    #[test]
    fn rebalance_keeps_residual_when_no_peer_has_headroom() {
        use crate::coordinator::kv::KvConfig;
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let sa =
            SaParams { kv: KvConfig::hard(10), ..SaParams::with_max_batch(4) };
        let inst = instances(2, 1_000_000.0);
        let mut outcome = overcommitted_outcome();
        // fill instance 1 so the 4-block victim cannot fit (load 8 + 4 > 10)
        let filler = Job {
            req_idx: 4,
            input_len: 48,
            output_len: 16,
            slo: Slo::E2e { e2e_ms: 5_000.0 },
        };
        outcome.plans[1].jobs.push(filler);
        outcome.plans[1].schedule.order.push(1);
        outcome.plans[1].schedule.batches.push(1);
        let stats =
            rebalance_overcommit(&mut outcome, &inst, &predictor, &mem, &sa);
        // nothing moved: the overcommit stays and is the engine
        // preemption layer's to absorb at execution time
        assert_eq!(stats, MigrationStats::default());
        assert_eq!(outcome.plans[0].jobs.len(), 3);
        assert_eq!(outcome.plans[1].jobs.len(), 2);
    }

    #[test]
    fn schedule_with_migration_is_identity_without_overcommit() {
        let reqs: Vec<Request> = (0..12)
            .map(|i| req(i, 100 + 50 * i as usize, 20 + 10 * i as usize))
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel::default();
        let sa = SaParams::with_max_batch(4);
        let plain = schedule(
            &reqs,
            &outs,
            &instances(3, 16_000.0),
            &predictor,
            &mem,
            &sa,
        )
        .unwrap();
        let (migrated, stats) = schedule_with_migration(
            &reqs,
            &outs,
            &instances(3, 16_000.0),
            &predictor,
            &mem,
            &sa,
        )
        .unwrap();
        // unlimited pool: the repair pass is a guaranteed no-op
        assert_eq!(stats, MigrationStats::default());
        assert_eq!(plain.plans.len(), migrated.plans.len());
        for (a, b) in plain.plans.iter().zip(&migrated.plans) {
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(a.jobs, b.jobs);
        }
    }

    #[test]
    fn single_instance_never_migrates() {
        use crate::coordinator::kv::KvConfig;
        let predictor = LatencyPredictor::paper_table2();
        let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
        let sa =
            SaParams { kv: KvConfig::hard(10), ..SaParams::with_max_batch(4) };
        let inst = instances(1, 1_000_000.0);
        let mut outcome = overcommitted_outcome();
        outcome.plans.truncate(1);
        let stats =
            rebalance_overcommit(&mut outcome, &inst, &predictor, &mem, &sa);
        assert_eq!(stats, MigrationStats::default());
        assert_eq!(outcome.plans[0].jobs.len(), 3, "plan left untouched");
    }
}
