//! JSON-lines wire protocol: request parsing + completion serialization.

use anyhow::{anyhow, Result};

use crate::coordinator::request::{Completion, Request, Slo, TaskType};
use crate::util::json::Json;

/// Parse a `{"op":"generate", …}` message into a [`Request`].
///
/// Either `prompt` (text; its byte length is the input length) or
/// `input_len` (synthetic prompt) must be present. `slo` defaults per task
/// type when omitted (chat → interactive 10 s / 50 ms; code → e2e 30 s).
pub fn parse_generate(
    msg: &Json,
    id: u64,
    max_total_tokens: usize,
) -> Result<Request> {
    let task = match msg.get("task").as_str() {
        Some(name) => TaskType::from_name(name)
            .ok_or_else(|| anyhow!("unknown task '{name}'"))?,
        None => TaskType::Chat,
    };
    let prompt: Option<Vec<u8>> =
        msg.get("prompt").as_str().map(|s| s.as_bytes().to_vec());
    let input_len = match (&prompt, msg.get("input_len").as_usize()) {
        (Some(p), _) => p.len(),
        (None, Some(n)) => n,
        (None, None) => {
            return Err(anyhow!("generate needs 'prompt' or 'input_len'"))
        }
    };
    if input_len == 0 {
        return Err(anyhow!("empty prompt"));
    }
    let max_tokens = msg.get("max_tokens").as_usize().unwrap_or(32).max(1);
    if input_len + max_tokens > max_total_tokens {
        return Err(anyhow!(
            "input_len {input_len} + max_tokens {max_tokens} exceeds cap {max_total_tokens}"
        ));
    }
    let slo = match Slo::from_json(&msg.get("slo")) {
        Some(s) => s,
        None => match task {
            TaskType::Code => Slo::E2e { e2e_ms: 30_000.0 },
            _ => Slo::Interactive { ttft_ms: 10_000.0, tpot_ms: 50.0 },
        },
    };
    Ok(Request {
        id,
        task,
        input_len,
        output_len: max_tokens,
        slo,
        arrival_ms: crate::util::now_ms(),
        prompt,
    })
}

/// Serialize a completion into the reply object.
pub fn completion_to_json(c: &Completion) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(c.id as f64)),
        ("task", Json::str(c.task.name())),
        ("generated", Json::num(c.generated as f64)),
        ("e2e_ms", Json::num(c.e2e_ms)),
        ("ttft_ms", Json::num(c.ttft_ms)),
        ("tpot_ms", Json::num(c.tpot_ms)),
        ("wait_ms", Json::num(c.wait_ms)),
        ("batch_size", Json::num(c.batch_size as f64)),
        ("slo_met", Json::Bool(c.slo_met())),
    ];
    if let Some(text) = &c.text {
        fields.push(("text", Json::str(String::from_utf8_lossy(text))));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate_with_prompt() {
        let msg = Json::parse(
            r#"{"op":"generate","task":"code","prompt":"def f():","max_tokens":16}"#,
        )
        .unwrap();
        let r = parse_generate(&msg, 5, 380).unwrap();
        assert_eq!(r.id, 5);
        assert_eq!(r.task, TaskType::Code);
        assert_eq!(r.input_len, 8);
        assert_eq!(r.output_len, 16);
        assert!(r.slo.prioritizes_e2e()); // code default SLO
        assert_eq!(r.prompt.as_deref(), Some(b"def f():".as_ref()));
    }

    #[test]
    fn parse_generate_with_input_len_and_slo() {
        let msg = Json::parse(
            r#"{"op":"generate","task":"chat","input_len":100,"max_tokens":8,
                "slo":{"kind":"interactive","ttft_ms":500,"tpot_ms":20}}"#,
        )
        .unwrap();
        let r = parse_generate(&msg, 0, 380).unwrap();
        assert_eq!(r.input_len, 100);
        assert_eq!(
            r.slo,
            Slo::Interactive { ttft_ms: 500.0, tpot_ms: 20.0 }
        );
        assert!(r.prompt.is_none());
    }

    #[test]
    fn parse_generate_rejects_bad_input() {
        let over = Json::parse(
            r#"{"op":"generate","input_len":350,"max_tokens":50}"#,
        )
        .unwrap();
        assert!(parse_generate(&over, 0, 380).is_err());
        let none = Json::parse(r#"{"op":"generate"}"#).unwrap();
        assert!(parse_generate(&none, 0, 380).is_err());
        let bad_task =
            Json::parse(r#"{"op":"generate","task":"x","input_len":5}"#)
                .unwrap();
        assert!(parse_generate(&bad_task, 0, 380).is_err());
    }

    #[test]
    fn completion_roundtrips_to_json() {
        let c = Completion {
            id: 9,
            task: TaskType::Chat,
            slo: Slo::Interactive { ttft_ms: 100.0, tpot_ms: 10.0 },
            input_len: 20,
            predicted_lo: 4,
            generated: 4,
            e2e_ms: 50.0,
            ttft_ms: 30.0,
            tpot_ms: 5.0,
            wait_ms: 2.0,
            batch_size: 2,
            text: Some(b"hello".to_vec()),
        };
        let v = completion_to_json(&c);
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("id").as_i64(), Some(9));
        assert_eq!(v.get("slo_met"), &Json::Bool(true));
        assert_eq!(v.get("text").as_str(), Some("hello"));
        // parseable end-to-end
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
