//! Online admission integration tests (ISSUE 2 acceptance):
//!
//! * **online-equals-offline** — when every request arrives at t = 0 and
//!   nothing is frozen, the online controller must reproduce the
//!   closed-wave `schedule` bit for bit (plan, objective, and executed
//!   completions).
//! * **frozen-prefix invariant** — no replan ever reorders dispatched
//!   jobs, across random traces, admission chunkings, and strategies.
//! * **determinism** — equal seeds reproduce an online run exactly, on
//!   generated traces (Poisson / ON-OFF / class mixes).

use slo_serve::config::profiles::by_name;
use slo_serve::config::{OutputPrediction, SloTargets};
use slo_serve::coordinator::objective::{Evaluator, Job};
use slo_serve::coordinator::online::{
    run_online, ReplanStrategy, WaveController,
};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::profiler::{MemoryModel, RequestProfiler};
use slo_serve::coordinator::request::Request;
use slo_serve::coordinator::scheduler::{instance_seed, schedule, InstanceInfo};
use slo_serve::coordinator::{execute_plans, predict_outputs};
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::Engine;
use slo_serve::util::prop::check;
use slo_serve::util::rng::Rng;
use slo_serve::workload::dataset::RequestFactory;
use slo_serve::workload::trace::{ArrivalProcess, ClassMix, TraceSpec};

fn paper_predictor() -> slo_serve::coordinator::predictor::LatencyPredictor {
    slo_serve::coordinator::predictor::LatencyPredictor::paper_table2()
}

fn t0_wave(n: usize, seed: u64) -> (Vec<Request>, Vec<usize>) {
    let mut factory =
        RequestFactory::new(seed, SloTargets::default().scaled(0.5));
    let mut reqs = factory.mixed_wave(n);
    let mut rng = Rng::new(seed);
    ArrivalProcess::Concurrent.apply(&mut reqs, &mut rng);
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    (reqs, outs)
}

/// Acceptance: t = 0 arrivals, empty frozen prefix → bit-identical plan
/// and objective to the closed-wave `schedule` (single instance).
#[test]
fn online_equals_offline_for_t0_arrivals() {
    let predictor = paper_predictor();
    for seed in [0u64, 7, 42] {
        let (reqs, outs) = t0_wave(14, seed);
        let sa = SaParams { max_batch: 4, seed, ..Default::default() };

        let offline = schedule(
            &reqs,
            &outs,
            &[InstanceInfo { id: 0, mem_mb: 1e9 }],
            &predictor,
            &MemoryModel::default(),
            &sa,
        )
        .unwrap();
        assert_eq!(offline.seed, seed);
        let off_plan = &offline.plans[0];

        // The controller plays instance 0 of the fleet: same derived seed.
        let online_params =
            SaParams { seed: instance_seed(sa.seed, 0), ..sa };
        let mut ctl = WaveController::new(
            &predictor,
            online_params,
            ReplanStrategy::Warm,
        );
        let jobs: Vec<Job> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| Job::from_request(i, r, outs[i]))
            .collect();
        ctl.admit(&jobs).unwrap();

        assert_eq!(
            ctl.plan(),
            &off_plan.schedule,
            "seed {seed}: online plan differs from closed-wave schedule"
        );
        let ev = Evaluator::new(&jobs, &predictor);
        let full = ev.eval(ctl.plan());
        assert_eq!(
            ctl.eval().g.to_bits(),
            full.g.to_bits(),
            "seed {seed}: objective not bit-identical"
        );
        assert_eq!(ctl.eval().met, full.met);
        assert_eq!(ctl.frozen_batches(), 0);
    }
}

/// Timeline escape hatch (ISSUE 4 acceptance): with every arrival at
/// t = 0 and `KvPhaseModel::Reserve`, the arrival-aware controller is
/// bit-identical to the legacy (pre-timeline) admission — plans,
/// objective bits, and executed completions — which in turn equals the
/// closed-wave `schedule` (`online_equals_offline_for_t0_arrivals`).
#[test]
fn arrival_aware_equals_legacy_at_t0() {
    use slo_serve::coordinator::online::{run_online_opts, OnlineOpts};
    let predictor = paper_predictor();
    for seed in [1u64, 13] {
        let (reqs, outs) = t0_wave(13, seed);
        let sa = SaParams { max_batch: 4, seed, ..Default::default() };

        // controller level: admit vs admit_at(zeros) — same plan bits
        let online_params = SaParams { seed: instance_seed(sa.seed, 0), ..sa };
        let jobs: Vec<Job> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| Job::from_request(i, r, outs[i]))
            .collect();
        let mut legacy =
            WaveController::new(&predictor, online_params, ReplanStrategy::Warm);
        legacy.admit(&jobs).unwrap();
        let mut aware =
            WaveController::new(&predictor, online_params, ReplanStrategy::Warm);
        let zeros: Vec<f64> = reqs.iter().map(|r| r.arrival_ms).collect();
        assert!(zeros.iter().all(|&a| a == 0.0));
        aware.admit_at(&jobs, &zeros).unwrap();
        assert_eq!(legacy.plan(), aware.plan(), "seed {seed}");
        assert_eq!(
            legacy.eval().g.to_bits(),
            aware.eval().g.to_bits(),
            "seed {seed}"
        );

        // event-loop level: executed completions are bit-identical
        let run = |arrival_aware: bool| {
            let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
            profile.noise_std = 0.0;
            let mut engine = SimEngine::new(profile, 4, 0);
            run_online_opts(
                &reqs,
                &outs,
                &mut engine,
                &predictor,
                &SaParams { seed: instance_seed(sa.seed, 0), ..sa },
                ReplanStrategy::Warm,
                OnlineOpts { arrival_aware, ..Default::default() },
            )
            .unwrap()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits(), "seed {seed}");
            assert_eq!(x.ttft_ms.to_bits(), y.ttft_ms.to_bits());
            assert_eq!(x.batch_size, y.batch_size);
        }
        // the predicted timelines agree bit for bit too
        assert_eq!(a.predicted.len(), b.predicted.len());
        for (x, y) in a.predicted.iter().zip(&b.predicted) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.wait_ms.to_bits(), y.wait_ms.to_bits());
            assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits());
        }
    }
}

/// The executed path agrees too: running the t = 0 trace through the
/// online event loop produces the same completions as executing the
/// closed-wave plan on an identical engine.
#[test]
fn online_execution_matches_offline_execution_at_t0() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0; // timing must match exactly
    let predictor = paper_predictor();
    let (reqs, outs) = t0_wave(12, 3);
    let sa = SaParams { max_batch: 4, seed: 5, ..Default::default() };

    let offline = schedule(
        &reqs,
        &outs,
        &[InstanceInfo { id: 0, mem_mb: 1e9 }],
        &predictor,
        &MemoryModel::default(),
        &sa,
    )
    .unwrap();
    let mut engines: Vec<Box<dyn Engine + Send>> =
        vec![Box::new(SimEngine::new(profile.clone(), 4, 0))];
    let mut profiler = RequestProfiler::new();
    let offline_completions =
        execute_plans(&reqs, &offline.plans, &mut engines, &mut profiler)
            .unwrap();

    let mut engine = SimEngine::new(profile, 4, 0);
    let online = run_online(
        &reqs,
        &outs,
        &mut engine,
        &predictor,
        &SaParams { seed: instance_seed(sa.seed, 0), ..sa },
        ReplanStrategy::Warm,
    )
    .unwrap();

    assert_eq!(online.completions.len(), offline_completions.len());
    for (a, b) in online.completions.iter().zip(&offline_completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits(), "id {}", a.id);
        assert_eq!(a.ttft_ms.to_bits(), b.ttft_ms.to_bits());
        assert_eq!(a.batch_size, b.batch_size);
    }
    assert_eq!(online.stats.replans, 1); // one admission, nothing frozen
}

/// Acceptance: no replan — warm or cold — ever reorders dispatched jobs,
/// and every admitted job is dispatched exactly once.
#[test]
fn frozen_prefix_is_never_reordered() {
    let predictor = paper_predictor();
    check("dispatched prefix survives every replan", 30, |rng| {
        let strategy = if rng.chance(0.5) {
            ReplanStrategy::Warm
        } else {
            ReplanStrategy::Cold
        };
        let max_batch = 1 + rng.below(4);
        let params = SaParams {
            max_batch,
            seed: rng.next_u64(),
            t0: 100.0,
            iters_per_temp: 15,
            ..Default::default()
        };
        let mut ctl = WaveController::new(&predictor, params, strategy);
        let mut dispatched: Vec<usize> = Vec::new();
        let mut admitted = 0usize;
        for _ in 0..5 {
            let fresh_n = 1 + rng.below(6);
            let fresh: Vec<Job> = (admitted..admitted + fresh_n)
                .map(|i| Job {
                    req_idx: i,
                    input_len: 1 + rng.below(1500),
                    output_len: 1 + rng.below(400),
                    slo: slo_serve::coordinator::request::Slo::E2e {
                        e2e_ms: rng.uniform(500.0, 30_000.0),
                    },
                })
                .collect();
            admitted += fresh_n;
            ctl.admit(&fresh).map_err(|e| e.to_string())?;
            ctl.plan()
                .validate(max_batch)
                .map_err(|e| format!("invalid plan after admit: {e}"))?;
            // the already-dispatched jobs must sit untouched at the head
            let fp = ctl.frozen_positions();
            if fp != dispatched.len() {
                return Err(format!(
                    "frozen positions {fp} != dispatched {}",
                    dispatched.len()
                ));
            }
            let head: Vec<usize> = ctl.plan().order[..fp]
                .iter()
                .map(|&j| ctl.jobs()[j].req_idx)
                .collect();
            if head != dispatched {
                return Err(format!(
                    "dispatched prefix reordered: {head:?} != {dispatched:?}"
                ));
            }
            // dispatch a random number of ready batches
            for _ in 0..rng.below(3) {
                if let Some(d) = ctl.dispatch_next() {
                    dispatched.extend(d.jobs.iter().map(|j| j.req_idx));
                }
            }
        }
        while let Some(d) = ctl.dispatch_next() {
            dispatched.extend(d.jobs.iter().map(|j| j.req_idx));
        }
        let mut sorted = dispatched.clone();
        sorted.sort_unstable();
        if sorted != (0..admitted).collect::<Vec<_>>() {
            return Err(format!(
                "dispatch is not a permutation of admissions: {sorted:?}"
            ));
        }
        Ok(())
    });
}

/// Equal seeds reproduce a full online run — trace generation included —
/// bit for bit; different seeds diverge.
#[test]
fn online_runs_are_reproducible_per_seed() {
    let run = |seed: u64| {
        let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
        profile.noise_std = 0.0;
        let predictor = paper_predictor();
        let mut factory =
            RequestFactory::new(seed, SloTargets::default().scaled(0.6));
        let mut trace_rng = Rng::new(seed ^ 0x0411_13E);
        let trace = ClassMix::chat_code(
            24,
            ArrivalProcess::Poisson { rps: 10.0 },
            ArrivalProcess::OnOff { rps: 30.0, on_ms: 500.0, off_ms: 1000.0 },
        )
        .generate(&mut factory, &mut trace_rng);
        let profiler = RequestProfiler::new();
        let mut pred_rng = Rng::new(seed);
        let outs = predict_outputs(
            &trace,
            &profiler,
            OutputPrediction::Oracle { rel_err: 0.0 },
            &mut pred_rng,
            2000,
        );
        let mut engine = SimEngine::new(profile, 4, seed);
        let out = run_online(
            &trace,
            &outs,
            &mut engine,
            &predictor,
            &SaParams { max_batch: 4, seed, ..Default::default() },
            ReplanStrategy::Warm,
        )
        .unwrap();
        (
            out.completions
                .iter()
                .map(|c| (c.id, c.e2e_ms.to_bits()))
                .collect::<Vec<_>>(),
            out.stats.replans,
            out.seed,
        )
    };
    let a = run(9);
    let b = run(9);
    assert_eq!(a, b);
    assert_eq!(a.2, 9);
    let c = run(10);
    assert_ne!(a.0, c.0);
}

/// The Poisson-trace warm/cold comparison the example reports: both
/// strategies serve everything; warm replans never land below their own
/// warm seed (the structural guarantee behind "warm ≥ cold seeds").
#[test]
fn poisson_trace_served_under_both_strategies() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    let predictor = paper_predictor();
    let mut factory =
        RequestFactory::new(21, SloTargets::default().scaled(0.5));
    let mut trace_rng = Rng::new(21);
    let trace = TraceSpec {
        n: 32,
        arrivals: ArrivalProcess::Poisson { rps: 12.0 },
    }
    .generate(&mut factory, &mut trace_rng);
    let profiler = RequestProfiler::new();
    let mut pred_rng = Rng::new(21);
    let outs = predict_outputs(
        &trace,
        &profiler,
        OutputPrediction::Oracle { rel_err: 0.0 },
        &mut pred_rng,
        2000,
    );
    for strategy in [ReplanStrategy::Warm, ReplanStrategy::Cold] {
        let mut engine = SimEngine::new(profile.clone(), 4, 21);
        let out = run_online(
            &trace,
            &outs,
            &mut engine,
            &predictor,
            &SaParams { max_batch: 4, seed: 21, ..Default::default() },
            strategy,
        )
        .unwrap();
        assert_eq!(out.completions.len(), 32, "{strategy:?}");
        assert!(out.stats.replans >= 2, "{strategy:?}: {:?}", out.stats);
        assert!(out.stats.replan_ms_total >= 0.0);
        assert_eq!(out.stats.dispatched_jobs, 32);
    }
}
