use slo_serve::engine::real::RealEngine;
use slo_serve::engine::{Engine, EngineRequest};

fn main() -> anyhow::Result<()> {
    let mut e = RealEngine::load(&std::env::var("ARTS").unwrap_or("artifacts".into()))?;
    e.warmup(4)?;
    let batch: Vec<EngineRequest> = (0..4)
        .map(|i| EngineRequest { id: i, input_len: 64, max_new_tokens: 24, prompt: None })
        .collect();
    let _ = e.run_batch(&batch)?; // warm
    let steps0 = e.decode_steps;
    let exec0 = e.execute_ms;
    let t0 = std::time::Instant::now();
    let _ = e.run_batch(&batch)?;
    let wall = t0.elapsed().as_secs_f64() * 1e3;
    let steps = e.decode_steps - steps0;
    let exec = e.execute_ms - exec0;
    println!("wall {wall:.1} ms | {} steps | execute (incl. literal io) {exec:.1} ms ({:.1}/step) | host-side {:.1} ms",
             steps, exec / steps as f64, wall - exec);
    Ok(())
}
