//! Async streaming serving front end.
//!
//! Architecture (replaces the former thread-per-connection server — see
//! `docs/ARCHITECTURE.md` §server):
//!
//! ```text
//!            ┌───────────────────────────── FrontDoor ──────────────┐
//!  clients ─►│ validate ─► session_shard ─► bounded queue (per shard)│
//!  (submit)  │        429 + retry_after when every queue is full     │
//!            └───────┬───────────────┬──────────────────────────────┘
//!                    ▼               ▼
//!              [shard worker 0] [shard worker N-1]   (threads)
//!              WaveController + engine each; admit/defer, dispatch,
//!              reconcile — run_online's loop on a live clock
//!                    │               │
//!                    └── StreamEvent channels back to the clients:
//!                        Admitted → Token* → Done/Failed
//! ```
//!
//! * [`front`]      — the sharded admission door: bounded MPSC queues,
//!   consistent-hash routing, cross-shard handoff, 429 backpressure, and
//!   the synchronous [`front::serve_trace`] replay (invariant 12's
//!   escape hatch).
//! * [`shard`]      — the per-shard worker loop (controller + engine).
//! * [`tcp`]        — single-threaded non-blocking reactor speaking the
//!   JSON-lines protocol, streaming frames per decode step.
//! * [`protocol`]   — wire parsing + reply/stream frame serialization.
//! * [`bench_http`] — the in-process open-loop load generator behind
//!   `slo-serve bench-http` (CI's serving smoke gate).
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"generate","task":"chat","input_len":120,"max_tokens":40,
//!     "session":7,"stream":true,
//!     "slo":{"kind":"interactive","ttft_ms":10000,"tpot_ms":50}}
//! <- {"ok":true,"event":"admitted","id":3,"shard":1,"queue_ms":0.4}
//! <- {"ok":true,"event":"token","id":3,"index":0,"t_ms":812.5}
//! <- …
//! <- {"ok":true,"event":"done","id":3,"generated":40,"e2e_ms":912.0,…}
//! -> {"op":"generate","input_len":64}          (no "stream")
//! <- {"ok":true,"id":4,"generated":32,…}       (single completion line)
//! <- {"ok":false,"code":429,"error":"saturated","retry_after_ms":180}
//! -> {"op":"stats"}   ·   {"op":"shutdown"}
//! ```

pub mod bench_http;
pub mod front;
pub mod protocol;
pub mod shard;
pub mod tcp;

pub use front::{
    serve_trace, session_shard, shard_seed, FrontDoor, FrontDoorConfig,
    StreamEvent, StreamHandle, SubmitError, TryNext,
};
pub use shard::{ShardMetrics, ShardShared};
pub use tcp::{serve_tcp, Client, TcpServer};
