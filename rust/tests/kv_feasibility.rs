//! KV-aware batching acceptance tests (ISSUE 3):
//!
//! * **unlimited-pool bit-identity** — with a `u64::MAX` pool (any mode)
//!   the search draws the pre-KV RNG stream: plans, evaluations, and
//!   search stats are identical across `Unlimited`, `Hard`, and `Soft`,
//!   and `schedule` outcomes match batch for batch.
//! * **oversize hard-fail** — a single job larger than every pool fails
//!   loudly at instance assignment, online admission, and the engine.
//! * **exact-fit boundary** — a batch occupying exactly the pool is
//!   feasible; one block less flips it to excess 1.
//! * **constrained pool end-to-end** — where the pre-KV path plans a
//!   batch the engine refuses (KV overcommit), the hard-mode scheduler
//!   produces a feasible plan that executes within the block pool.

use slo_serve::config::profiles::by_name;
use slo_serve::coordinator::execute_plans;
use slo_serve::coordinator::kv::{KvConfig, KvMode, KvPhaseModel};
use slo_serve::coordinator::objective::{Evaluator, Job, Schedule};
use slo_serve::coordinator::online::{ReplanStrategy, WaveController};
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{priority_mapping, SaParams};
use slo_serve::coordinator::profiler::{MemoryModel, RequestProfiler};
use slo_serve::coordinator::request::{Request, Slo, TaskType};
use slo_serve::coordinator::scheduler::{schedule, InstanceInfo};
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::Engine;
use slo_serve::util::rng::Rng;

fn random_jobs(rng: &mut Rng, n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            req_idx: i,
            input_len: 1 + rng.below(1500),
            output_len: 1 + rng.below(400),
            slo: Slo::E2e { e2e_ms: rng.uniform(1_000.0, 30_000.0) },
        })
        .collect()
}

/// Acceptance: `pool_blocks = u64::MAX` under every mode draws the exact
/// RNG stream of the pre-KV search — trajectories and results are
/// bit-identical to the `Unlimited` (legacy) configuration.
#[test]
fn unlimited_pool_is_bit_identical_across_modes() {
    let pred = LatencyPredictor::paper_table2();
    for seed in [0u64, 3, 11] {
        let mut rng = Rng::new(seed ^ 0x77AA);
        let jobs = random_jobs(&mut rng, 15);
        let ev = Evaluator::new(&jobs, &pred);
        let base = SaParams {
            max_batch: 4,
            seed,
            t0: 200.0,
            iters_per_temp: 30,
            ..Default::default()
        };
        let legacy = priority_mapping(&ev, &base);
        for kv in [
            KvConfig { pool_blocks: u64::MAX, ..KvConfig::hard(0) },
            KvConfig { pool_blocks: u64::MAX, ..KvConfig::soft(0, 123.0) },
            // phased demand with an unlimited pool never binds either:
            // same RNG stream, same plan, same stats
            KvConfig { pool_blocks: u64::MAX, ..KvConfig::hard(0) }
                .with_phase(KvPhaseModel::Phased),
        ] {
            let res = priority_mapping(&ev, &SaParams { kv, ..base });
            assert_eq!(res.schedule, legacy.schedule, "seed {seed} {kv:?}");
            assert_eq!(
                res.eval.g.to_bits(),
                legacy.eval.g.to_bits(),
                "seed {seed} {kv:?}: objective not bit-identical"
            );
            assert_eq!(res.stats.evals, legacy.stats.evals, "seed {seed}");
            assert_eq!(res.stats.accepted, legacy.stats.accepted, "seed {seed}");
            assert_eq!(res.stats.improved, legacy.stats.improved, "seed {seed}");
        }
    }
}

/// The multi-instance outcome is equally unchanged: `ScheduleOutcome`
/// plans under an infinite hard pool equal the legacy configuration's,
/// batch partition included.
#[test]
fn unlimited_pool_schedule_outcome_matches_legacy() {
    let pred = LatencyPredictor::paper_table2();
    let reqs: Vec<Request> = (0..14)
        .map(|i| {
            Request::synthetic(
                i as u64,
                TaskType::Code,
                100 + 40 * i as usize,
                10 + 7 * i as usize,
                Slo::E2e { e2e_ms: 30_000.0 },
            )
        })
        .collect();
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    let instances: Vec<InstanceInfo> = (0..3)
        .map(|id| InstanceInfo { id, mem_mb: 16_000.0 })
        .collect();
    let mem = MemoryModel::default();
    let base = SaParams::with_max_batch(4);
    let legacy =
        schedule(&reqs, &outs, &instances, &pred, &mem, &base).unwrap();
    let infinite_hard = SaParams {
        kv: KvConfig { pool_blocks: u64::MAX, ..KvConfig::hard(0) },
        ..base
    };
    let kvd =
        schedule(&reqs, &outs, &instances, &pred, &mem, &infinite_hard)
            .unwrap();
    assert_eq!(legacy.plans.len(), kvd.plans.len());
    assert_eq!(legacy.seed, kvd.seed);
    for (a, b) in legacy.plans.iter().zip(&kvd.plans) {
        assert_eq!(a.instance, b.instance);
        assert_eq!(a.schedule, b.schedule, "instance {}", a.instance);
        assert_eq!(a.request_order(), b.request_order());
    }
}

/// ISSUE 4 escape hatch: `KvPhaseModel::Reserve` (the default) with
/// explicit zero arrivals replays the pre-timeline, pre-phase scheduler
/// byte for byte — `ScheduleOutcome` plans, objective bits, seed, and
/// search stats all equal the plain configuration's.
#[test]
fn reserve_mode_t0_schedule_outcome_is_byte_equal_to_pre_timeline() {
    let pred = LatencyPredictor::paper_table2();
    for seed in [0u64, 5, 21] {
        let mut rng = Rng::new(seed ^ 0x1EAF);
        let jobs = random_jobs(&mut rng, 14);
        // search level: a timeline evaluator with all-zero arrivals and
        // t0 = 0 must walk the identical trajectory
        let zeros = vec![0.0; jobs.len()];
        let plain = Evaluator::new(&jobs, &pred);
        let timeline = Evaluator::with_arrivals(&jobs, &pred, 0.0, &zeros);
        let p = SaParams {
            max_batch: 4,
            seed,
            t0: 150.0,
            iters_per_temp: 25,
            // Reserve is the default phase; every job fits the pool alone
            kv: KvConfig::hard(128),
            ..Default::default()
        };
        let a = priority_mapping(&plain, &p);
        let b = priority_mapping(&timeline, &p);
        assert_eq!(a.schedule, b.schedule, "seed {seed}");
        assert_eq!(a.eval.g.to_bits(), b.eval.g.to_bits(), "seed {seed}");
        assert_eq!(
            a.eval.total_e2e_ms.to_bits(),
            b.eval.total_e2e_ms.to_bits(),
            "seed {seed}"
        );
        assert_eq!(a.stats.evals, b.stats.evals, "seed {seed}");
        assert_eq!(a.stats.accepted, b.stats.accepted, "seed {seed}");
        assert_eq!(a.stats.improved, b.stats.improved, "seed {seed}");

        // scheduler level: the full Algorithm 2 outcome (t = 0 requests)
        // is equal plan for plan, seed included
        let reqs: Vec<Request> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| {
                Request::synthetic(
                    i as u64,
                    TaskType::Code,
                    j.input_len,
                    j.output_len,
                    j.slo,
                )
            })
            .collect();
        let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
        let instances: Vec<InstanceInfo> = (0..2)
            .map(|id| InstanceInfo { id, mem_mb: 16_000.0 })
            .collect();
        let mem = MemoryModel::default();
        let x = schedule(&reqs, &outs, &instances, &pred, &mem, &p).unwrap();
        let y = schedule(&reqs, &outs, &instances, &pred, &mem, &p).unwrap();
        assert_eq!(x.seed, y.seed);
        for (pa, pb) in x.plans.iter().zip(&y.plans) {
            assert_eq!(pa.schedule, pb.schedule);
            assert_eq!(pa.request_order(), pb.request_order());
        }
    }
}

/// Acceptance: with staggered output lengths, the phased demand model
/// legally forms batches the reserve model must refuse — and the phased
/// engine executes them within the same physical pool.
#[test]
fn phased_mode_batches_beyond_reserve_and_executes() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    profile.kv_pool_mb = 200.0; // engine: 400 tokens -> 25 blocks
    let pred = profile.truth;
    // job A: 160 in / 4 out (11 blocks full), job B: 160 in / 160 out
    // (20 blocks): reserve demand 31 > 25, phased peak 22 <= 25.
    let reqs = vec![
        Request::synthetic(0, TaskType::Code, 160, 4, Slo::E2e { e2e_ms: 1e12 }),
        Request::synthetic(1, TaskType::Code, 160, 160, Slo::E2e { e2e_ms: 1e12 }),
    ];
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    let jobs: Vec<Job> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| Job::from_request(i, r, outs[i]))
        .collect();
    let ev = Evaluator::new(&jobs, &pred);
    let both = Schedule { order: vec![0, 1], batches: vec![2] };
    let reserve = KvConfig::hard(25);
    let phased = reserve.with_phase(KvPhaseModel::Phased);
    // demand models disagree on the same batch
    assert_eq!(ev.kv_excess(&both, &reserve), 6);
    assert_eq!(ev.kv_excess(&both, &phased), 0);
    // the phased hard search may (and here, seeded trivially, does)
    // return the merged batch: loose SLOs -> sorted seed early-exits
    let res = priority_mapping(
        &ev,
        &SaParams { kv: phased, ..SaParams::with_max_batch(2) },
    );
    assert_eq!(res.schedule.batches, vec![2], "{:?}", res.schedule);
    // and the phased engine executes it within the 25-block pool
    let mut engine = SimEngine::new(profile, 2, 0)
        .with_kv_phase(KvPhaseModel::Phased);
    let batch: Vec<slo_serve::engine::EngineRequest> = res
        .schedule
        .order
        .iter()
        .map(|&j| slo_serve::engine::EngineRequest {
            id: reqs[jobs[j].req_idx].id,
            input_len: reqs[jobs[j].req_idx].input_len,
            max_new_tokens: reqs[jobs[j].req_idx].output_len,
            prompt: None,
        })
        .collect();
    engine.run_batch(&batch).unwrap();
    assert_eq!(engine.peak_used_blocks(), 22);
    assert_eq!(engine.kv().active_seqs(), 0);
}

/// Acceptance: a single job larger than the pool hard-fails with a clear
/// error at every layer that could otherwise plan a fiction.
#[test]
fn oversize_job_fails_loudly_everywhere() {
    let pred = LatencyPredictor::paper_table2();
    let mem = MemoryModel { utility: 1.0, mb_per_token: 1.0 };
    // 100-token instance pools (6 blocks); the request needs 750 tokens.
    let reqs = vec![Request::synthetic(
        9,
        TaskType::Code,
        700,
        50,
        Slo::E2e { e2e_ms: 1e9 },
    )];
    let outs = vec![50usize];
    let instances: Vec<InstanceInfo> =
        (0..2).map(|id| InstanceInfo { id, mem_mb: 100.0 }).collect();
    // scheduler: instance assignment refuses
    let err = schedule(
        &reqs,
        &outs,
        &instances,
        &pred,
        &mem,
        &SaParams::with_max_batch(4),
    )
    .unwrap_err();
    assert!(format!("{err}").contains("KV footprint"), "{err}");

    // online admission: the controller refuses
    let p = SaParams {
        kv: KvConfig::from_pool_mb(100.0, &mem, 16, KvMode::Hard),
        ..SaParams::with_max_batch(4)
    };
    let mut ctl = WaveController::new(&pred, p, ReplanStrategy::Warm);
    let job = Job::from_request(0, &reqs[0], outs[0]);
    let err = ctl.admit(&[job]).unwrap_err();
    assert!(format!("{err}").contains("KV blocks"), "{err}");

    // engine: the allocator-backed pre-check refuses
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    profile.kv_pool_mb = 100.0; // 200 tokens -> 12 blocks
    let mut engine = SimEngine::new(profile, 4, 0);
    let err = engine
        .run_batch(&[slo_serve::engine::EngineRequest {
            id: 9,
            input_len: 700,
            max_new_tokens: 50,
            prompt: None,
        }])
        .unwrap_err();
    assert!(
        format!("{err}").contains("overcommits the KV pool"),
        "{err}"
    );
}

/// Acceptance: exact fit sits on the feasible side of the boundary.
#[test]
fn exact_fit_boundary() {
    let pred = LatencyPredictor::paper_table2();
    // two jobs of exactly 10 blocks each (160 tokens)
    let jobs: Vec<Job> = (0..2)
        .map(|i| Job {
            req_idx: i,
            input_len: 150,
            output_len: 10,
            slo: Slo::E2e { e2e_ms: 1e9 },
        })
        .collect();
    let both = Schedule { order: vec![0, 1], batches: vec![2] };
    let ev = Evaluator::new(&jobs, &pred);
    let exact = KvConfig::hard(20);
    assert_eq!(ev.kv_excess(&both, &exact), 0, "exact fit must be feasible");
    let short = KvConfig::hard(19);
    assert_eq!(ev.kv_excess(&both, &short), 1, "one block short -> excess 1");
    // the hard search at the exact-fit pool keeps batching legal and
    // returns a feasible plan
    let res = priority_mapping(
        &ev,
        &SaParams { kv: exact, ..SaParams::with_max_batch(2) },
    );
    assert_eq!(ev.kv_excess(&res.schedule, &exact), 0);
    // one block short: the plan must fall back to singleton batches
    let res = priority_mapping(
        &ev,
        &SaParams { kv: short, ..SaParams::with_max_batch(2) },
    );
    assert_eq!(ev.kv_excess(&res.schedule, &short), 0);
    assert_eq!(res.schedule.batches, vec![1, 1], "{:?}", res.schedule);
}

/// Acceptance: on a constrained pool the legacy path plans batches the
/// engine refuses at execution time; the hard-mode scheduler produces a
/// feasible plan that runs to completion within the block pool.
#[test]
fn constrained_pool_feasible_where_legacy_overcommits() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    profile.kv_pool_mb = 200.0; // engine: 400 tokens -> 25 blocks
    let pred = profile.truth;
    let mem = profile.mem; // μ=0.9, σ=0.5 -> scheduler pool 22 blocks
    // 8 requests × 160 tokens (10 blocks): 3 to a batch overcommits the
    // 25-block engine pool; the 22-block scheduler pool allows 2.
    let reqs: Vec<Request> = (0..8)
        .map(|i| {
            Request::synthetic(
                i as u64,
                TaskType::Code,
                150,
                10,
                Slo::E2e { e2e_ms: 1e12 }, // loose: legacy early-exits
            )
        })
        .collect();
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    let instances = vec![InstanceInfo { id: 0, mem_mb: profile.kv_pool_mb }];

    // Legacy (unlimited) packs max_batch-sized batches: 4 × 10 blocks
    // = 40 > 25 — the engine refuses the very first batch.
    let legacy = schedule(
        &reqs,
        &outs,
        &instances,
        &pred,
        &mem,
        &SaParams::with_max_batch(4),
    )
    .unwrap();
    assert!(legacy.plans[0].schedule.batches.iter().any(|&b| b >= 3));
    let mut engines: Vec<Box<dyn Engine + Send>> =
        vec![Box::new(SimEngine::new(profile.clone(), 4, 0))];
    let mut profiler = RequestProfiler::new();
    let err = execute_plans(&reqs, &legacy.plans, &mut engines, &mut profiler)
        .unwrap_err();
    assert!(
        format!("{err}").contains("overcommits the KV pool"),
        "legacy plan should have overcommitted: {err}"
    );

    // Hard mode: per-instance pool (22 blocks) bounds every batch; the
    // plan executes to completion and the engine's high-water mark stays
    // within the pool.
    let kv = KvConfig::from_pool_mb(profile.kv_pool_mb, &mem, 16, KvMode::Hard);
    assert_eq!(kv.pool_blocks, 22);
    let outcome = schedule(
        &reqs,
        &outs,
        &instances,
        &pred,
        &mem,
        &SaParams { kv, ..SaParams::with_max_batch(4) },
    )
    .unwrap();
    let ev = Evaluator::new(&outcome.plans[0].jobs, &pred);
    assert_eq!(ev.kv_excess(&outcome.plans[0].schedule, &kv), 0);
    let mut profiler = RequestProfiler::new();
    let mut engines: Vec<Box<dyn Engine + Send>> =
        vec![Box::new(SimEngine::new(profile.clone(), 4, 0))];
    let completions =
        execute_plans(&reqs, &outcome.plans, &mut engines, &mut profiler)
            .unwrap();
    assert_eq!(completions.len(), 8);
    // replay on a directly owned engine to read the high-water mark
    let mut sim = SimEngine::new(profile.clone(), 4, 0);
    for plan in &outcome.plans {
        for (_, start, size) in plan.schedule.batch_spans() {
            let batch: Vec<slo_serve::engine::EngineRequest> = plan.schedule
                .order[start..start + size]
                .iter()
                .map(|&j| {
                    let r = &reqs[plan.jobs[j].req_idx];
                    slo_serve::engine::EngineRequest {
                        id: r.id,
                        input_len: r.input_len,
                        max_new_tokens: r.output_len,
                        prompt: None,
                    }
                })
                .collect();
            sim.run_batch(&batch).unwrap();
        }
    }
    assert!(
        sim.peak_used_blocks() <= 25,
        "peak {} blocks exceeds the engine pool",
        sim.peak_used_blocks()
    );
    assert!(sim.peak_used_blocks() > 0);
}
