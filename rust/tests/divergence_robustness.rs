//! Divergence robustness harness (ISSUE 5 acceptance).
//!
//! Output-length divergence makes the engine finish jobs at a *true* EOS
//! step that differs from the scheduler's prediction — short outputs free
//! KV early, overruns hold and keep growing it, and the online loop may
//! reconcile and replan mid-trace. This harness pins the two properties
//! that make that safe to ship:
//!
//! * **escape hatch** — `DivergenceModel::Off` (and the σ = 0 divergence
//!   models) replay the pre-divergence scheduler byte for byte: same
//!   plans, same `ScheduleOutcome`, same executed completions, same RNG
//!   streams (the divergence stream is separate from the timing-noise
//!   stream by construction);
//! * **safety invariants under divergence** — across seeds × σ = 0.5
//!   lognormal × {Reserve, Phased} × {Hard, Soft, Unlimited} KV modes,
//!   with drift-reconciling replans active: no KV-block leak (the
//!   allocator returns to empty after drain), every admitted job
//!   completes exactly once, and waits/e2e are measured from true
//!   completions (non-negative, wait ≤ e2e).

use slo_serve::config::profiles::by_name;
use slo_serve::coordinator::execute_plans;
use slo_serve::coordinator::kv::{KvConfig, KvPhaseModel};
use slo_serve::coordinator::online::{
    run_online_opts, OnlineOpts, ReplanStrategy,
};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::profiler::{MemoryModel, RequestProfiler};
use slo_serve::coordinator::request::{Completion, Request, Slo, TaskType};
use slo_serve::coordinator::scheduler::{schedule, InstanceInfo};
use slo_serve::engine::sim::{DivergenceModel, SimEngine};
use slo_serve::engine::Engine;
use slo_serve::util::rng::Rng;

fn random_trace(rng: &mut Rng, n: usize) -> Vec<Request> {
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += rng.uniform(0.0, 300.0);
            let mut r = Request::synthetic(
                i as u64,
                if rng.chance(0.5) { TaskType::Chat } else { TaskType::Code },
                1 + rng.below(240),
                1 + rng.below(60),
                Slo::E2e { e2e_ms: rng.uniform(2_000.0, 60_000.0) },
            );
            r.arrival_ms = t;
            r
        })
        .collect()
}

fn completion_bits(c: &Completion) -> (u64, u64, u64, u64, usize) {
    (
        c.id,
        c.e2e_ms.to_bits(),
        c.ttft_ms.to_bits(),
        c.wait_ms.to_bits(),
        c.generated,
    )
}

/// Escape hatch, closed-wave path: the full PR 4 pipeline
/// (`schedule` + `execute_plans`) is byte-equal between a default engine,
/// a `with_divergence(Off)` engine, and the σ = 0 divergence models
/// (whose multiplier is exactly 1 and whose draws come from a stream the
/// timing noise never touches).
#[test]
fn divergence_off_closed_wave_is_bit_identical() {
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let predictor = profile.truth;
    let mem = MemoryModel::default();
    let mut rng = Rng::new(0xD1F_F);
    let reqs: Vec<Request> = (0..14)
        .map(|i| {
            Request::synthetic(
                i as u64,
                TaskType::Code,
                1 + rng.below(800),
                1 + rng.below(150),
                Slo::E2e { e2e_ms: 60_000.0 },
            )
        })
        .collect();
    let outs: Vec<usize> = reqs.iter().map(|r| r.output_len).collect();
    let instances = vec![InstanceInfo { id: 0, mem_mb: profile.kv_pool_mb }];
    let sa = SaParams::with_max_batch(4);

    // plans are a pure function of the inputs — divergence never sees them
    let a = schedule(&reqs, &outs, &instances, &predictor, &mem, &sa).unwrap();
    let b = schedule(&reqs, &outs, &instances, &predictor, &mem, &sa).unwrap();
    assert_eq!(a.seed, b.seed);
    for (pa, pb) in a.plans.iter().zip(&b.plans) {
        assert_eq!(pa.schedule, pb.schedule, "ScheduleOutcome diverged");
    }

    let run = |model: Option<DivergenceModel>| {
        let mut engine = SimEngine::new(profile.clone(), 4, 7);
        if let Some(m) = model {
            engine = engine.with_divergence(m);
        }
        let mut engines: Vec<Box<dyn Engine + Send>> = vec![Box::new(engine)];
        let mut profiler = RequestProfiler::new();
        execute_plans(&reqs, &a.plans, &mut engines, &mut profiler).unwrap()
    };
    let base = run(None);
    assert_eq!(base.len(), reqs.len());
    for model in [
        DivergenceModel::Off,
        DivergenceModel::Lognormal { sigma: 0.0 },
        DivergenceModel::QuantileTrace { sigma: 0.0 },
    ] {
        let got = run(Some(model));
        for (x, y) in base.iter().zip(&got) {
            assert_eq!(
                completion_bits(x),
                completion_bits(y),
                "{model:?} diverged from the pre-divergence engine"
            );
        }
    }
}

/// Escape hatch, online path: `run_online_opts` with a default engine and
/// default opts is byte-equal to an engine with `Off` divergence and an
/// explicitly-zero drift threshold — reconciliation is bookkeeping only.
#[test]
fn divergence_off_online_is_bit_identical() {
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let predictor = profile.truth;
    let mut rng = Rng::new(0x0FF_1);
    let trace = random_trace(&mut rng, 14);
    let outs: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let sa = SaParams {
        max_batch: 4,
        seed: 3,
        t0: 100.0,
        iters_per_temp: 15,
        ..Default::default()
    };
    let run = |model: Option<DivergenceModel>, opts: OnlineOpts| {
        let mut engine = SimEngine::new(profile.clone(), 4, 3);
        if let Some(m) = model {
            engine = engine.with_divergence(m);
        }
        run_online_opts(
            &trace,
            &outs,
            &mut engine,
            &predictor,
            &sa,
            ReplanStrategy::Warm,
            opts,
        )
        .unwrap()
    };
    let base = run(None, OnlineOpts::default());
    let off = run(
        Some(DivergenceModel::Off),
        OnlineOpts { replan_drift_ms: 0.0, ..Default::default() },
    );
    assert_eq!(base.completions.len(), off.completions.len());
    for (x, y) in base.completions.iter().zip(&off.completions) {
        assert_eq!(completion_bits(x), completion_bits(y));
    }
    assert_eq!(base.stats.replans, off.stats.replans);
    assert_eq!(base.stats.drift_replans, 0);
    assert_eq!(off.stats.drift_replans, 0);
    for (x, y) in base.predicted.iter().zip(&off.predicted) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.e2e_ms.to_bits(), y.e2e_ms.to_bits());
        assert_eq!(x.wait_ms.to_bits(), y.wait_ms.to_bits());
    }
}

/// Safety invariants under real divergence: seeds × {Reserve, Phased} ×
/// {Hard, Soft, Unlimited}, σ = 0.5 lognormal, arrival-aware timeline,
/// drift-reconciling replans on, compaction alternating.
#[test]
fn no_leak_no_double_completion_under_divergence() {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.03;
    let predictor = profile.truth;
    for seed in 0..5u64 {
        for phase in [KvPhaseModel::Reserve, KvPhaseModel::Phased] {
            for kv in [
                KvConfig::UNLIMITED,
                KvConfig::hard(48),
                KvConfig::soft(48, 1.0),
            ] {
                let kv = kv.with_phase(phase);
                let mut rng = Rng::new(seed.wrapping_mul(0x5109) ^ 0xD1E5);
                let n = 10 + rng.below(8);
                let trace = random_trace(&mut rng, n);
                let outs: Vec<usize> =
                    trace.iter().map(|r| r.output_len).collect();
                let sa = SaParams {
                    max_batch: 4,
                    seed,
                    t0: 100.0,
                    iters_per_temp: 10,
                    kv,
                    ..Default::default()
                };
                let mut engine = SimEngine::new(profile.clone(), 4, seed)
                    .with_kv_phase(phase)
                    .with_divergence(DivergenceModel::Lognormal {
                        sigma: 0.5,
                    });
                let tag = format!("seed {seed} {phase:?} {:?}", kv.mode);
                let out = run_online_opts(
                    &trace,
                    &outs,
                    &mut engine,
                    &predictor,
                    &sa,
                    ReplanStrategy::Warm,
                    OnlineOpts {
                        arrival_aware: true,
                        replan_drift_ms: 150.0,
                        compact_dispatched: seed % 2 == 0,
                        ..Default::default()
                    },
                )
                .unwrap_or_else(|e| panic!("{tag}: run failed: {e}"));

                // every admitted job completes exactly once
                assert_eq!(out.completions.len(), n, "{tag}");
                let ids: Vec<u64> =
                    out.completions.iter().map(|c| c.id).collect();
                assert_eq!(
                    ids,
                    (0..n as u64).collect::<Vec<u64>>(),
                    "{tag}: duplicate or missing completions"
                );
                // waits are measured from true completions on the real
                // arrival clock
                for c in &out.completions {
                    assert!(c.wait_ms >= -1e-9, "{tag}: {c:?}");
                    assert!(c.ttft_ms >= c.wait_ms - 1e-9, "{tag}: {c:?}");
                    assert!(c.e2e_ms >= c.wait_ms - 1e-9, "{tag}: {c:?}");
                    assert!(c.generated >= 1, "{tag}: {c:?}");
                }
                // σ = 0.5 divergence actually happened …
                assert!(
                    out.completions
                        .iter()
                        .any(|c| c.generated != c.predicted_lo),
                    "{tag}: no divergence at σ = 0.5"
                );
                assert!(
                    out.stats.avg_abs_lo_divergence() > 0.0,
                    "{tag}: reconcile saw no divergence"
                );
                // … and the allocator drained back to zero: no KV leak
                assert_eq!(engine.kv().active_seqs(), 0, "{tag}: leaked seqs");
                assert_eq!(
                    engine.kv().free_blocks(),
                    engine.kv().config().total_blocks,
                    "{tag}: leaked blocks"
                );
                assert!(
                    engine.peak_used_blocks()
                        <= engine.kv().config().total_blocks,
                    "{tag}"
                );
            }
        }
    }
}

/// The conservative quantile reservation column composes with divergence:
/// a hard pool reserving at the 0.9 output-length quantile still plans
/// feasibly, serves everything, and leaks nothing when actual lengths
/// diverge.
#[test]
fn quantile_reservation_column_serves_divergent_trace() {
    use slo_serve::coordinator::predictor::quantile_multiplier;
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    let predictor = profile.truth;
    let mut rng = Rng::new(0x9_01);
    let trace = random_trace(&mut rng, 12);
    let outs: Vec<usize> = trace.iter().map(|r| r.output_len).collect();
    let sigma = 0.5;
    let mult = quantile_multiplier(sigma, 0.9);
    assert!(mult > 1.0);
    let kv = KvConfig::hard(64).with_lo_mult(mult);
    let sa = SaParams {
        max_batch: 4,
        seed: 1,
        t0: 100.0,
        iters_per_temp: 10,
        kv,
        ..Default::default()
    };
    let mut engine = SimEngine::new(profile, 4, 1)
        .with_divergence(DivergenceModel::Lognormal { sigma });
    let out = run_online_opts(
        &trace,
        &outs,
        &mut engine,
        &predictor,
        &sa,
        ReplanStrategy::Warm,
        OnlineOpts { arrival_aware: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.completions.len(), trace.len());
    assert_eq!(engine.kv().active_seqs(), 0);
    assert_eq!(engine.kv().free_blocks(), engine.kv().config().total_blocks);
}
