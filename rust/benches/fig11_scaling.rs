//! Paper Fig. 11: multi-instance scalability — (A) ΔG sustained across
//! 1–4 instances; (B) scheduling overhead growing ~linearly when instances
//! are mapped sequentially on one server.
//!
//! Methodology mirrors §5.5: a 10-request wave is replicated per instance
//! (n = 10 × instances) and Algorithm 2 assigns + priority-maps each
//! instance independently.

use slo_serve::bench::run_scenario;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::metrics::Table;

fn cfg(policy: &str, instances: usize, seed: u64) -> RunConfig {
    RunConfig {
        policy: policy.into(),
        n_requests: 10 * instances,
        n_instances: instances,
        max_batch: 2,
        seed,
        output_pred: OutputPrediction::Oracle { rel_err: 0.05 },
        slos: SloTargets::default().scaled(0.4),
        ..Default::default()
    }
}

fn main() {
    println!("== Fig. 11: SLO-aware scheduling across 1–4 instances ==\n");
    let seeds: Vec<u64> = (0..3).collect();
    let mut t = Table::new(&[
        "instances", "requests", "ΔG vs fcfs", "sched overhead (ms)",
        "overhead/instance (ms)",
    ]);
    for instances in 1..=4usize {
        let mut sa_g = 0.0;
        let mut fcfs_g = 0.0;
        let mut overhead = 0.0;
        for &seed in &seeds {
            let sa = run_scenario(&cfg("slo-aware-sa", instances, seed)).unwrap();
            sa_g += sa.metrics.g_req_per_s;
            overhead += sa.sched_overhead_ms;
            fcfs_g += run_scenario(&cfg("fcfs", instances, seed))
                .unwrap()
                .metrics
                .g_req_per_s;
        }
        overhead /= seeds.len() as f64;
        t.row(vec![
            instances.to_string(),
            (10 * instances).to_string(),
            format!("{:+.1}%", (sa_g / fcfs_g - 1.0) * 100.0),
            format!("{overhead:.3}"),
            format!("{:.3}", overhead / instances as f64),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper shape: ΔG sustained as instances scale; overhead grows ~linearly");
    println!("with instance count (0.93 ms @2 → 1.91 ms @4 in the paper) because the");
    println!("per-instance mappings run sequentially on one server.");
    println!("note: the numbers above are cpu time (Σ per-instance mapping) to stay");
    println!("comparable with the paper; the production scheduler path");
    println!("(coordinator::scheduler::schedule) maps instances on parallel threads");
    println!("and reports wall clock separately as ScheduleOutcome::overhead_ms.");
}
