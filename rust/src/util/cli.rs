//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! typed accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

/// A parsed argument set.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

/// Declarative option spec used for parsing + help generation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag, Some(default) => value option.
    pub default: Option<&'static str>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1:?}")]
    BadValue(String, String),
}

impl Args {
    /// Parse `argv` (without the program name) against `specs`.
    pub fn parse(
        argv: &[String],
        specs: &[OptSpec],
    ) -> Result<Args, CliError> {
        let mut out = Args::default();
        // seed defaults
        for spec in specs {
            if let Some(d) = spec.default {
                out.flags.insert(spec.name.to_string(), d.to_string());
            }
        }
        let find = |name: &str| specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = find(name)
                    .ok_or_else(|| CliError::Unknown(name.to_string()))?;
                let value = if spec.default.is_none() && inline_val.is_none() {
                    "true".to_string() // boolean flag
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.into()))?
                };
                out.flags.insert(name.to_string(), value);
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true" | "1" | "yes"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let raw = self.get(name).unwrap_or("0");
        raw.parse()
            .map_err(|_| CliError::BadValue(name.into(), raw.into()))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let raw = self.get(name).unwrap_or("0");
        raw.parse()
            .map_err(|_| CliError::BadValue(name.into(), raw.into()))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let raw = self.get(name).unwrap_or("0");
        raw.parse()
            .map_err(|_| CliError::BadValue(name.into(), raw.into()))
    }

    /// Comma-separated list of usize (e.g. `--batch-sizes 1,2,4`).
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        let raw = self.get(name).unwrap_or("");
        if raw.is_empty() {
            return Ok(vec![]);
        }
        raw.split(',')
            .map(|p| {
                p.trim().parse().map_err(|_| {
                    CliError::BadValue(name.into(), raw.into())
                })
            })
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render help text for a command.
pub fn render_help(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("{program} — {about}\n\nOptions:\n");
    for s in specs {
        let left = match s.default {
            Some(d) => format!("  --{} <value>  [default: {}]", s.name, d),
            None => format!("  --{}", s.name),
        };
        out.push_str(&format!("{left:<44}{}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "seed", help: "rng seed", default: Some("42") },
            OptSpec { name: "name", help: "label", default: Some("x") },
            OptSpec { name: "verbose", help: "chatty", default: None },
            OptSpec { name: "sizes", help: "list", default: Some("") },
        ]
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.usize("seed").unwrap(), 42);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse(&argv(&["--seed", "7", "--name=run1"]), &specs())
            .unwrap();
        assert_eq!(a.usize("seed").unwrap(), 7);
        assert_eq!(a.str("name"), "run1");
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&argv(&["--verbose"]), &specs()).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let a = Args::parse(&argv(&["serve", "--seed", "1", "extra"]),
                            &specs()).unwrap();
        assert_eq!(a.positional(), &["serve", "extra"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(Args::parse(&argv(&["--nope"]), &specs()),
                         Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(Args::parse(&argv(&["--seed"]), &specs()),
                         Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_value_rejected() {
        let a = Args::parse(&argv(&["--seed", "abc"]), &specs()).unwrap();
        assert!(matches!(a.usize("seed"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn usize_list_parses() {
        let a = Args::parse(&argv(&["--sizes", "1,2, 4"]), &specs()).unwrap();
        assert_eq!(a.usize_list("sizes").unwrap(), vec![1, 2, 4]);
        let b = Args::parse(&[], &specs()).unwrap();
        assert!(b.usize_list("sizes").unwrap().is_empty());
    }

    #[test]
    fn help_mentions_options() {
        let text = render_help("prog", "does things", &specs());
        assert!(text.contains("--seed"));
        assert!(text.contains("default: 42"));
    }
}
