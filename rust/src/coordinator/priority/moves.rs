//! Neighbourhood moves for the simulated-annealing search (Algorithm 1).
//!
//! Three perturbations generate a new candidate from the current schedule:
//!
//! * [`squeeze_prev`]  — `squeezeLastIter`: pull a request into the
//!   *previous* batch iteration (if it is not in the first iteration and the
//!   previous batch has room).
//! * [`delay_next`]    — `delayNextIter`: push a request into the *next*
//!   batch iteration (if it has room; delaying out of the final batch opens
//!   a fresh iteration — the Fig. 4(C) move).
//! * [`rand_swap`]     — `randSwapping`: exchange two positions in the
//!   priority sequence.
//!
//! All moves preserve the schedule invariants (permutation; positive batch
//! sizes ≤ max; partition) — enforced by the property tests.

use crate::coordinator::objective::Schedule;
use crate::util::rng::Rng;

/// Try to move one random job into the previous batch. Returns false if no
/// job is eligible (then the caller should pick another move).
pub fn squeeze_prev(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    if s.batches.len() < 2 {
        return false;
    }
    // Eligible batches k>0 with batches[k-1] < max_batch.
    let eligible: Vec<usize> = (1..s.batches.len())
        .filter(|&k| s.batches[k - 1] < max_batch)
        .collect();
    if eligible.is_empty() {
        return false;
    }
    let k = *rng.choose(&eligible);
    let start_k: usize = s.batches[..k].iter().sum();
    // pick a random member of batch k and move it to the end of batch k-1
    let pick = start_k + rng.below(s.batches[k]);
    let job = s.order.remove(pick);
    s.order.insert(start_k, job);
    s.batches[k - 1] += 1;
    s.batches[k] -= 1;
    if s.batches[k] == 0 {
        s.batches.remove(k);
    }
    true
}

/// Try to move one random job into the next batch (creating a new final
/// batch when delaying from the last one). Returns false if nothing moved.
pub fn delay_next(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    if s.order.is_empty() {
        return false;
    }
    let m = s.batches.len();
    // Eligible source batches: k < m-1 with batches[k+1] < max_batch, or the
    // final batch if it holds more than one job (otherwise delaying is a
    // no-op that recreates the same batch).
    let eligible: Vec<usize> = (0..m)
        .filter(|&k| {
            if k + 1 < m {
                s.batches[k + 1] < max_batch
            } else {
                s.batches[k] > 1
            }
        })
        .collect();
    if eligible.is_empty() {
        return false;
    }
    let k = *rng.choose(&eligible);
    let start_k: usize = s.batches[..k].iter().sum();
    let pick = start_k + rng.below(s.batches[k]);
    let job = s.order.remove(pick);
    // insert at the START of batch k+1's span (which, after removal, begins
    // at start_k + batches[k] - 1)
    let insert_at = start_k + s.batches[k] - 1;
    s.order.insert(insert_at, job);
    if k + 1 < m {
        s.batches[k] -= 1;
        s.batches[k + 1] += 1;
        if s.batches[k] == 0 {
            s.batches.remove(k);
        }
    } else {
        s.batches[k] -= 1;
        s.batches.push(1);
    }
    true
}

/// Swap two random positions in the priority sequence. Returns false only
/// for schedules with fewer than two jobs.
pub fn rand_swap(s: &mut Schedule, rng: &mut Rng) -> bool {
    let n = s.order.len();
    if n < 2 {
        return false;
    }
    let i = rng.below(n);
    let mut j = rng.below(n - 1);
    if j >= i {
        j += 1;
    }
    s.order.swap(i, j);
    true
}

/// Apply one randomly-selected move (the `rand(0,1,2)` of Algorithm 1,
/// line 20), retrying with the other moves if the chosen one is infeasible.
/// Returns false only if no move is possible at all.
pub fn random_move(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    let first = rng.below(3);
    for offset in 0..3 {
        let moved = match (first + offset) % 3 {
            0 => squeeze_prev(s, max_batch, rng),
            1 => delay_next(s, max_batch, rng),
            _ => rand_swap(s, rng),
        };
        if moved {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn sorted(v: &[usize]) -> Vec<usize> {
        let mut s = v.to_vec();
        s.sort_unstable();
        s
    }

    #[test]
    fn squeeze_moves_job_backward() {
        let mut rng = Rng::new(0);
        let mut s = Schedule { order: vec![0, 1, 2, 3], batches: vec![1, 1, 1, 1] };
        assert!(squeeze_prev(&mut s, 2, &mut rng));
        s.validate(2).unwrap();
        assert_eq!(s.order.len(), 4);
        assert_eq!(s.batches.iter().sum::<usize>(), 4);
        assert_eq!(s.batches.len(), 3); // one batch merged away
    }

    #[test]
    fn squeeze_respects_max_batch() {
        let mut rng = Rng::new(1);
        let mut s = Schedule { order: vec![0, 1, 2, 3], batches: vec![2, 2] };
        assert!(!squeeze_prev(&mut s, 2, &mut rng)); // previous batch full
        assert_eq!(s.batches, vec![2, 2]);
    }

    #[test]
    fn squeeze_single_batch_impossible() {
        let mut rng = Rng::new(2);
        let mut s = Schedule { order: vec![0, 1], batches: vec![2] };
        assert!(!squeeze_prev(&mut s, 4, &mut rng));
    }

    #[test]
    fn delay_from_last_creates_new_batch() {
        let mut rng = Rng::new(3);
        let mut s = Schedule { order: vec![0, 1], batches: vec![2] };
        assert!(delay_next(&mut s, 2, &mut rng));
        s.validate(2).unwrap();
        assert_eq!(s.batches, vec![1, 1]);
    }

    #[test]
    fn delay_singleton_last_batch_refused() {
        let mut rng = Rng::new(4);
        let mut s = Schedule { order: vec![0], batches: vec![1] };
        assert!(!delay_next(&mut s, 4, &mut rng));
        // two batches, next full, last is singleton -> nothing eligible
        let mut s =
            Schedule { order: vec![0, 1], batches: vec![1, 1] };
        assert!(!delay_next(&mut s, 1, &mut rng) || s.validate(1).is_ok());
    }

    #[test]
    fn swap_preserves_multiset() {
        let mut rng = Rng::new(5);
        let mut s = Schedule { order: vec![3, 1, 4, 0, 2], batches: vec![5] };
        let before = sorted(&s.order);
        assert!(rand_swap(&mut s, &mut rng));
        assert_eq!(sorted(&s.order), before);
        assert_ne!(s.order, vec![3, 1, 4, 0, 2]); // a swap always changes order
    }

    #[test]
    fn random_move_always_valid() {
        check("random_move preserves schedule invariants", 300, |rng| {
            let n = 1 + rng.below(12);
            let max_batch = 1 + rng.below(4);
            let mut s = Schedule::fcfs(n, max_batch);
            for _ in 0..30 {
                random_move(&mut s, max_batch, rng);
                s.validate(max_batch).map_err(|e| {
                    format!("n={n} max_batch={max_batch}: {e} ({s:?})")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn moves_reach_different_batch_counts() {
        // SA must be able to both split and merge batches.
        let mut rng = Rng::new(7);
        let mut min_batches = usize::MAX;
        let mut max_batches = 0;
        let mut s = Schedule::fcfs(6, 3);
        for _ in 0..2000 {
            random_move(&mut s, 3, &mut rng);
            min_batches = min_batches.min(s.batches.len());
            max_batches = max_batches.max(s.batches.len());
        }
        assert!(min_batches <= 2, "min {min_batches}");
        assert!(max_batches >= 4, "max {max_batches}");
    }
}
