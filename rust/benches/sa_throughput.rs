//! SA scheduler throughput: incremental (prediction table + delta
//! evaluation + zero-alloc moves) vs the full-evaluation reference path,
//! at wave sizes N ∈ {16, 64, 256, 512}.
//!
//! Reports per-mapping wall time and objective evaluations per second for
//! both paths, and writes machine-readable results to
//! `BENCH_sa_throughput.json` (cargo package root) so future PRs can track
//! the perf trajectory.
//!
//!     cargo bench --bench sa_throughput

use slo_serve::bench::time_ms;
use slo_serve::coordinator::objective::{Evaluator, Job};
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{
    priority_mapping, priority_mapping_full, SaParams,
};
use slo_serve::coordinator::request::Slo;
use slo_serve::metrics::Table;
use slo_serve::util::json::Json;
use slo_serve::util::rng::Rng;

const MAX_BATCH: usize = 8;
/// SA search seed; recorded in the JSON so CI's regression gate compares
/// reproducible runs (the workload seed per size is `0xBEEF ^ n`).
const SA_SEED: u64 = 7;

/// Mixed wave with SLOs tight enough that the sorted seed never meets them
/// all — the early-exit fast path would otherwise skip the search entirely
/// and the measurement would be meaningless.
fn jobs(n: usize, seed: u64) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let input_len = rng.range(50, 1500) as usize;
            let output_len = rng.range(20, 400) as usize;
            let slo = if i % 10 == 0 {
                // a few unmeetable bounds pin the search away from early exit
                Slo::E2e { e2e_ms: 1.0 }
            } else if rng.chance(0.5) {
                Slo::E2e { e2e_ms: rng.uniform(500.0, 30_000.0) }
            } else {
                Slo::Interactive {
                    ttft_ms: rng.uniform(200.0, 8_000.0),
                    tpot_ms: rng.uniform(10.0, 50.0),
                }
            };
            Job { req_idx: i, input_len, output_len, slo }
        })
        .collect()
}

fn main() {
    println!("== SA priority-mapping throughput: incremental vs full eval ==\n");
    let pred = LatencyPredictor::paper_table2();
    let mut t = Table::new(&[
        "N",
        "full (ms)",
        "incremental (ms)",
        "speedup",
        "full evals/s",
        "incremental evals/s",
    ]);
    let mut sizes: Vec<Json> = Vec::new();

    for &n in &[16usize, 64, 256, 512] {
        let jobs_seed = 0xBEEF ^ n as u64;
        let js = jobs(n, jobs_seed);
        let ev = Evaluator::new(&js, &pred);
        let params =
            SaParams { max_batch: MAX_BATCH, seed: SA_SEED, ..Default::default() };

        // deterministic for a fixed seed, so stats come from one dry run
        let res = priority_mapping(&ev, &params);
        assert!(!res.stats.early_exit, "N={n}: early exit would skew timing");
        let evals = res.stats.evals;

        let iters = if n >= 256 { 3 } else { 10 };
        let inc_ms = time_ms(1, iters, || {
            let _ = priority_mapping(&ev, &params);
        });
        let full_ms = time_ms(1, iters, || {
            let _ = priority_mapping_full(&ev, &params);
        });

        let speedup = full_ms / inc_ms;
        let full_eps = evals as f64 / (full_ms / 1e3);
        let inc_eps = evals as f64 / (inc_ms / 1e3);
        t.row(vec![
            n.to_string(),
            format!("{full_ms:.3}"),
            format!("{inc_ms:.3}"),
            format!("{speedup:.1}x"),
            format!("{full_eps:.0}"),
            format!("{inc_eps:.0}"),
        ]);
        sizes.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("jobs_seed", Json::num(jobs_seed as f64)),
            ("sa_evals", Json::num(evals as f64)),
            ("full_ms", Json::num(full_ms)),
            ("incremental_ms", Json::num(inc_ms)),
            ("speedup", Json::num(speedup)),
            ("full_evals_per_s", Json::num(full_eps)),
            ("incremental_evals_per_s", Json::num(inc_eps)),
        ]));
    }
    print!("{}", t.render());

    let doc = Json::obj(vec![
        ("bench", Json::str("sa_throughput")),
        ("max_batch", Json::num(MAX_BATCH as f64)),
        ("sa_seed", Json::num(SA_SEED as f64)),
        ("sa_t0", Json::num(SaParams::default().t0)),
        ("sa_iters_per_temp", Json::num(SaParams::default().iters_per_temp as f64)),
        ("sizes", Json::arr(sizes)),
    ]);
    let out = format!("{}\n", doc.to_string_pretty());
    std::fs::write("BENCH_sa_throughput.json", out)
        .expect("writing BENCH_sa_throughput.json");
    println!("\nwrote BENCH_sa_throughput.json");
    println!("paths are bit-identical (tests/incremental_eval_equivalence.rs);");
    println!("the speedup is pure hot-path restructuring.");
}
