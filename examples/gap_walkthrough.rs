//! Optimality-gap walkthrough: how far from *provably optimal* are the
//! SA search and the cheap index/threshold baselines?
//!
//! Runs the gap matrix over divergence σ ∈ {0, 0.2, 0.5} × KV mode
//! {Hard, Unlimited} at N = 10 and prints per-regime certified gaps —
//! every number is measured against a branch-and-bound bound
//! ([`slo_serve::coordinator::gap`]), so "0.00%" means *proven* optimal,
//! not "matched another heuristic". The σ axis enters through the KV
//! 0.9-quantile reservation: larger σ charges bigger footprints against
//! the Hard pool while Unlimited rows are σ-invariant. The last column
//! flags regimes where an index policy matched the search — the signal a
//! policy router would use to skip SA there.
//!
//!     cargo run --release --example gap_walkthrough

use slo_serve::bench::gap::{run_matrix, summarize, GapConfig, GapKv, SloMix};
use slo_serve::coordinator::kv::KvPhaseModel;
use slo_serve::metrics::Table;

fn main() {
    println!(
        "optimality-gap walkthrough: σ x KV mode at N = 10 (certified \
         bounds)\n"
    );
    let cfg = GapConfig {
        ns: vec![10],
        seeds: vec![1, 2, 3],
        mixes: vec![SloMix::Mixed],
        sigmas: vec![0.0, 0.2, 0.5],
        kvs: vec![
            (GapKv::Hard, KvPhaseModel::Reserve),
            (GapKv::Unlimited, KvPhaseModel::Reserve),
        ],
        ..GapConfig::default()
    };
    let rows = run_matrix(&cfg);

    let mut t = Table::new(&[
        "sigma",
        "kv",
        "closed",
        "SA gap",
        "best baseline",
        "baseline gap",
        "idx>=SA",
    ]);
    for &sigma in &cfg.sigmas {
        for &(kv, _) in &cfg.kvs {
            // aggregate the seeds of one (σ, kv) regime
            let cell: Vec<_> = rows
                .iter()
                .filter(|r| r.sigma == sigma && r.kv.name() == kv.name())
                .collect();
            let closed = cell.iter().filter(|r| r.closed).count();
            let k = cell.len() as f64;
            let sa_gap: f64 =
                cell.iter().map(|r| r.sa.gap).sum::<f64>() / k;
            // per-seed best baseline, averaged
            let mut bl_gap = 0.0;
            let mut bl_names: Vec<&str> = Vec::new();
            for r in &cell {
                let best = r
                    .baselines
                    .iter()
                    .max_by(|a, b| a.g.total_cmp(&b.g))
                    .expect("baselines non-empty");
                bl_gap += best.gap;
                if !bl_names.contains(&best.name) {
                    bl_names.push(best.name);
                }
            }
            bl_gap /= k;
            let idx_wins =
                cell.iter().filter(|r| r.index_beats_sa).count();
            t.row(vec![
                format!("{sigma:.1}"),
                kv.name().to_string(),
                format!("{closed}/{}", cell.len()),
                format!("{:.2}%", 100.0 * sa_gap),
                bl_names.join("/"),
                format!("{:.2}%", 100.0 * bl_gap),
                if idx_wins > 0 {
                    format!("{idx_wins}/{}", cell.len())
                } else {
                    "-".into()
                },
            ]);
        }
    }
    print!("{}", t.render());

    let s = summarize(&rows);
    println!(
        "\n{} cells, {} closed exactly; worst SA certified gap {:.2}% \
         (gated cells); index policies matched/beat SA in {} cell(s).",
        s.cells,
        s.closed,
        100.0 * s.max_gated_sa_gap,
        s.index_beats_sa_cells
    );
    println!(
        "reading the table: gaps are against branch-and-bound bounds — a \
         closed cell's bound IS the optimum, so its gap is exact \
         suboptimality, not heuristic-vs-heuristic distance."
    );
    println!("gap_walkthrough OK");
}
