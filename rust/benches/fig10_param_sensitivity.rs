//! Paper Fig. 10: sensitivity of G to perturbed latency-predictor fitting
//! parameters (α, β, γ, δ for prefill and decode), 10 requests, max batch 4.
//!
//! The scheduler runs with one coefficient scaled by ±10% / ±25% / ±50%
//! while the simulated engine keeps the true coefficients. Paper shape:
//! degradation grows with deviation; α (the batch×length interaction) is
//! the most sensitive; worst observed drop ≈ 1.9%.

use slo_serve::bench::{fit_predictor_from_profile, run_scenario, run_scenario_with};
use slo_serve::config::profiles::by_name;
use slo_serve::config::{OutputPrediction, RunConfig, SloTargets};
use slo_serve::coordinator::predictor::{Coeff, LatencyPredictor};
use slo_serve::metrics::Table;

fn cfg(seed: u64) -> RunConfig {
    RunConfig {
        policy: "slo-aware-sa".into(),
        n_requests: 10,
        max_batch: 4,
        seed,
        output_pred: OutputPrediction::Oracle { rel_err: 0.05 },
        slos: SloTargets::default().scaled(0.4),
        ..Default::default()
    }
}

fn main() {
    println!("== Fig. 10: G degradation under fitting-parameter variation ==");
    println!("10 requests, max batch 4, qwen7b-v100x2-vllm\n");
    let seeds: Vec<u64> = (0..4).collect();
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let fitted = fit_predictor_from_profile(&profile, 0);

    let avg_g = |pred: Option<LatencyPredictor>| -> f64 {
        let mut g = 0.0;
        for &seed in &seeds {
            g += run_scenario_with(&cfg(seed), pred)
                .unwrap()
                .metrics
                .g_req_per_s;
        }
        g / seeds.len() as f64
    };
    let baseline = avg_g(Some(fitted));
    let _ = run_scenario(&cfg(0)); // warm caches

    let mut t = Table::new(&[
        "phase", "coeff", "-50%", "-25%", "-10%", "+10%", "+25%", "+50%",
    ]);
    for phase in ["prefill", "decode"] {
        for coeff in Coeff::ALL {
            let mut row = vec![phase.to_string(), coeff.name().into()];
            for rel in [-0.5, -0.25, -0.1, 0.1, 0.25, 0.5] {
                let mut p = fitted;
                if phase == "prefill" {
                    p.prefill = p.prefill.perturbed(coeff, rel);
                } else {
                    p.decode = p.decode.perturbed(coeff, rel);
                }
                let g = avg_g(Some(p));
                row.push(format!("{:+.1}%", (g / baseline - 1.0) * 100.0));
            }
            t.row(row);
        }
    }
    print!("{}", t.render());
    println!("\npaper shape: degradation correlates with deviation; α most impactful");
    println!("(it scales the batch×length interaction); worst drop ≈ -1.9%.");
}
