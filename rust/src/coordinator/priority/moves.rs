//! Neighbourhood moves for the simulated-annealing search (Algorithm 1).
//!
//! Three perturbations generate a new candidate from the current schedule:
//!
//! * [`squeeze_prev`]  — `squeezeLastIter`: pull a request into the
//!   *previous* batch iteration (if it is not in the first iteration and the
//!   previous batch has room).
//! * [`delay_next`]    — `delayNextIter`: push a request into the *next*
//!   batch iteration (if it has room; delaying out of the final batch opens
//!   a fresh iteration — the Fig. 4(C) move).
//! * [`rand_swap`]     — `randSwapping`: exchange two positions in the
//!   priority sequence.
//!
//! All moves are **allocation-free**: eligible batches are selected by
//! count-then-take-k-th sampling instead of collecting an `eligible`
//! vector, and the `order` edits are in-place slice rotations
//! (`rotate_left`/`rotate_right`) instead of `remove`/`insert` pairs. Each
//! `*_desc` variant returns an [`AppliedMove`] describing exactly which
//! batches changed membership and how to revert the `order` edit — the
//! contract the incremental evaluator
//! ([`crate::coordinator::objective::IncrementalEval`]) builds on.
//!
//! All moves preserve the schedule invariants (permutation; positive batch
//! sizes ≤ max; partition) — enforced by the property tests.
//!
//! **Frozen-prefix masking** (online admission): every move has a
//! `*_masked` variant taking `frozen_batches` — the number of leading
//! batches already dispatched to an engine. Masked moves never change the
//! membership, order, or boundaries of the frozen prefix: eligible source
//! batches start at `frozen_batches` (and squeeze targets at
//! `frozen_batches` too), and swaps only sample positions at or beyond the
//! first unfrozen position. With `frozen_batches == 0` the masked variants
//! draw the exact same RNG stream and produce the exact same edits as the
//! unmasked ones — the bit-identity the online-equals-offline equivalence
//! test rests on.
//!
//! **KV-block feasibility** (Eq. 20): every move also has a `*_kv`
//! variant taking an optional [`KvVeto`] — a view of the per-job block
//! footprints and current per-batch occupancy maintained by the
//! incremental evaluator. With a veto present (hard KV mode), a move that
//! would push any batch's demand over the pool is refused *after* its
//! RNG draws but *before* any mutation, so the schedule is untouched and
//! [`random_move_desc_kv`] falls through to the next move family. Demand
//! is priced per the active model — footprint sums under reserve
//! accounting, exact occupancy peaks when a [`PhasedVeto`] is present —
//! and in both cases the source batch's demand only ever shrinks, so a
//! vetoed generator can never increase any batch's excess: a feasible
//! schedule stays feasible for the whole search. With `kv == None` the
//! `*_kv` variants draw the exact RNG stream of the plain/masked ones.
//!
//! **Sliding-window restriction** (chunk-granular online planning): every
//! move also has a `*_win` variant taking a `window` — the number of
//! batches beyond the frozen prefix the search may edit. With `window ==
//! W > 0` only batches `frozen_batches..hi` are eligible, where
//! `hi = m.min(frozen_batches + W)`: squeeze sources/targets, delay
//! sources and targets, and both swap positions must lie inside the
//! window, and delaying may only open a fresh final batch when the window
//! already reaches the schedule's end (`hi == m`). Batches at `hi..` keep
//! their membership and internal order (their indices may shift when a
//! windowed batch empties). With `window == 0` the window is unbounded
//! and the `*_win` variants draw the exact RNG stream and produce the
//! exact edits of the `*_kv` ones — the invariant-15 bit-identity.
//!
//! **Per-chain move streams** (parallel tempering): the generators hold
//! no state beyond the `&mut Rng` handed in, so each tempering chain
//! drives its own derived RNG
//! ([`crate::coordinator::priority::annealing::SaParams::chains`])
//! through the same allocation-free move code with zero sharing — chain
//! 0's stream is byte-identical to the untempered search's (invariant
//! 11), and K chains never contend on anything but their own schedule.

use crate::coordinator::kv;
use crate::coordinator::objective::{Job, Schedule};
use crate::util::rng::Rng;

/// Phase-aware demand inputs for the veto
/// ([`crate::coordinator::kv::KvPhaseModel::Phased`]): raw job lengths
/// plus the block granularity, enough to recompute a candidate batch's
/// exact occupancy peak without allocating.
#[derive(Debug, Clone, Copy)]
pub struct PhasedVeto<'a> {
    /// The wave's jobs (index = job id) — inputs/predicted outputs feed
    /// the peak computation.
    pub jobs: &'a [Job],
    /// Tokens per KV block.
    pub block_tokens: usize,
}

impl PhasedVeto<'_> {
    #[inline]
    fn lens(&self, j: usize) -> (usize, usize) {
        let job = &self.jobs[j];
        (job.input_len, job.output_len)
    }

    /// Peak of `members ∪ {extra}` — the one shared peak implementation
    /// ([`kv::phased_peak_over`]) over a virtual member set, so the veto
    /// can never diverge from the evaluators' demand accounting.
    fn peak_with(&self, members: &[usize], extra: usize) -> u64 {
        kv::phased_peak_over(
            members.len() + 1,
            |i| {
                if i < members.len() {
                    self.lens(members[i])
                } else {
                    self.lens(extra)
                }
            },
            self.block_tokens,
        )
    }

    /// Peak of `members` with member `from` replaced by `to`.
    fn peak_swapped(&self, members: &[usize], from: usize, to: usize) -> u64 {
        kv::phased_peak_over(
            members.len(),
            |i| {
                let j = members[i];
                self.lens(if j == from { to } else { j })
            },
            self.block_tokens,
        )
    }
}

/// Read-only KV state the hard-feasibility veto consults (borrowed from
/// [`crate::coordinator::objective::IncrementalEval`]'s per-batch
/// aggregates and the
/// [`crate::coordinator::pred_table::PredTable`] footprints).
///
/// Under reserve demand the sum-based checks are exact. With `phased`
/// present, candidate batches are re-priced at their exact phase-aware
/// occupancy peak instead — also exact, so in both models a vetoed
/// generator never materializes an overcommitting candidate and a
/// feasible schedule stays feasible for the whole search.
#[derive(Debug, Clone, Copy)]
pub struct KvVeto<'a> {
    /// Per-job KV footprint in blocks (index = job id).
    pub job_blocks: &'a [u64],
    /// Current per-batch demand in blocks (index = batch).
    pub batch_blocks: &'a [u64],
    /// Pool capacity in blocks.
    pub pool_blocks: u64,
    /// Phase-aware demand inputs; `None` under reserve accounting.
    pub phased: Option<PhasedVeto<'a>>,
}

impl KvVeto<'_> {
    /// Would moving `job` into the existing batch `target` (whose member
    /// jobs are `target_members`) overcommit it?
    #[inline]
    fn into_batch_ok(
        &self,
        target: usize,
        target_members: &[usize],
        job: usize,
    ) -> bool {
        match &self.phased {
            None => {
                self.batch_blocks[target] + self.job_blocks[job]
                    <= self.pool_blocks
            }
            Some(p) => p.peak_with(target_members, job) <= self.pool_blocks,
        }
    }

    /// Can `job` open a fresh singleton batch? (A singleton's phased peak
    /// equals its full footprint, so one rule serves both models.)
    #[inline]
    fn alone_ok(&self, job: usize) -> bool {
        self.job_blocks[job] <= self.pool_blocks
    }

    /// Would exchanging `job_a` (in batch `ba`, members `ma`) with
    /// `job_b` (in batch `bb`, members `mb`) overcommit either batch?
    #[inline]
    fn swap_ok(
        &self,
        ba: usize,
        ma: &[usize],
        job_a: usize,
        bb: usize,
        mb: &[usize],
        job_b: usize,
    ) -> bool {
        if ba == bb {
            return true; // intra-batch swap never changes occupancy
        }
        match &self.phased {
            None => {
                let a = self.batch_blocks[ba] - self.job_blocks[job_a]
                    + self.job_blocks[job_b];
                let b = self.batch_blocks[bb] - self.job_blocks[job_b]
                    + self.job_blocks[job_a];
                a <= self.pool_blocks && b <= self.pool_blocks
            }
            Some(p) => {
                p.peak_swapped(ma, job_a, job_b) <= self.pool_blocks
                    && p.peak_swapped(mb, job_b, job_a) <= self.pool_blocks
            }
        }
    }
}

/// Start offset of batch `k` within the order (Σ earlier batch sizes).
#[inline]
fn span_start(batches: &[usize], k: usize) -> usize {
    batches[..k].iter().sum()
}

/// How to revert an in-place `order` edit (the `order` length never
/// changes, so every move is undone by one rotation or one swap).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderUndo {
    /// `order[lo..=hi]` was rotated right by one; rotate left to revert.
    RotateLeft { lo: usize, hi: usize },
    /// `order[lo..=hi]` was rotated left by one; rotate right to revert.
    RotateRight { lo: usize, hi: usize },
    /// Positions `i` and `j` were swapped; swap again to revert.
    Swap { i: usize, j: usize },
}

impl OrderUndo {
    /// Revert the order edit this record describes.
    pub fn revert(self, order: &mut [usize]) {
        match self {
            OrderUndo::RotateLeft { lo, hi } => order[lo..=hi].rotate_left(1),
            OrderUndo::RotateRight { lo, hi } => order[lo..=hi].rotate_right(1),
            OrderUndo::Swap { i, j } => order.swap(i, j),
        }
    }
}

/// Description of a successfully applied move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AppliedMove {
    /// The (new-indexing) batch indices whose *membership* changed.
    /// `b_lo <= b_hi`; equal when only one batch changed. Batches strictly
    /// between the two (possible for [`rand_swap`]) are untouched.
    pub b_lo: usize,
    pub b_hi: usize,
    /// `Some(k)`: the source batch emptied and was removed at index `k`
    /// (pre-removal indexing; batches ≥ k shifted down by one).
    pub removed_batch: Option<usize>,
    /// A new singleton final batch was appended (delay from the last batch).
    pub appended_batch: bool,
    /// How to revert the `order` edit.
    pub undo: OrderUndo,
}

/// Index of the `r`-th batch (ascending) satisfying `elig`, given that at
/// least `r + 1` batches do. Zero-allocation replacement for collecting an
/// eligible-batch vector and indexing into it.
#[inline]
fn nth_eligible(
    range: std::ops::Range<usize>,
    r: usize,
    mut elig: impl FnMut(usize) -> bool,
) -> usize {
    let mut seen = 0usize;
    for k in range {
        if elig(k) {
            if seen == r {
                return k;
            }
            seen += 1;
        }
    }
    unreachable!("nth_eligible: fewer eligible batches than counted")
}

/// Batch index containing position `pos` (`pos` must be < Σ batches).
#[inline]
fn batch_of(batches: &[usize], pos: usize) -> usize {
    let mut end = 0usize;
    for (k, &b) in batches.iter().enumerate() {
        end += b;
        if pos < end {
            return k;
        }
    }
    unreachable!("position {pos} beyond schedule")
}

/// Try to move one random job into the previous batch. Returns a move
/// description, or `None` (schedule untouched) if no batch is eligible.
pub fn squeeze_prev_desc(
    s: &mut Schedule,
    max_batch: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    squeeze_prev_desc_masked(s, max_batch, 0, rng)
}

/// [`squeeze_prev_desc`] with the first `frozen_batches` batches frozen:
/// both the source batch and the (previous) target batch must lie beyond
/// the frozen prefix.
pub fn squeeze_prev_desc_masked(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    squeeze_prev_desc_kv(s, max_batch, frozen_batches, None, rng)
}

/// [`squeeze_prev_desc_masked`] with an optional KV-feasibility veto: the
/// move is refused (schedule untouched) if pulling the picked job into the
/// previous batch would push that batch's block occupancy over the pool.
pub fn squeeze_prev_desc_kv(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    squeeze_prev_desc_win(s, max_batch, frozen_batches, 0, kv, rng)
}

/// [`squeeze_prev_desc_kv`] restricted to a sliding window of `window`
/// batches beyond the frozen prefix (0 = unbounded): both the source and
/// the (previous) target batch must lie inside the window. `window == 0`
/// draws the exact RNG stream of [`squeeze_prev_desc_kv`].
pub fn squeeze_prev_desc_win(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    window: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    let m = s.batches.len();
    let hi = if window == 0 { m } else { m.min(frozen_batches + window) };
    // Source k needs an unfrozen target k-1: k ranges over first..hi.
    let first = frozen_batches + 1;
    if hi < first + 1 {
        return None;
    }
    // Eligible batches k >= first with batches[k-1] < max_batch.
    let elig = |k: usize| s.batches[k - 1] < max_batch;
    let count = (first..hi).filter(|&k| elig(k)).count();
    if count == 0 {
        return None;
    }
    let k = nth_eligible(first..hi, rng.below(count), elig);
    let start_k: usize = s.batches[..k].iter().sum();
    // pick a random member of batch k and move it to the end of batch k-1
    let pick = start_k + rng.below(s.batches[k]);
    if let Some(v) = kv {
        let target_members = &s.order[start_k - s.batches[k - 1]..start_k];
        if !v.into_batch_ok(k - 1, target_members, s.order[pick]) {
            return None; // target batch would overcommit the KV pool
        }
    }
    s.order[start_k..=pick].rotate_right(1);
    s.batches[k - 1] += 1;
    s.batches[k] -= 1;
    let removed_batch = if s.batches[k] == 0 {
        s.batches.remove(k);
        Some(k)
    } else {
        None
    };
    Some(AppliedMove {
        b_lo: k - 1,
        b_hi: if removed_batch.is_some() { k - 1 } else { k },
        removed_batch,
        appended_batch: false,
        undo: OrderUndo::RotateLeft { lo: start_k, hi: pick },
    })
}

/// Try to move one random job into the next batch (creating a new final
/// batch when delaying from the last one). Returns `None` (schedule
/// untouched) if nothing can move.
pub fn delay_next_desc(
    s: &mut Schedule,
    max_batch: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    delay_next_desc_masked(s, max_batch, 0, rng)
}

/// [`delay_next_desc`] with the first `frozen_batches` batches frozen: the
/// source batch must lie beyond the frozen prefix (the target batch is
/// always later still).
pub fn delay_next_desc_masked(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    delay_next_desc_kv(s, max_batch, frozen_batches, None, rng)
}

/// [`delay_next_desc_masked`] with an optional KV-feasibility veto: the
/// move is refused (schedule untouched) if pushing the picked job into the
/// next batch would overcommit it (or if the job cannot even hold a
/// singleton batch, when delaying out of the final batch).
pub fn delay_next_desc_kv(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    delay_next_desc_win(s, max_batch, frozen_batches, 0, kv, rng)
}

/// [`delay_next_desc_kv`] restricted to a sliding window of `window`
/// batches beyond the frozen prefix (0 = unbounded): the source batch and
/// its target must lie inside the window, and delaying out of the final
/// batch (opening a fresh iteration) is only possible when the window
/// reaches the schedule's end. `window == 0` draws the exact RNG stream
/// of [`delay_next_desc_kv`].
pub fn delay_next_desc_win(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    window: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    if s.order.is_empty() {
        return None;
    }
    let m = s.batches.len();
    if frozen_batches >= m {
        return None;
    }
    let hi = if window == 0 { m } else { m.min(frozen_batches + window) };
    // Eligible source batches: k with an in-window target k+1 that has
    // room, or the final *schedule* batch — only when the window reaches
    // it — if it holds more than one job (otherwise delaying is a no-op
    // that recreates the same batch). A batch whose target would fall
    // outside the window is ineligible: windowed planning never edits
    // batches the controller has not yet opened for search.
    let elig = |k: usize| {
        if k + 1 < hi {
            s.batches[k + 1] < max_batch
        } else if k + 1 < m {
            false
        } else {
            s.batches[k] > 1
        }
    };
    let count = (frozen_batches..hi).filter(|&k| elig(k)).count();
    if count == 0 {
        return None;
    }
    let k = nth_eligible(frozen_batches..hi, rng.below(count), elig);
    let start_k: usize = s.batches[..k].iter().sum();
    let pick = start_k + rng.below(s.batches[k]);
    if let Some(v) = kv {
        let feasible = if k + 1 < m {
            let next_start = start_k + s.batches[k];
            let target_members =
                &s.order[next_start..next_start + s.batches[k + 1]];
            v.into_batch_ok(k + 1, target_members, s.order[pick])
        } else {
            v.alone_ok(s.order[pick])
        };
        if !feasible {
            return None; // target batch would overcommit the KV pool
        }
    }
    // rotate the picked job to the START of batch k+1's span (the slot at
    // start_k + batches[k] - 1 once the boundary moves)
    let insert_at = start_k + s.batches[k] - 1;
    s.order[pick..=insert_at].rotate_left(1);
    if k + 1 < m {
        s.batches[k] -= 1;
        s.batches[k + 1] += 1;
        let removed_batch = if s.batches[k] == 0 {
            s.batches.remove(k);
            Some(k)
        } else {
            None
        };
        Some(AppliedMove {
            b_lo: k,
            b_hi: if removed_batch.is_some() { k } else { k + 1 },
            removed_batch,
            appended_batch: false,
            undo: OrderUndo::RotateRight { lo: pick, hi: insert_at },
        })
    } else {
        // delaying out of the final (multi-job) batch opens a new iteration
        s.batches[k] -= 1;
        s.batches.push(1);
        Some(AppliedMove {
            b_lo: k,
            b_hi: k + 1,
            removed_batch: None,
            appended_batch: true,
            undo: OrderUndo::RotateRight { lo: pick, hi: insert_at },
        })
    }
}

/// Swap two random positions in the priority sequence. Returns `None` only
/// for schedules with fewer than two jobs.
pub fn rand_swap_desc(s: &mut Schedule, rng: &mut Rng) -> Option<AppliedMove> {
    rand_swap_desc_masked(s, 0, rng)
}

/// [`rand_swap_desc`] with the first `frozen_batches` batches frozen: both
/// swapped positions are sampled from the unfrozen suffix.
pub fn rand_swap_desc_masked(
    s: &mut Schedule,
    frozen_batches: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    rand_swap_desc_kv(s, frozen_batches, None, rng)
}

/// [`rand_swap_desc_masked`] with an optional KV-feasibility veto: the
/// swap is refused (schedule untouched) if exchanging the two jobs would
/// overcommit either batch. Intra-batch swaps never change occupancy and
/// are always allowed.
pub fn rand_swap_desc_kv(
    s: &mut Schedule,
    frozen_batches: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    rand_swap_desc_win(s, frozen_batches, 0, kv, rng)
}

/// [`rand_swap_desc_kv`] restricted to a sliding window of `window`
/// batches beyond the frozen prefix (0 = unbounded): both swapped
/// positions are sampled from the window's order span
/// `[frozen_pos, Σ batches[..hi])`. `window == 0` draws the exact RNG
/// stream of [`rand_swap_desc_kv`].
pub fn rand_swap_desc_win(
    s: &mut Schedule,
    frozen_batches: usize,
    window: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    let n = s.order.len();
    let m = s.batches.len();
    let frozen_pos: usize = s.batches[..frozen_batches.min(m)].iter().sum();
    let win_end = if window == 0 {
        n
    } else {
        s.batches[..m.min(frozen_batches + window)].iter().sum()
    };
    if win_end.saturating_sub(frozen_pos) < 2 {
        return None;
    }
    let free = win_end - frozen_pos;
    let i = frozen_pos + rng.below(free);
    let mut j = frozen_pos + rng.below(free - 1);
    if j >= i {
        j += 1;
    }
    let (lo_pos, hi_pos) = if i < j { (i, j) } else { (j, i) };
    let b_lo = batch_of(&s.batches, lo_pos);
    let b_hi = batch_of(&s.batches, hi_pos);
    if let Some(v) = kv {
        // member spans are only needed by the phased arm; the O(m) span
        // sums are skipped entirely under reserve accounting.
        let (ma, mb): (&[usize], &[usize]) =
            if v.phased.is_some() && b_lo != b_hi {
                let sa = span_start(&s.batches, b_lo);
                let sb = span_start(&s.batches, b_hi);
                (
                    &s.order[sa..sa + s.batches[b_lo]],
                    &s.order[sb..sb + s.batches[b_hi]],
                )
            } else {
                (&[], &[])
            };
        if !v.swap_ok(b_lo, ma, s.order[lo_pos], b_hi, mb, s.order[hi_pos]) {
            return None; // exchange would overcommit a batch's KV pool
        }
    }
    s.order.swap(i, j);
    Some(AppliedMove {
        b_lo,
        b_hi,
        removed_batch: None,
        appended_batch: false,
        undo: OrderUndo::Swap { i, j },
    })
}

/// Apply one randomly-selected move (the `rand(0,1,2)` of Algorithm 1,
/// line 20), retrying with the other moves if the chosen one is infeasible.
/// Returns `None` (schedule untouched) only if no move is possible at all.
pub fn random_move_desc(
    s: &mut Schedule,
    max_batch: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    random_move_desc_masked(s, max_batch, 0, rng)
}

/// [`random_move_desc`] with the first `frozen_batches` batches frozen.
/// Returns `None` (schedule untouched) only if no masked move is possible.
pub fn random_move_desc_masked(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    random_move_desc_kv(s, max_batch, frozen_batches, None, rng)
}

/// [`random_move_desc_masked`] with an optional KV-feasibility veto. A
/// vetoed move family counts as infeasible and the rotation falls through
/// to the next one; `None` is returned (schedule untouched) only when all
/// three fail. With `kv == None` the RNG stream and edits are identical to
/// the plain masked path.
pub fn random_move_desc_kv(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    random_move_desc_win(s, max_batch, frozen_batches, 0, kv, rng)
}

/// [`random_move_desc_kv`] restricted to a sliding window of `window`
/// batches beyond the frozen prefix (0 = unbounded). A move family that
/// has no in-window candidates counts as infeasible and the rotation
/// falls through to the next one. `window == 0` draws the exact RNG
/// stream and produces the exact edits of [`random_move_desc_kv`].
pub fn random_move_desc_win(
    s: &mut Schedule,
    max_batch: usize,
    frozen_batches: usize,
    window: usize,
    kv: Option<&KvVeto>,
    rng: &mut Rng,
) -> Option<AppliedMove> {
    let first = rng.below(3);
    for offset in 0..3 {
        let mv = match (first + offset) % 3 {
            0 => squeeze_prev_desc_win(
                s,
                max_batch,
                frozen_batches,
                window,
                kv,
                rng,
            ),
            1 => delay_next_desc_win(
                s,
                max_batch,
                frozen_batches,
                window,
                kv,
                rng,
            ),
            _ => rand_swap_desc_win(s, frozen_batches, window, kv, rng),
        };
        if mv.is_some() {
            return mv;
        }
    }
    None
}

/// Boolean-returning convenience wrapper over [`squeeze_prev_desc`].
pub fn squeeze_prev(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    squeeze_prev_desc(s, max_batch, rng).is_some()
}

/// Boolean-returning convenience wrapper over [`delay_next_desc`].
pub fn delay_next(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    delay_next_desc(s, max_batch, rng).is_some()
}

/// Boolean-returning convenience wrapper over [`rand_swap_desc`].
pub fn rand_swap(s: &mut Schedule, rng: &mut Rng) -> bool {
    rand_swap_desc(s, rng).is_some()
}

/// Boolean-returning convenience wrapper over [`random_move_desc`].
pub fn random_move(s: &mut Schedule, max_batch: usize, rng: &mut Rng) -> bool {
    random_move_desc(s, max_batch, rng).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn sorted(v: &[usize]) -> Vec<usize> {
        let mut s = v.to_vec();
        s.sort_unstable();
        s
    }

    #[test]
    fn squeeze_moves_job_backward() {
        let mut rng = Rng::new(0);
        let mut s = Schedule { order: vec![0, 1, 2, 3], batches: vec![1, 1, 1, 1] };
        assert!(squeeze_prev(&mut s, 2, &mut rng));
        s.validate(2).unwrap();
        assert_eq!(s.order.len(), 4);
        assert_eq!(s.batches.iter().sum::<usize>(), 4);
        assert_eq!(s.batches.len(), 3); // one batch merged away
    }

    #[test]
    fn squeeze_respects_max_batch() {
        let mut rng = Rng::new(1);
        let mut s = Schedule { order: vec![0, 1, 2, 3], batches: vec![2, 2] };
        assert!(!squeeze_prev(&mut s, 2, &mut rng)); // previous batch full
        assert_eq!(s.batches, vec![2, 2]);
    }

    #[test]
    fn squeeze_single_batch_impossible() {
        let mut rng = Rng::new(2);
        let mut s = Schedule { order: vec![0, 1], batches: vec![2] };
        assert!(!squeeze_prev(&mut s, 4, &mut rng));
    }

    #[test]
    fn delay_from_last_creates_new_batch() {
        let mut rng = Rng::new(3);
        let mut s = Schedule { order: vec![0, 1], batches: vec![2] };
        assert!(delay_next(&mut s, 2, &mut rng));
        s.validate(2).unwrap();
        assert_eq!(s.batches, vec![1, 1]);
    }

    #[test]
    fn delay_singleton_last_batch_refused() {
        let mut rng = Rng::new(4);
        let mut s = Schedule { order: vec![0], batches: vec![1] };
        assert!(!delay_next(&mut s, 4, &mut rng));
        // two batches, next full, last is singleton -> nothing eligible
        let mut s =
            Schedule { order: vec![0, 1], batches: vec![1, 1] };
        assert!(!delay_next(&mut s, 1, &mut rng) || s.validate(1).is_ok());
    }

    #[test]
    fn swap_preserves_multiset() {
        let mut rng = Rng::new(5);
        let mut s = Schedule { order: vec![3, 1, 4, 0, 2], batches: vec![5] };
        let before = sorted(&s.order);
        assert!(rand_swap(&mut s, &mut rng));
        assert_eq!(sorted(&s.order), before);
        assert_ne!(s.order, vec![3, 1, 4, 0, 2]); // a swap always changes order
    }

    #[test]
    fn random_move_always_valid() {
        check("random_move preserves schedule invariants", 300, |rng| {
            let n = 1 + rng.below(12);
            let max_batch = 1 + rng.below(4);
            let mut s = Schedule::fcfs(n, max_batch);
            for _ in 0..30 {
                random_move(&mut s, max_batch, rng);
                s.validate(max_batch).map_err(|e| {
                    format!("n={n} max_batch={max_batch}: {e} ({s:?})")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn moves_reach_different_batch_counts() {
        // SA must be able to both split and merge batches.
        let mut rng = Rng::new(7);
        let mut min_batches = usize::MAX;
        let mut max_batches = 0;
        let mut s = Schedule::fcfs(6, 3);
        for _ in 0..2000 {
            random_move(&mut s, 3, &mut rng);
            min_batches = min_batches.min(s.batches.len());
            max_batches = max_batches.max(s.batches.len());
        }
        assert!(min_batches <= 2, "min {min_batches}");
        assert!(max_batches >= 4, "max {max_batches}");
    }

    #[test]
    fn undo_reverts_every_move_exactly() {
        check("OrderUndo::revert restores the order", 300, |rng| {
            let n = 1 + rng.below(14);
            let max_batch = 1 + rng.below(4);
            let mut s = Schedule::fcfs(n, max_batch);
            // walk to a random state first
            for _ in 0..10 {
                random_move_desc(&mut s, max_batch, rng);
            }
            let before_order = s.order.clone();
            let before_batches = s.batches.clone();
            match random_move_desc(&mut s, max_batch, rng) {
                None => {
                    if s.order != before_order || s.batches != before_batches {
                        return Err("failed move mutated schedule".into());
                    }
                }
                Some(mv) => {
                    s.validate(max_batch)
                        .map_err(|e| format!("after move: {e}"))?;
                    mv.undo.revert(&mut s.order);
                    if s.order != before_order {
                        return Err(format!(
                            "undo mismatch: {:?} != {before_order:?} ({mv:?})",
                            s.order
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn applied_move_reports_touched_batches() {
        // squeeze from batch 1 of [2,2]: batch 0 grows, batch 1 shrinks.
        let mut rng = Rng::new(8);
        let mut s = Schedule { order: vec![0, 1, 2, 3], batches: vec![2, 2] };
        let mv = squeeze_prev_desc(&mut s, 3, &mut rng).unwrap();
        assert_eq!((mv.b_lo, mv.b_hi), (0, 1));
        assert_eq!(mv.removed_batch, None);
        assert!(!mv.appended_batch);
        assert_eq!(s.batches, vec![3, 1]);

        // squeeze from a singleton batch removes it.
        let mut s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
        let mv = squeeze_prev_desc(&mut s, 2, &mut rng).unwrap();
        assert_eq!((mv.b_lo, mv.b_hi), (0, 0));
        assert_eq!(mv.removed_batch, Some(1));
        assert_eq!(s.batches, vec![2]);

        // delay out of the final multi-job batch appends a batch.
        let mut s = Schedule { order: vec![0, 1], batches: vec![2] };
        let mv = delay_next_desc(&mut s, 2, &mut rng).unwrap();
        assert_eq!((mv.b_lo, mv.b_hi), (0, 1));
        assert!(mv.appended_batch);
        assert_eq!(s.batches, vec![1, 1]);
    }

    #[test]
    fn masked_moves_never_touch_frozen_prefix() {
        check("masked moves preserve the frozen prefix", 300, |rng| {
            let n = 1 + rng.below(14);
            let max_batch = 1 + rng.below(4);
            let mut s = Schedule::fcfs(n, max_batch);
            // walk to a random state first
            for _ in 0..10 {
                random_move_desc(&mut s, max_batch, rng);
            }
            let frozen = rng.below(s.batches.len() + 1);
            let frozen_pos: usize = s.batches[..frozen].iter().sum();
            for _ in 0..30 {
                let order_prefix = s.order[..frozen_pos].to_vec();
                let batch_prefix = s.batches[..frozen].to_vec();
                if let Some(mv) =
                    random_move_desc_masked(&mut s, max_batch, frozen, rng)
                {
                    s.validate(max_batch)
                        .map_err(|e| format!("after masked move: {e}"))?;
                    if s.order[..frozen_pos] != order_prefix[..] {
                        return Err(format!(
                            "frozen order changed: {:?} != {order_prefix:?}",
                            &s.order[..frozen_pos]
                        ));
                    }
                    if s.batches[..frozen] != batch_prefix[..] {
                        return Err(format!(
                            "frozen batches changed: {:?} != {batch_prefix:?}",
                            &s.batches[..frozen]
                        ));
                    }
                    if mv.b_lo < frozen {
                        return Err(format!(
                            "move reports frozen batch touched: {mv:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn masked_with_zero_frozen_matches_unmasked_stream() {
        // Same seed, same schedule: frozen = 0 must replay the exact edits
        // of the unmasked path (the online-equals-offline bit-identity).
        let mut a = Schedule::fcfs(9, 3);
        let mut b = Schedule::fcfs(9, 3);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        for _ in 0..200 {
            let ma = random_move_desc(&mut a, 3, &mut rng_a);
            let mb = random_move_desc_masked(&mut b, 3, 0, &mut rng_b);
            assert_eq!(ma, mb);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn fully_frozen_schedule_admits_no_moves() {
        let mut rng = Rng::new(12);
        let mut s = Schedule::fcfs(6, 2);
        let m = s.batches.len();
        let before = s.clone();
        assert!(random_move_desc_masked(&mut s, 2, m, &mut rng).is_none());
        assert_eq!(s, before);
    }

    fn batch_blocks_of(s: &Schedule, job_blocks: &[u64]) -> Vec<u64> {
        let mut out = Vec::with_capacity(s.batches.len());
        let mut start = 0usize;
        for &b in &s.batches {
            out.push(s.order[start..start + b].iter().map(|&j| job_blocks[j]).sum());
            start += b;
        }
        out
    }

    #[test]
    fn kv_none_matches_masked_stream() {
        let mut a = Schedule::fcfs(9, 3);
        let mut b = Schedule::fcfs(9, 3);
        let mut rng_a = Rng::new(31);
        let mut rng_b = Rng::new(31);
        for _ in 0..200 {
            let ma = random_move_desc_masked(&mut a, 3, 0, &mut rng_a);
            let mb = random_move_desc_kv(&mut b, 3, 0, None, &mut rng_b);
            assert_eq!(ma, mb);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn kv_veto_never_overcommits_a_feasible_schedule() {
        check("vetoed moves keep every batch within the pool", 200, |rng| {
            let n = 2 + rng.below(12);
            let max_batch = 1 + rng.below(4);
            let job_blocks: Vec<u64> =
                (0..n).map(|_| 1 + rng.below(5) as u64).collect();
            // pool just big enough that FCFS packing is feasible
            let mut s = Schedule::fcfs(n, max_batch);
            let pool = *batch_blocks_of(&s, &job_blocks).iter().max().unwrap()
                + rng.below(3) as u64;
            for step in 0..60 {
                let bb = batch_blocks_of(&s, &job_blocks);
                if bb.iter().any(|&b| b > pool) {
                    return Err(format!("step {step}: overcommitted {bb:?}"));
                }
                let veto = KvVeto {
                    job_blocks: &job_blocks,
                    batch_blocks: &bb,
                    pool_blocks: pool,
                    phased: None,
                };
                random_move_desc_kv(&mut s, max_batch, 0, Some(&veto), rng);
                s.validate(max_batch)
                    .map_err(|e| format!("step {step}: {e}"))?;
            }
            let bb = batch_blocks_of(&s, &job_blocks);
            if bb.iter().any(|&b| b > pool) {
                return Err(format!("final state overcommitted: {bb:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn kv_veto_refuses_infeasible_squeeze_but_allows_delay() {
        // Two singleton batches of 3 blocks each, pool of 4: squeezing
        // them together (6 blocks) must be vetoed; delaying job 0 out of
        // batch 0 is a no-op candidate set, but a swap stays legal.
        let job_blocks = vec![3u64, 3u64];
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let mut s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
            let bb = batch_blocks_of(&s, &job_blocks);
            let veto = KvVeto {
                job_blocks: &job_blocks,
                batch_blocks: &bb,
                pool_blocks: 4,
                phased: None,
            };
            if let Some(_mv) =
                random_move_desc_kv(&mut s, 2, 0, Some(&veto), &mut rng)
            {
                // only the swap is feasible: batches must stay [1, 1]
                assert_eq!(s.batches, vec![1, 1], "{s:?}");
            }
        }
    }

    #[test]
    fn phased_veto_admits_what_reserve_refuses_and_never_overcommits() {
        use crate::coordinator::request::Slo;
        // job 0: 160 in / 4 out (11 blocks full); job 1: 160 in / 160 out
        // (20 blocks full). Reserve sum 31; phased peak 22 (job 0 frees
        // its blocks after 4 tokens). Pool 22: merging the two singleton
        // batches must be vetoed under reserve and allowed under phased.
        let jobs = vec![
            Job {
                req_idx: 0,
                input_len: 160,
                output_len: 4,
                slo: Slo::E2e { e2e_ms: 1e9 },
            },
            Job {
                req_idx: 1,
                input_len: 160,
                output_len: 160,
                slo: Slo::E2e { e2e_ms: 1e9 },
            },
        ];
        let job_blocks = vec![11u64, 20];
        let phased = PhasedVeto { jobs: &jobs, block_tokens: 16 };
        assert_eq!(phased.peak_with(&[0], 1), 22);
        assert_eq!(phased.peak_with(&[1], 0), 22);
        let mut saw_merge = false;
        let mut rng = Rng::new(9);
        for _ in 0..60 {
            let mut s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
            let bb = batch_blocks_of(&s, &job_blocks);
            // reserve veto refuses the merge outright
            let reserve = KvVeto {
                job_blocks: &job_blocks,
                batch_blocks: &bb,
                pool_blocks: 22,
                phased: None,
            };
            if random_move_desc_kv(&mut s, 2, 0, Some(&reserve), &mut rng)
                .is_some()
            {
                assert_eq!(s.batches, vec![1, 1], "reserve veto leaked: {s:?}");
            }
            // phased veto prices the merged batch at its true 22-block peak
            let mut s = Schedule { order: vec![0, 1], batches: vec![1, 1] };
            let bb = vec![11u64, 20]; // singleton peaks == footprints
            let veto = KvVeto {
                job_blocks: &job_blocks,
                batch_blocks: &bb,
                pool_blocks: 22,
                phased: Some(phased),
            };
            if random_move_desc_kv(&mut s, 2, 0, Some(&veto), &mut rng)
                .is_some()
                && s.batches == vec![2]
            {
                saw_merge = true;
            }
        }
        assert!(saw_merge, "phased veto never allowed the legal merge");
    }

    #[test]
    fn win_zero_matches_kv_stream() {
        // window = 0 must replay the exact edits and RNG stream of the
        // unwindowed path (invariant 15's search-side half).
        let mut a = Schedule::fcfs(9, 3);
        let mut b = Schedule::fcfs(9, 3);
        let mut rng_a = Rng::new(41);
        let mut rng_b = Rng::new(41);
        for _ in 0..200 {
            let ma = random_move_desc_kv(&mut a, 3, 0, None, &mut rng_a);
            let mb =
                random_move_desc_win(&mut b, 3, 0, 0, None, &mut rng_b);
            assert_eq!(ma, mb);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn windowed_moves_stay_inside_window() {
        check("windowed moves never reorder beyond the window", 300, |rng| {
            let n = 1 + rng.below(14);
            let max_batch = 1 + rng.below(4);
            let mut s = Schedule::fcfs(n, max_batch);
            for _ in 0..10 {
                random_move_desc(&mut s, max_batch, rng);
            }
            let frozen = rng.below(s.batches.len() + 1);
            let window = 1 + rng.below(3);
            for _ in 0..30 {
                let m = s.batches.len();
                let hi = m.min(frozen + window);
                let frozen_pos: usize =
                    s.batches[..frozen.min(m)].iter().sum();
                let win_end: usize = s.batches[..hi].iter().sum();
                let prefix = s.order[..frozen_pos.min(s.order.len())].to_vec();
                let suffix = s.order[win_end..].to_vec();
                let tail_batches = s.batches[hi..].to_vec();
                if let Some(mv) = random_move_desc_win(
                    &mut s, max_batch, frozen, window, None, rng,
                ) {
                    s.validate(max_batch)
                        .map_err(|e| format!("after windowed move: {e}"))?;
                    if s.order[..prefix.len()] != prefix[..] {
                        return Err("frozen order changed".into());
                    }
                    if s.order[win_end..] != suffix[..] {
                        return Err(format!(
                            "order beyond window changed: {:?} != {suffix:?}",
                            &s.order[win_end..]
                        ));
                    }
                    // Batches beyond the window keep membership; their
                    // indices shift down by one when a windowed batch is
                    // removed. An append only happens when hi == m.
                    let new_hi = if mv.removed_batch.is_some() {
                        hi - 1
                    } else if mv.appended_batch {
                        hi + 1
                    } else {
                        hi
                    };
                    if mv.appended_batch && hi != m {
                        return Err(format!(
                            "append escaped the window: hi={hi} m={m}"
                        ));
                    }
                    if s.batches[new_hi.min(s.batches.len())..]
                        != tail_batches[..]
                    {
                        return Err(format!(
                            "batches beyond window changed: {:?} != \
                             {tail_batches:?}",
                            &s.batches[new_hi.min(s.batches.len())..]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn window_blocks_delay_escape_and_append() {
        // [2, 2] with window 1: squeeze has no in-window target, delay's
        // target (batch 1) is outside the window and the final-batch
        // append is out of reach, so only intra-window swaps survive and
        // the batch structure is pinned.
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let mut s =
                Schedule { order: vec![0, 1, 2, 3], batches: vec![2, 2] };
            if let Some(mv) =
                random_move_desc_win(&mut s, 4, 0, 1, None, &mut rng)
            {
                assert_eq!(s.batches, vec![2, 2], "{mv:?}");
                assert!(matches!(mv.undo, OrderUndo::Swap { .. }), "{mv:?}");
                assert_eq!(s.order[2..], [2, 3][..], "window leaked: {s:?}");
            }
        }
    }

    #[test]
    fn batch_of_positions() {
        let batches = vec![2, 3, 1];
        assert_eq!(batch_of(&batches, 0), 0);
        assert_eq!(batch_of(&batches, 1), 0);
        assert_eq!(batch_of(&batches, 2), 1);
        assert_eq!(batch_of(&batches, 4), 1);
        assert_eq!(batch_of(&batches, 5), 2);
    }
}
