#!/usr/bin/env bash
# Commit one (or more) bench JSONL rows into perf/TRAJECTORY.jsonl on
# origin/main and prove they landed. Shared by the bench, bench-http,
# and gap CI jobs so the merge/push/verify protocol exists exactly once.
#
#   scripts/trajectory_commit.sh <row-file> <label>
#
# <row-file>: JSONL whose rows to union-merge into the trajectory; must
#             contain a row carrying $GITHUB_SHA (the append step tags
#             every row with the commit it measured).
# <label>:    short job name for commit messages and log lines.
#
# Success REQUIRES the $GITHUB_SHA row to be present on origin/main when
# the script exits — including on the "nothing to commit" path. The
# previous inline version declared victory whenever `git diff --cached
# --quiet` said there was nothing to stage, which is exactly how five
# PRs of bench rows vanished while perf/TRAJECTORY.jsonl sat at 0 bytes:
# an empty merge input produced an empty diff, the step exited 0, and
# the SHA check lived in a separate step that only guarded the happy
# path. Every exit path here re-reads the file from origin/main and
# fails on an *empty* file as well as a missing SHA row.
set -euo pipefail

row_file="${1:?usage: trajectory_commit.sh <row-file> <label>}"
label="${2:?usage: trajectory_commit.sh <row-file> <label>}"
: "${GITHUB_SHA:?GITHUB_SHA must be set}"

# The input must already carry this run's row: failing here separates
# "the append step produced nothing" from "the push lost it".
if ! test -s "$row_file"; then
  echo "FAIL: $label row file '$row_file' is empty — nothing to commit"
  exit 1
fi
if ! grep -q "$GITHUB_SHA" "$row_file"; then
  echo "FAIL: $label row file '$row_file' has no row for $GITHUB_SHA"
  exit 1
fi

git config user.name "github-actions[bot]"
git config user.email \
  "41898282+github-actions[bot]@users.noreply.github.com"

# Retry with an order-preserving union merge so concurrent bench jobs
# never conflict a row away: rebuild on top of the freshest main each
# attempt, dedup committed + new rows.
pushed=0
for attempt in 1 2 3; do
  git fetch origin main
  git reset --hard origin/main
  awk '!seen[$0]++' perf/TRAJECTORY.jsonl "$row_file" \
    > /tmp/trajectory_merged.jsonl
  cp /tmp/trajectory_merged.jsonl perf/TRAJECTORY.jsonl
  git add perf/TRAJECTORY.jsonl
  if git diff --cached --quiet; then
    # Nothing to stage is success ONLY if the row is already committed
    # (e.g. a rerun of this workflow) — never because the merge input
    # was empty. This branch is the old silent-drop bug.
    if grep -q "$GITHUB_SHA" perf/TRAJECTORY.jsonl; then
      echo "$label row for $GITHUB_SHA already committed on main"
      pushed=1
      break
    fi
    echo "FAIL: nothing to commit, yet main has no $label row for" \
      "$GITHUB_SHA — the merge dropped this run's row"
    exit 1
  fi
  git commit -m "ci: append $label result to perf trajectory [skip ci]"
  if git push origin HEAD:main; then
    pushed=1
    break
  fi
  echo "push rejected (concurrent run?), retry ${attempt}"
done
if [ "$pushed" != "1" ]; then
  echo "FAIL: $label row not pushed to main after 3 attempts"
  exit 1
fi

# Prove it landed: re-read from the remote, not the local tree. Both
# checks block — non-empty AND carrying this run's row.
git fetch origin main
git show origin/main:perf/TRAJECTORY.jsonl > /tmp/trajectory_remote.jsonl
if ! test -s /tmp/trajectory_remote.jsonl; then
  echo "FAIL: perf/TRAJECTORY.jsonl on origin/main is empty"
  exit 1
fi
if ! grep -q "$GITHUB_SHA" /tmp/trajectory_remote.jsonl; then
  echo "FAIL: no $label row for $GITHUB_SHA on origin/main — the" \
    "append/commit chain dropped this run's bench result"
  exit 1
fi
echo "OK: $label trajectory row for $GITHUB_SHA is on origin/main"
