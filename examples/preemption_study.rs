//! Preemption & migration study (ISSUE 9): what pool exhaustion costs
//! under σ = 0.5 output-length divergence, and what each recovery
//! mechanism buys back.
//!
//! Every request in the trace is chosen (by id, against the
//! deterministic QuantileTrace divergence head) to overrun its
//! predicted output 2–5×, and the per-instance KV pool is sized so a
//! single context always fits but a planned batch's *true* demand
//! usually doesn't. A two-instance fleet then serves the same trace
//! four ways:
//!
//! * **truncate** — the PR 5 legacy behavior: an overrunning member is
//!   force-stopped at the block boundary (fast, but the tail of every
//!   overrun is silently lost);
//! * **preempt: recompute** — the slackest member suspends and later
//!   re-prefills its whole context;
//! * **preempt: swap** — the victim's KV moves to a modeled host buffer
//!   over an 8 GB/s link and is copied back on resume;
//! * **swap + migrate** — additionally, a saturated instance sheds
//!   decode work to an idle peer's wave queue.
//!
//! The "full out" column is the fraction of completions that produced
//! their full divergent output — the quality axis the attainment/G
//! columns hide (truncation finishes *faster* precisely because it
//! throws work away).
//!
//! All seeds are printed; reruns are bit-identical.
//!
//!     cargo run --release --example preemption_study

use slo_serve::config::profiles::by_name;
use slo_serve::coordinator::kv::KvConfig;
use slo_serve::coordinator::online::{
    run_online_fleet_migrating, run_online_fleet_opts, OnlineOpts,
    ReplanStrategy,
};
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::coordinator::request::{Request, Slo, TaskType};
use slo_serve::engine::sim::{DivergenceModel, PreemptConfig, SimEngine};
use slo_serve::engine::Engine;
use slo_serve::metrics::{fmt, RunMetrics, Table};
use slo_serve::util::rng::Rng;

const SEED: u64 = 42;
const REQUESTS: usize = 40;
const MAX_BATCH: usize = 4;
const INSTANCES: usize = 2;
const BLOCK_TOKENS: usize = 16;
const SIGMA: f64 = 0.5;

fn blocks(tokens: usize) -> usize {
    tokens.div_ceil(BLOCK_TOKENS)
}

/// Ids are searched so every request overruns its nominal output 2–5×
/// under the σ = 0.5 QuantileTrace head (a pure function of the id).
fn overrun_trace(model: &DivergenceModel) -> (Vec<Request>, Vec<usize>) {
    let mut rng = Rng::new(SEED ^ 0x9E_EE);
    let mut used: Vec<u64> = Vec::new();
    let mut probe = Rng::new(0); // QuantileTrace consumes no draws
    let mut t = 0.0f64;
    let requests: Vec<Request> = (0..REQUESTS)
        .map(|i| {
            let input = 32 + 8 * (i % 8);
            let nominal = 8 + 4 * (i % 5);
            let id = (0..1_000_000u64)
                .find(|id| {
                    !used.contains(id) && {
                        let a = model.actual_lo(*id, nominal, &mut probe);
                        a >= 2 * nominal && a <= 5 * nominal
                    }
                })
                .expect("no overrunning id");
            used.push(id);
            t += rng.uniform(20.0, 140.0);
            let mut r = Request::synthetic(
                id,
                if i % 2 == 0 { TaskType::Chat } else { TaskType::Code },
                input,
                nominal,
                Slo::E2e { e2e_ms: 2_500.0 + 150.0 * i as f64 },
            );
            r.arrival_ms = t;
            r
        })
        .collect();
    let outs = requests.iter().map(|r| r.output_len).collect();
    (requests, outs)
}

fn main() -> anyhow::Result<()> {
    let model = DivergenceModel::QuantileTrace { sigma: SIGMA };
    let (trace, outs) = overrun_trace(&model);

    // Pool: the single largest true context plus a one-block growth
    // margin fits, so preemption never deadlocks into truncation — but
    // a 2-4 member batch's true demand exceeds it routinely.
    let mut probe = Rng::new(0);
    let pool = trace
        .iter()
        .map(|r| {
            let a = model.actual_lo(r.id, r.output_len, &mut probe);
            blocks(r.input_len + a.max(r.output_len) + 1)
        })
        .max()
        .unwrap()
        + 2;
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.kv_pool_mb =
        pool as f64 * BLOCK_TOKENS as f64 * profile.mem.mb_per_token;
    let predictor = profile.truth;

    let sa = SaParams {
        max_batch: MAX_BATCH,
        seed: SEED,
        iters_per_temp: 20,
        kv: KvConfig::hard(pool as u64),
        ..Default::default()
    };

    // How much of each request's true output survives, per run.
    let full_output_pct = |completions: &[slo_serve::coordinator::request::Completion]| {
        let mut probe = Rng::new(0);
        let full = completions
            .iter()
            .filter(|c| {
                let r = trace.iter().find(|r| r.id == c.id).unwrap();
                c.generated >= model.actual_lo(r.id, r.output_len, &mut probe)
            })
            .count();
        100.0 * full as f64 / completions.len().max(1) as f64
    };

    println!(
        "== {REQUESTS} requests, every one overrunning its prediction 2-5x \
         (sigma = {SIGMA} quantile-trace), {INSTANCES} instances, \
         {pool}-block pools ==\n"
    );
    let mut t = Table::new(&[
        "mode",
        "attainment",
        "chat",
        "code",
        "G (req/s)",
        "full out %",
        "truncs",
        "preempts",
        "migrations",
    ]);

    let variants: [(&str, PreemptConfig, bool); 4] = [
        ("truncate (PR 5)", PreemptConfig::OFF, false),
        ("preempt: recompute", PreemptConfig::recompute(), false),
        ("preempt: swap 8GB/s", PreemptConfig::swap(8.0, 4096), false),
        ("swap + migrate", PreemptConfig::swap(8.0, 4096), true),
    ];
    for (name, preempt, migrate) in variants {
        let mut engines: Vec<Box<dyn Engine + Send>> = (0..INSTANCES)
            .map(|i| {
                Box::new(
                    SimEngine::new(
                        profile.clone(),
                        MAX_BATCH,
                        SEED ^ ((i as u64) << 8),
                    )
                    .with_divergence(model)
                    .with_preemption(preempt),
                ) as Box<dyn Engine + Send>
            })
            .collect();
        let opts = OnlineOpts {
            arrival_aware: true,
            replan_drift_ms: 150.0,
            migrate,
            ..Default::default()
        };
        let (completions, outcomes) = if migrate {
            run_online_fleet_migrating(
                &trace, &outs, &mut engines, &predictor, &sa,
                ReplanStrategy::Warm, opts,
            )?
        } else {
            run_online_fleet_opts(
                &trace, &outs, &mut engines, &predictor, &sa,
                ReplanStrategy::Warm, opts,
            )?
        };
        let m = RunMetrics::from_completions(&completions);
        let by_task = RunMetrics::attainment_by_task(&completions);
        let att = |name: &str| {
            by_task
                .iter()
                .find(|(tt, _, _)| tt.name() == name)
                .map_or("-".into(), |(_, a, _)| fmt(*a))
        };
        let truncs: usize = engines
            .iter()
            .map(|e| e.preemption_stats().kv_truncations)
            .sum();
        let preempts: usize =
            outcomes.iter().map(|o| o.stats.preemptions).sum();
        let migrations: usize =
            outcomes.iter().map(|o| o.stats.migrations).sum();
        t.row(vec![
            name.into(),
            fmt(m.attainment()),
            att("chat"),
            att("code"),
            fmt(m.g_req_per_s),
            format!("{:.0}", full_output_pct(&completions)),
            truncs.to_string(),
            preempts.to_string(),
            migrations.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\n(truncation \"wins\" latency by discarding the tail of every \
         overrun — its full-output column is the price; preemption \
         serves the complete outputs and pays in attainment, swap \
         cheaper than recompute; migration sheds saturated-instance \
         work to the idle peer)\n\nseeds: trace/search {SEED}; rerun \
         reproduces these numbers bit for bit"
    );
    Ok(())
}
