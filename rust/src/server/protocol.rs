//! JSON-lines wire protocol: request parsing, completion serialization,
//! and the streaming event frames.
//!
//! Reply frames carry an `event` discriminator when they are part of a
//! stream (`admitted` / `token` / `done`); single-reply mode sends the
//! bare completion object for backward compatibility. Rejections use
//! HTTP-style `code`s: 429 saturated (with `retry_after_ms`), 400
//! invalid, 503 shutting down.

use anyhow::{anyhow, Result};

use crate::coordinator::request::{Completion, Request, Slo, TaskType};
use crate::util::json::Json;

/// Parse a `{"op":"generate", …}` message into a [`Request`].
///
/// Either `prompt` (text; its byte length is the input length) or
/// `input_len` (synthetic prompt) must be present. `slo` defaults per task
/// type when omitted (chat → interactive 10 s / 50 ms; code → e2e 30 s).
pub fn parse_generate(
    msg: &Json,
    id: u64,
    max_total_tokens: usize,
) -> Result<Request> {
    let task = match msg.get("task").as_str() {
        Some(name) => TaskType::from_name(name)
            .ok_or_else(|| anyhow!("unknown task '{name}'"))?,
        None => TaskType::Chat,
    };
    let prompt: Option<Vec<u8>> =
        msg.get("prompt").as_str().map(|s| s.as_bytes().to_vec());
    let input_len = match (&prompt, msg.get("input_len").as_usize()) {
        (Some(p), _) => p.len(),
        (None, Some(n)) => n,
        (None, None) => {
            return Err(anyhow!("generate needs 'prompt' or 'input_len'"))
        }
    };
    if input_len == 0 {
        return Err(anyhow!("empty prompt"));
    }
    let max_tokens = msg.get("max_tokens").as_usize().unwrap_or(32).max(1);
    if input_len + max_tokens > max_total_tokens {
        return Err(anyhow!(
            "input_len {input_len} + max_tokens {max_tokens} exceeds cap {max_total_tokens}"
        ));
    }
    let slo = match Slo::from_json(&msg.get("slo")) {
        Some(s) => s,
        None => match task {
            TaskType::Code => Slo::E2e { e2e_ms: 30_000.0 },
            _ => Slo::Interactive { ttft_ms: 10_000.0, tpot_ms: 50.0 },
        },
    };
    Ok(Request {
        id,
        task,
        input_len,
        output_len: max_tokens,
        slo,
        arrival_ms: crate::util::now_ms(),
        prompt,
    })
}

/// Transport-level options a generate message carries beyond the core
/// [`Request`] fields.
#[derive(Debug, Clone, Copy, Default)]
pub struct GenerateOpts {
    /// Session id for consistent shard routing
    /// ([`crate::server::front::session_shard`]); the connection id is
    /// the fallback when absent.
    pub session: Option<u64>,
    /// Stream `admitted`/`token` frames instead of a single reply.
    pub stream: bool,
}

/// Parse the transport options of a generate message (never fails:
/// absent fields fall back to defaults).
pub fn parse_generate_opts(msg: &Json) -> GenerateOpts {
    GenerateOpts {
        session: msg.get("session").as_i64().map(|v| v as u64),
        stream: msg.get("stream").as_bool().unwrap_or(false),
    }
}

/// `{"event":"admitted", …}` stream frame.
pub fn admitted_json(id: u64, shard: usize, queue_ms: f64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("event", Json::str("admitted")),
        ("id", Json::num(id as f64)),
        ("shard", Json::num(shard as f64)),
        ("queue_ms", Json::num(queue_ms)),
    ])
}

/// `{"event":"token", …}` stream frame (one per emitted token).
pub fn token_json(id: u64, index: usize, t_ms: f64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("event", Json::str("token")),
        ("id", Json::num(id as f64)),
        ("index", Json::num(index as f64)),
        ("t_ms", Json::num(t_ms)),
    ])
}

/// Terminal stream frame: the completion object tagged
/// `"event":"done"`.
pub fn done_json(c: &Completion) -> Json {
    let mut v = completion_to_json(c);
    if let Json::Obj(map) = &mut v {
        map.insert("event".into(), Json::str("done"));
    }
    v
}

/// Failure frame/reply for one request.
pub fn failed_json(id: u64, error: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("event", Json::str("failed")),
        ("id", Json::num(id as f64)),
        ("error", Json::str(error)),
    ])
}

/// 429 backpressure rejection with the drain-rate-derived retry hint.
pub fn reject_saturated_json(retry_after_ms: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::num(429.0)),
        ("error", Json::str("saturated")),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
}

/// Generic error reply with an HTTP-style code.
pub fn error_json(code: u32, error: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("code", Json::num(code as f64)),
        ("error", Json::str(error)),
    ])
}

/// Serialize a completion into the reply object.
pub fn completion_to_json(c: &Completion) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(c.id as f64)),
        ("task", Json::str(c.task.name())),
        ("generated", Json::num(c.generated as f64)),
        ("e2e_ms", Json::num(c.e2e_ms)),
        ("ttft_ms", Json::num(c.ttft_ms)),
        ("tpot_ms", Json::num(c.tpot_ms)),
        ("wait_ms", Json::num(c.wait_ms)),
        ("batch_size", Json::num(c.batch_size as f64)),
        ("slo_met", Json::Bool(c.slo_met())),
    ];
    if let Some(text) = &c.text {
        fields.push(("text", Json::str(String::from_utf8_lossy(text))));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate_with_prompt() {
        let msg = Json::parse(
            r#"{"op":"generate","task":"code","prompt":"def f():","max_tokens":16}"#,
        )
        .unwrap();
        let r = parse_generate(&msg, 5, 380).unwrap();
        assert_eq!(r.id, 5);
        assert_eq!(r.task, TaskType::Code);
        assert_eq!(r.input_len, 8);
        assert_eq!(r.output_len, 16);
        assert!(r.slo.prioritizes_e2e()); // code default SLO
        assert_eq!(r.prompt.as_deref(), Some(b"def f():".as_ref()));
    }

    #[test]
    fn parse_generate_with_input_len_and_slo() {
        let msg = Json::parse(
            r#"{"op":"generate","task":"chat","input_len":100,"max_tokens":8,
                "slo":{"kind":"interactive","ttft_ms":500,"tpot_ms":20}}"#,
        )
        .unwrap();
        let r = parse_generate(&msg, 0, 380).unwrap();
        assert_eq!(r.input_len, 100);
        assert_eq!(
            r.slo,
            Slo::Interactive { ttft_ms: 500.0, tpot_ms: 20.0 }
        );
        assert!(r.prompt.is_none());
    }

    #[test]
    fn parse_generate_rejects_bad_input() {
        let over = Json::parse(
            r#"{"op":"generate","input_len":350,"max_tokens":50}"#,
        )
        .unwrap();
        assert!(parse_generate(&over, 0, 380).is_err());
        let none = Json::parse(r#"{"op":"generate"}"#).unwrap();
        assert!(parse_generate(&none, 0, 380).is_err());
        let bad_task =
            Json::parse(r#"{"op":"generate","task":"x","input_len":5}"#)
                .unwrap();
        assert!(parse_generate(&bad_task, 0, 380).is_err());
    }

    #[test]
    fn generate_opts_defaults_and_overrides() {
        let plain = Json::parse(r#"{"op":"generate","input_len":5}"#).unwrap();
        let o = parse_generate_opts(&plain);
        assert_eq!(o.session, None);
        assert!(!o.stream);
        let full = Json::parse(
            r#"{"op":"generate","input_len":5,"session":99,"stream":true}"#,
        )
        .unwrap();
        let o = parse_generate_opts(&full);
        assert_eq!(o.session, Some(99));
        assert!(o.stream);
    }

    #[test]
    fn stream_frames_roundtrip() {
        let a = admitted_json(3, 1, 2.5);
        assert_eq!(a.get("event").as_str(), Some("admitted"));
        assert_eq!(a.get("shard").as_usize(), Some(1));
        let t = token_json(3, 7, 120.0);
        assert_eq!(t.get("event").as_str(), Some("token"));
        assert_eq!(t.get("index").as_usize(), Some(7));
        let r = reject_saturated_json(250);
        assert_eq!(r.get("ok"), &Json::Bool(false));
        assert_eq!(r.get("code").as_i64(), Some(429));
        assert_eq!(r.get("retry_after_ms").as_usize(), Some(250));
        let f = failed_json(4, "boom");
        assert_eq!(f.get("error").as_str(), Some("boom"));
        // every frame parses back from the wire
        for v in [a, t, r, f, error_json(400, "bad")] {
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn done_frame_is_completion_plus_event_tag() {
        let c = Completion {
            id: 1,
            task: TaskType::Code,
            slo: Slo::E2e { e2e_ms: 1000.0 },
            input_len: 10,
            predicted_lo: 5,
            generated: 5,
            e2e_ms: 500.0,
            ttft_ms: 100.0,
            tpot_ms: 8.0,
            wait_ms: 0.0,
            batch_size: 1,
            text: None,
        };
        let v = done_json(&c);
        assert_eq!(v.get("event").as_str(), Some("done"));
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("id").as_i64(), Some(1));
        assert_eq!(v.get("slo_met"), &Json::Bool(true));
    }

    #[test]
    fn completion_roundtrips_to_json() {
        let c = Completion {
            id: 9,
            task: TaskType::Chat,
            slo: Slo::Interactive { ttft_ms: 100.0, tpot_ms: 10.0 },
            input_len: 20,
            predicted_lo: 4,
            generated: 4,
            e2e_ms: 50.0,
            ttft_ms: 30.0,
            tpot_ms: 5.0,
            wait_ms: 2.0,
            batch_size: 2,
            text: Some(b"hello".to_vec()),
        };
        let v = completion_to_json(&c);
        assert_eq!(v.get("ok"), &Json::Bool(true));
        assert_eq!(v.get("id").as_i64(), Some(9));
        assert_eq!(v.get("slo_met"), &Json::Bool(true));
        assert_eq!(v.get("text").as_str(), Some("hello"));
        // parseable end-to-end
        let text = v.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
