//! AOT runtime: load `artifacts/` (HLO text + weights + manifest) and
//! execute TinyLM on the PJRT CPU client.
//!
//! This is the only module that touches the `xla` crate. Flow (see
//! /opt/xla-example/README.md for the interchange gotchas):
//!
//! ```text
//! manifest.json ─┐
//! weights.bin  ──┼─> ModelRuntime::load(dir)
//! *.hlo.txt    ──┘       │ HloModuleProto::from_text_file (HLO TEXT — the
//!                        │ xla_extension 0.5.1 proto parser rejects jax≥0.5
//!                        │ 64-bit instruction ids)
//!                        ▼
//!            PjRtClient::cpu().compile(…)  (lazy, cached per bucket)
//!                        ▼
//!            prefill(tokens)  /  decode_step(caches, tokens, pos)
//! ```
//!
//! Executables are compiled **lazily** per bucket and cached. All results
//! come back as a single tuple buffer (the published `xla` crate cannot
//! split tuple buffers on-device), so KV caches round-trip through host
//! literals; EXPERIMENTS.md §Perf quantifies the copy cost.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Model hyperparameters from the manifest (must match python ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub bos: i32,
    pub eos: i32,
}

/// One prefill bucket (batch × padded sequence length).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillBucket {
    pub batch: usize,
    pub seq: usize,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub spec: ModelSpec,
    /// Parameter (name, shape) in AOT argument order.
    pub params: Vec<(String, Vec<usize>)>,
    pub prefill_buckets: Vec<(PrefillBucket, String)>,
    pub decode_buckets: Vec<(usize, String)>,
    pub weights_file: String,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("manifest.json parse")?;
        let model = v.get("model");
        let req_usize = |node: &Json, key: &str| -> Result<usize> {
            node.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        let spec = ModelSpec {
            vocab: req_usize(model, "vocab")?,
            d_model: req_usize(model, "d_model")?,
            n_layers: req_usize(model, "n_layers")?,
            n_heads: req_usize(model, "n_heads")?,
            head_dim: req_usize(model, "head_dim")?,
            max_seq: req_usize(model, "max_seq")?,
            bos: v.get("tokens").get("bos").as_i64().unwrap_or(256) as i32,
            eos: v.get("tokens").get("eos").as_i64().unwrap_or(257) as i32,
        };
        let params = v
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: params missing"))?
            .iter()
            .map(|p| {
                let name = p.get("name").as_str().unwrap_or("").to_string();
                let shape: Vec<usize> = p
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect();
                (name, shape)
            })
            .collect();
        let prefill_buckets = v
            .get("buckets")
            .get("prefill")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|b| {
                Ok((
                    PrefillBucket {
                        batch: b
                            .get("batch")
                            .as_usize()
                            .ok_or_else(|| anyhow!("bad prefill bucket"))?,
                        seq: b
                            .get("seq")
                            .as_usize()
                            .ok_or_else(|| anyhow!("bad prefill bucket"))?,
                    },
                    b.get("file").as_str().unwrap_or("").to_string(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let decode_buckets = v
            .get("buckets")
            .get("decode")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|b| {
                Ok((
                    b.get("batch")
                        .as_usize()
                        .ok_or_else(|| anyhow!("bad decode bucket"))?,
                    b.get("file").as_str().unwrap_or("").to_string(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        if prefill_buckets.is_empty() || decode_buckets.is_empty() {
            bail!("manifest: empty bucket tables");
        }
        Ok(Manifest {
            spec,
            params,
            prefill_buckets,
            decode_buckets,
            weights_file: v
                .get("weights")
                .as_str()
                .unwrap_or("weights.bin")
                .to_string(),
        })
    }

    /// Smallest prefill bucket covering (batch, seq). None if none fits.
    pub fn pick_prefill(
        &self,
        batch: usize,
        seq: usize,
    ) -> Option<PrefillBucket> {
        self.prefill_buckets
            .iter()
            .map(|(b, _)| *b)
            .filter(|b| b.batch >= batch && b.seq >= seq)
            .min_by_key(|b| (b.batch * b.seq, b.batch))
    }

    /// Smallest decode bucket covering `batch`.
    pub fn pick_decode(&self, batch: usize) -> Option<usize> {
        self.decode_buckets
            .iter()
            .map(|(b, _)| *b)
            .filter(|&b| b >= batch)
            .min()
    }

    pub fn max_prefill_batch(&self) -> usize {
        self.prefill_buckets.iter().map(|(b, _)| b.batch).max().unwrap_or(1)
    }

    pub fn max_prefill_seq(&self) -> usize {
        self.prefill_buckets.iter().map(|(b, _)| b.seq).max().unwrap_or(0)
    }
}

/// A host tensor loaded from the TLMW1 weights container.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Parse the TLMW1 weights container (see python/compile/aot.py).
pub fn parse_weights(bytes: &[u8]) -> Result<Vec<HostTensor>> {
    let mut off = 0usize;
    fn take<'a>(
        bytes: &'a [u8],
        off: &mut usize,
        n: usize,
    ) -> Result<&'a [u8]> {
        if *off + n > bytes.len() {
            bail!("weights: truncated at offset {}", *off);
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    }
    let magic = take(bytes, &mut off, 6)?;
    if magic != b"TLMW1\0" {
        bail!("weights: bad magic {magic:?}");
    }
    let count =
        u32::from_le_bytes(take(bytes, &mut off, 4)?.try_into()?) as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len =
            u32::from_le_bytes(take(bytes, &mut off, 4)?.try_into()?) as usize;
        let name =
            String::from_utf8(take(bytes, &mut off, name_len)?.to_vec())
                .context("weights: non-utf8 tensor name")?;
        let dtype = take(bytes, &mut off, 1)?[0];
        if dtype != 0 {
            bail!("weights: unsupported dtype {dtype}");
        }
        let ndim = take(bytes, &mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(
                take(bytes, &mut off, 4)?.try_into()?,
            ) as usize);
        }
        let n: usize = shape.iter().product();
        let raw = take(bytes, &mut off, 4 * n)?;
        let mut data = Vec::with_capacity(n);
        for chunk in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into()?));
        }
        tensors.push(HostTensor { name, shape, data });
    }
    if off != bytes.len() {
        bail!("weights: {} trailing bytes", bytes.len() - off);
    }
    Ok(tensors)
}

/// Result of one prefill call.
pub struct PrefillResult {
    /// `[batch][vocab]` logits at each row's last *real* position.
    pub last_logits: Vec<Vec<f32>>,
    pub k_caches: xla::Literal,
    pub v_caches: xla::Literal,
    /// Bucket actually executed.
    pub bucket: PrefillBucket,
}

/// Result of one decode step.
pub struct DecodeResult {
    /// `[batch][vocab]` next-token logits per row.
    pub logits: Vec<Vec<f32>>,
    pub k_caches: xla::Literal,
    pub v_caches: xla::Literal,
}

/// The loaded model: PJRT client + weights + lazily-compiled executables.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    /// Weight literals in AOT argument order (host-resident; the execute
    /// API re-uploads per call — see module docs).
    weights: Vec<xla::Literal>,
    prefill_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode_exes: HashMap<usize, xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load manifest + weights from an artifacts directory. Executables are
    /// compiled on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {dir:?}/manifest.json"))?;
        let manifest = Manifest::parse(&manifest_text)?;
        let weight_bytes = std::fs::read(dir.join(&manifest.weights_file))
            .with_context(|| format!("reading {:?}", manifest.weights_file))?;
        let tensors = parse_weights(&weight_bytes)?;
        // Validate against the manifest's parameter table.
        if tensors.len() != manifest.params.len() {
            bail!(
                "weights/manifest mismatch: {} tensors vs {} params",
                tensors.len(),
                manifest.params.len()
            );
        }
        let mut weights = Vec::with_capacity(tensors.len());
        for (t, (name, shape)) in tensors.iter().zip(&manifest.params) {
            if &t.name != name || &t.shape != shape {
                bail!(
                    "weights/manifest mismatch: got {}{:?}, manifest says {}{:?}",
                    t.name,
                    t.shape,
                    name,
                    shape
                );
            }
            weights.push(f32_literal(&t.data, &t.shape)?);
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(ModelRuntime {
            client,
            manifest,
            dir,
            weights,
            prefill_exes: HashMap::new(),
            decode_exes: HashMap::new(),
        })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.manifest.spec
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e}"))
    }

    /// Ensure the prefill executable for a bucket is compiled.
    pub fn ensure_prefill(&mut self, bucket: PrefillBucket) -> Result<()> {
        if self.prefill_exes.contains_key(&(bucket.batch, bucket.seq)) {
            return Ok(());
        }
        let file = self
            .manifest
            .prefill_buckets
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, f)| f.clone())
            .ok_or_else(|| anyhow!("no prefill bucket {bucket:?}"))?;
        let exe = self.compile(&file)?;
        self.prefill_exes.insert((bucket.batch, bucket.seq), exe);
        Ok(())
    }

    /// Ensure the decode executable for a batch bucket is compiled.
    pub fn ensure_decode(&mut self, batch: usize) -> Result<()> {
        if self.decode_exes.contains_key(&batch) {
            return Ok(());
        }
        let file = self
            .manifest
            .decode_buckets
            .iter()
            .find(|(b, _)| *b == batch)
            .map(|(_, f)| f.clone())
            .ok_or_else(|| anyhow!("no decode bucket b{batch}"))?;
        let exe = self.compile(&file)?;
        self.decode_exes.insert(batch, exe);
        Ok(())
    }

    /// Run prefill over token rows (`rows[i]` is row *i*'s prompt tokens).
    /// Rows are right-padded to the selected bucket; `last_logits[i]` is the
    /// logits at `rows[i].len() - 1`.
    pub fn prefill(&mut self, rows: &[Vec<i32>]) -> Result<PrefillResult> {
        let batch = rows.len();
        let max_len = rows.iter().map(|r| r.len()).max().unwrap_or(0);
        if batch == 0 || max_len == 0 {
            bail!("prefill: empty input");
        }
        let bucket =
            self.manifest.pick_prefill(batch, max_len).ok_or_else(|| {
                anyhow!("no prefill bucket for batch={batch} seq={max_len}")
            })?;
        self.ensure_prefill(bucket)?;
        // pad tokens into the bucket
        let mut tokens = vec![0i32; bucket.batch * bucket.seq];
        for (i, row) in rows.iter().enumerate() {
            tokens[i * bucket.seq..i * bucket.seq + row.len()]
                .copy_from_slice(row);
        }
        let tokens_lit = i32_literal(&tokens, &[bucket.batch, bucket.seq])?;
        let exe = &self.prefill_exes[&(bucket.batch, bucket.seq)];
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&tokens_lit);
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("prefill execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("prefill fetch: {e}"))?;
        let mut parts =
            result.to_tuple().map_err(|e| anyhow!("prefill untuple: {e}"))?;
        if parts.len() != 3 {
            bail!("prefill: expected 3 outputs, got {}", parts.len());
        }
        let v_caches = parts.pop().unwrap();
        let k_caches = parts.pop().unwrap();
        let logits = parts.pop().unwrap();
        let vocab = self.manifest.spec.vocab;
        let all = logits
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e}"))?;
        // all: [bucket.batch, bucket.seq, vocab] row-major
        let mut last_logits = Vec::with_capacity(batch);
        for (i, row) in rows.iter().enumerate() {
            let pos = row.len() - 1;
            let base = (i * bucket.seq + pos) * vocab;
            last_logits.push(all[base..base + vocab].to_vec());
        }
        Ok(PrefillResult { last_logits, k_caches, v_caches, bucket })
    }

    /// One decode step at batch bucket `batch` (caches must be that bucket's
    /// shape). `tokens[i]`/`pos[i]` per row; rows beyond the live set should
    /// carry `pos = 0, token = 0` and their logits ignored.
    pub fn decode_step(
        &mut self,
        batch: usize,
        k_caches: &xla::Literal,
        v_caches: &xla::Literal,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<DecodeResult> {
        if tokens.len() != batch || pos.len() != batch {
            bail!("decode: tokens/pos must have length {batch}");
        }
        self.ensure_decode(batch)?;
        let tokens_lit = i32_literal(tokens, &[batch])?;
        let pos_lit = i32_literal(pos, &[batch])?;
        let exe = &self.decode_exes[&batch];
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(k_caches);
        args.push(v_caches);
        args.push(&tokens_lit);
        args.push(&pos_lit);
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("decode execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("decode fetch: {e}"))?;
        let mut parts =
            result.to_tuple().map_err(|e| anyhow!("decode untuple: {e}"))?;
        if parts.len() != 3 {
            bail!("decode: expected 3 outputs, got {}", parts.len());
        }
        let v_caches = parts.pop().unwrap();
        let k_caches = parts.pop().unwrap();
        let logits_lit = parts.pop().unwrap();
        let vocab = self.manifest.spec.vocab;
        let all = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("decode logits: {e}"))?;
        let logits =
            all.chunks(vocab).map(|c| c.to_vec()).collect::<Vec<_>>();
        Ok(DecodeResult { logits, k_caches, v_caches })
    }

    /// Grow prefill caches (bucket batch) to the decode bucket batch size by
    /// zero-padding rows. Caches are `[L, B, max_seq, H, Dh]`.
    pub fn pad_cache_batch(
        &self,
        cache: &xla::Literal,
        from_batch: usize,
        to_batch: usize,
    ) -> Result<xla::Literal> {
        if from_batch == to_batch {
            return Ok(cache.clone());
        }
        let s = &self.manifest.spec;
        let row = s.max_seq * s.n_heads * s.head_dim;
        let data =
            cache.to_vec::<f32>().map_err(|e| anyhow!("cache to_vec: {e}"))?;
        let mut out = vec![0f32; s.n_layers * to_batch * row];
        for l in 0..s.n_layers {
            for b in 0..from_batch.min(to_batch) {
                let src = (l * from_batch + b) * row;
                let dst = (l * to_batch + b) * row;
                out[dst..dst + row].copy_from_slice(&data[src..src + row]);
            }
        }
        f32_literal(
            &out,
            &[s.n_layers, to_batch, s.max_seq, s.n_heads, s.head_dim],
        )
    }
}

/// Build an f32 literal from host data.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )
    .map_err(|e| anyhow!("f32 literal: {e}"))
}

/// Build an i32 literal from host data.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )
    .map_err(|e| anyhow!("i32 literal: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_picks_buckets() {
        let text = r#"{
            "model": {"vocab": 258, "d_model": 128, "n_layers": 4,
                      "n_heads": 4, "head_dim": 32, "max_seq": 384,
                      "rope_theta": 10000.0, "norm_eps": 1e-5},
            "tokens": {"vocab": 258, "bos": 256, "eos": 257},
            "weights": "weights.bin",
            "params": [{"name": "embed", "shape": [258, 128]}],
            "buckets": {
              "prefill": [
                {"batch": 1, "seq": 32, "file": "p1_32"},
                {"batch": 4, "seq": 32, "file": "p4_32"},
                {"batch": 4, "seq": 256, "file": "p4_256"}
              ],
              "decode": [{"batch": 1, "file": "d1"},
                          {"batch": 4, "file": "d4"}]
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.spec.vocab, 258);
        assert_eq!(m.spec.eos, 257);
        assert_eq!(
            m.pick_prefill(1, 20),
            Some(PrefillBucket { batch: 1, seq: 32 })
        );
        assert_eq!(
            m.pick_prefill(2, 32),
            Some(PrefillBucket { batch: 4, seq: 32 })
        );
        assert_eq!(
            m.pick_prefill(3, 100),
            Some(PrefillBucket { batch: 4, seq: 256 })
        );
        assert_eq!(m.pick_prefill(5, 32), None);
        assert_eq!(m.pick_prefill(1, 1000), None);
        assert_eq!(m.pick_decode(1), Some(1));
        assert_eq!(m.pick_decode(2), Some(4));
        assert_eq!(m.pick_decode(9), None);
        assert_eq!(m.max_prefill_batch(), 4);
        assert_eq!(m.max_prefill_seq(), 256);
    }

    #[test]
    fn manifest_rejects_empty() {
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn weights_parser_roundtrip() {
        // hand-build a container with two tensors
        let mut bytes: Vec<u8> = b"TLMW1\0".to_vec();
        bytes.extend(2u32.to_le_bytes());
        for (name, shape, data) in [
            ("a", vec![2usize, 2], vec![1.0f32, 2.0, 3.0, 4.0]),
            ("b.c", vec![3usize], vec![-1.0f32, 0.5, 9.0]),
        ] {
            bytes.extend((name.len() as u32).to_le_bytes());
            bytes.extend(name.as_bytes());
            bytes.push(0); // f32
            bytes.push(shape.len() as u8);
            for d in &shape {
                bytes.extend((*d as u32).to_le_bytes());
            }
            for f in &data {
                bytes.extend(f.to_le_bytes());
            }
        }
        let tensors = parse_weights(&bytes).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(tensors[0].name, "a");
        assert_eq!(tensors[0].shape, vec![2, 2]);
        assert_eq!(tensors[1].data, vec![-1.0, 0.5, 9.0]);
    }

    #[test]
    fn weights_parser_rejects_corruption() {
        assert!(parse_weights(b"BAD").is_err());
        let mut ok: Vec<u8> = b"TLMW1\0".to_vec();
        ok.extend(1u32.to_le_bytes());
        ok.extend(1u32.to_le_bytes());
        ok.extend(b"x");
        ok.push(0);
        ok.push(1);
        ok.extend(4u32.to_le_bytes());
        ok.extend(&[0u8; 8]); // truncated: 4 floats declared, 2 given
        assert!(parse_weights(&ok).is_err());
        // trailing garbage
        let mut t: Vec<u8> = b"TLMW1\0".to_vec();
        t.extend(0u32.to_le_bytes());
        t.push(7);
        assert!(parse_weights(&t).is_err());
    }

    #[test]
    fn literal_builders() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = i32_literal(&[7, -3], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, -3]);
        assert!(f32_literal(&[1.0], &[2]).is_err()); // count mismatch
    }
}
