//! SA scheduler throughput: incremental (prediction table + delta
//! evaluation + zero-alloc moves) vs the full-evaluation reference path,
//! at wave sizes N ∈ {16, 64, 256, 512} — plus a parallel-tempering
//! chains axis (K ∈ {1, 2, 4, 8} at N = 256: wall time and final G per
//! chain count) and a SoA-vs-AoS per-batch reduce microbench.
//!
//! Reports per-mapping wall time and objective evaluations per second for
//! both paths, and writes machine-readable results to
//! `BENCH_sa_throughput.json` (cargo package root) so future PRs can track
//! the perf trajectory.
//!
//!     cargo bench --bench sa_throughput

use slo_serve::bench::time_ms;
use slo_serve::coordinator::objective::{Evaluator, Job};
use slo_serve::coordinator::predictor::LatencyPredictor;
use slo_serve::coordinator::priority::annealing::{
    priority_mapping, priority_mapping_full, SaParams,
};
use slo_serve::coordinator::request::Slo;
use slo_serve::metrics::Table;
use slo_serve::util::json::Json;
use slo_serve::util::rng::Rng;

const MAX_BATCH: usize = 8;
/// SA search seed; recorded in the JSON so CI's regression gate compares
/// reproducible runs (the workload seed per size is `0xBEEF ^ n`).
const SA_SEED: u64 = 7;

/// Mixed wave with SLOs tight enough that the sorted seed never meets them
/// all — the early-exit fast path would otherwise skip the search entirely
/// and the measurement would be meaningless.
fn jobs(n: usize, seed: u64) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let input_len = rng.range(50, 1500) as usize;
            let output_len = rng.range(20, 400) as usize;
            let slo = if i % 10 == 0 {
                // a few unmeetable bounds pin the search away from early exit
                Slo::E2e { e2e_ms: 1.0 }
            } else if rng.chance(0.5) {
                Slo::E2e { e2e_ms: rng.uniform(500.0, 30_000.0) }
            } else {
                Slo::Interactive {
                    ttft_ms: rng.uniform(200.0, 8_000.0),
                    tpot_ms: rng.uniform(10.0, 50.0),
                }
            };
            Job { req_idx: i, input_len, output_len, slo }
        })
        .collect()
}

/// AoS emulation of the evaluator's per-batch aggregates, for the layout
/// microbench only: the production [`slo_serve::coordinator::objective`]
/// store is the SoA this file measures against.
#[derive(Clone, Copy, Default)]
struct BatchAgg {
    bsum: f64,
    bmet: usize,
    bend: f64,
    bkv: u64,
}

/// SoA-vs-AoS reduce microbench: fold ~`m` per-batch aggregates into the
/// objective totals the SA hot path re-reduces after every move, with the
/// aggregates held as an array of structs vs parallel flat columns.
/// Returns (aos_ms, soa_ms) per full reduce pass.
fn reduce_layout_bench(m: usize) -> (f64, f64) {
    let mut rng = Rng::new(0xA05_50A);
    let aos: Vec<BatchAgg> = (0..m)
        .map(|_| BatchAgg {
            bsum: rng.uniform(10.0, 5_000.0),
            bmet: rng.below(9),
            bend: rng.uniform(10.0, 100_000.0),
            bkv: rng.below(4_000) as u64,
        })
        .collect();
    let bsum: Vec<f64> = aos.iter().map(|a| a.bsum).collect();
    let bmet: Vec<usize> = aos.iter().map(|a| a.bmet).collect();
    let bkv: Vec<u64> = aos.iter().map(|a| a.bkv).collect();
    let pool = 2_000u64;

    let reps = 2_000;
    let mut sink = 0.0f64;
    let aos_ms = time_ms(2, 5, || {
        for _ in 0..reps {
            let mut total = 0.0f64;
            let mut met = 0usize;
            let mut excess = 0u64;
            for a in &aos {
                total += a.bsum;
                met += a.bmet;
                excess += a.bkv.saturating_sub(pool);
            }
            sink += total + met as f64 + excess as f64;
        }
    });
    let soa_ms = time_ms(2, 5, || {
        for _ in 0..reps {
            let mut total = 0.0f64;
            for &s in &bsum {
                total += s;
            }
            let mut met = 0usize;
            for &c in &bmet {
                met += c;
            }
            let mut excess = 0u64;
            for &b in &bkv {
                excess += b.saturating_sub(pool);
            }
            sink += total + met as f64 + excess as f64;
        }
    });
    assert!(sink.is_finite()); // keep the folds observable
    (aos_ms / reps as f64, soa_ms / reps as f64)
}

fn main() {
    println!("== SA priority-mapping throughput: incremental vs full eval ==\n");
    let pred = LatencyPredictor::paper_table2();
    let mut t = Table::new(&[
        "N",
        "full (ms)",
        "incremental (ms)",
        "speedup",
        "full evals/s",
        "incremental evals/s",
    ]);
    let mut sizes: Vec<Json> = Vec::new();

    for &n in &[16usize, 64, 256, 512] {
        let jobs_seed = 0xBEEF ^ n as u64;
        let js = jobs(n, jobs_seed);
        let ev = Evaluator::new(&js, &pred);
        let params =
            SaParams { max_batch: MAX_BATCH, seed: SA_SEED, ..Default::default() };

        // deterministic for a fixed seed, so stats come from one dry run
        let res = priority_mapping(&ev, &params);
        assert!(!res.stats.early_exit, "N={n}: early exit would skew timing");
        let evals = res.stats.evals;

        let iters = if n >= 256 { 3 } else { 10 };
        let inc_ms = time_ms(1, iters, || {
            let _ = priority_mapping(&ev, &params);
        });
        let full_ms = time_ms(1, iters, || {
            let _ = priority_mapping_full(&ev, &params);
        });

        let speedup = full_ms / inc_ms;
        let full_eps = evals as f64 / (full_ms / 1e3);
        let inc_eps = evals as f64 / (inc_ms / 1e3);
        t.row(vec![
            n.to_string(),
            format!("{full_ms:.3}"),
            format!("{inc_ms:.3}"),
            format!("{speedup:.1}x"),
            format!("{full_eps:.0}"),
            format!("{inc_eps:.0}"),
        ]);
        sizes.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("jobs_seed", Json::num(jobs_seed as f64)),
            ("sa_evals", Json::num(evals as f64)),
            ("full_ms", Json::num(full_ms)),
            ("incremental_ms", Json::num(inc_ms)),
            ("speedup", Json::num(speedup)),
            ("full_evals_per_s", Json::num(full_eps)),
            ("incremental_evals_per_s", Json::num(inc_eps)),
        ]));
    }
    print!("{}", t.render());

    // Parallel-tempering chains axis at N = 256: deeper search per unit
    // wall time. Each K reports its wall per mapping and the final G the
    // tempered search converges to (same seed, same workload).
    println!("\n== parallel tempering: chains axis (N = 256) ==\n");
    let mut ct = Table::new(&[
        "chains",
        "wall (ms)",
        "final G",
        "evals",
        "exchanges",
        "winner",
    ]);
    let mut chain_rows: Vec<Json> = Vec::new();
    {
        let n = 256usize;
        let jobs_seed = 0xBEEF ^ n as u64;
        let js = jobs(n, jobs_seed);
        let ev = Evaluator::new(&js, &pred);
        for &k in &[1usize, 2, 4, 8] {
            let params = SaParams {
                max_batch: MAX_BATCH,
                seed: SA_SEED,
                chains: k,
                ..Default::default()
            };
            let res = priority_mapping(&ev, &params);
            let wall_ms = time_ms(1, 3, || {
                let _ = priority_mapping(&ev, &params);
            });
            ct.row(vec![
                k.to_string(),
                format!("{wall_ms:.3}"),
                format!("{:.6e}", res.eval.g),
                res.stats.evals.to_string(),
                res.stats.exchanges.to_string(),
                res.stats.winner_chain.to_string(),
            ]);
            chain_rows.push(Json::obj(vec![
                ("chains", Json::num(k as f64)),
                ("wall_ms", Json::num(wall_ms)),
                ("final_g", Json::num(res.eval.g)),
                ("sa_evals", Json::num(res.stats.evals as f64)),
                ("exchanges", Json::num(res.stats.exchanges as f64)),
                ("winner_chain", Json::num(res.stats.winner_chain as f64)),
            ]));
        }
    }
    print!("{}", ct.render());

    // Evaluator layout microbench: the per-move re-reduction over batch
    // aggregates, AoS vs the SoA layout the evaluator actually uses.
    let (aos_reduce_ms, soa_reduce_ms) = reduce_layout_bench(4096);
    let soa_speedup = aos_reduce_ms / soa_reduce_ms;
    println!(
        "\nreduce layout (4096 batches): AoS {:.6} ms, SoA {:.6} ms \
         ({soa_speedup:.2}x)",
        aos_reduce_ms, soa_reduce_ms
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("sa_throughput")),
        ("max_batch", Json::num(MAX_BATCH as f64)),
        ("sa_seed", Json::num(SA_SEED as f64)),
        ("sa_t0", Json::num(SaParams::default().t0)),
        ("sa_iters_per_temp", Json::num(SaParams::default().iters_per_temp as f64)),
        ("sizes", Json::arr(sizes)),
        ("chains", Json::arr(chain_rows)),
        ("aos_reduce_ms", Json::num(aos_reduce_ms)),
        ("soa_reduce_ms", Json::num(soa_reduce_ms)),
        ("soa_speedup", Json::num(soa_speedup)),
    ]);
    let out = format!("{}\n", doc.to_string_pretty());
    std::fs::write("BENCH_sa_throughput.json", out)
        .expect("writing BENCH_sa_throughput.json");
    println!("\nwrote BENCH_sa_throughput.json");
    println!("paths are bit-identical (tests/incremental_eval_equivalence.rs);");
    println!("the speedup is pure hot-path restructuring.");
}
