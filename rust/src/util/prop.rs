//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing case number and seed so the case can be replayed exactly:
//!
//! ```
//! use slo_serve::util::prop::check;
//! check("sum is commutative", 200, |rng| {
//!     let a = rng.range(-1000, 1000);
//!     let b = rng.range(-1000, 1000);
//!     if a + b != b + a { return Err(format!("a={a} b={b}")); }
//!     Ok(())
//! });
//! ```
//!
//! Set `PROP_SEED=<n>` to replay a single failing case, `PROP_CASES=<n>` to
//! override the case count.

use crate::util::rng::Rng;

/// Run `property` over `cases` seeded random cases; panics on first failure
/// with replay instructions. Returns the number of cases run.
pub fn check<F>(name: &str, cases: usize, mut property: F) -> usize
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed_text) = std::env::var("PROP_SEED") {
        let seed: u64 = seed_text.parse().expect("PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed on PROP_SEED={seed}: {msg}");
        }
        return 1;
    }
    let cases = std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        // Per-case seed is deterministic and independent of run order.
        let seed = 0x5EED_0000_0000_0000u64 ^ (case as u64).wrapping_mul(
            0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}/{cases}): {msg}\n\
                 replay with: PROP_SEED={seed}"
            );
        }
    }
    cases
}

/// Generate a random vector with the given length range and element generator.
pub fn vec_of<T>(
    rng: &mut Rng,
    len_range: (usize, usize),
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let (lo, hi) = len_range;
    let len = lo + rng.below(hi - lo + 1);
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let n = check("tautology", 50, |_| Ok(()));
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay with: PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn vec_of_respects_length_range() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let v = vec_of(&mut rng, (2, 5), |r| r.below(10));
            assert!((2..=5).contains(&v.len()));
        }
    }

    #[test]
    fn cases_are_deterministic() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let mut captured = Vec::new();
            check("capture", 3, |rng| {
                captured.push(rng.next_u64());
                Ok(())
            });
            firsts.push(captured);
        }
        assert_eq!(firsts[0], firsts[1]);
    }
}
