//! Integration: TCP JSON-lines server over simulated instances.

use slo_serve::config::profiles::by_name;
use slo_serve::coordinator::policies::Policy;
use slo_serve::coordinator::priority::annealing::SaParams;
use slo_serve::engine::instance::InstanceHandle;
use slo_serve::engine::sim::SimEngine;
use slo_serve::server::{start, Client, ServerConfig};
use slo_serve::util::json::Json;

fn boot(n_instances: usize) -> slo_serve::server::ServerHandle {
    let profile = by_name("qwen7b-v100x2-vllm").unwrap();
    let instances: Vec<InstanceHandle> = (0..n_instances)
        .map(|i| {
            InstanceHandle::spawn(
                i,
                Box::new(SimEngine::new(profile.clone(), 4, i as u64)),
            )
        })
        .collect();
    let cfg = ServerConfig {
        policy: Policy::SloAware(SaParams::with_max_batch(4)),
        predictor: profile.truth,
        window_ms: 10,
        max_batch: 4,
        max_total_tokens: profile.max_total_tokens,
    };
    start(cfg, instances).unwrap()
}

#[test]
fn generate_roundtrip() {
    let server = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client
        .call(
            &Json::parse(
                r#"{"op":"generate","task":"chat","input_len":100,"max_tokens":10}"#,
            )
            .unwrap(),
        )
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
    assert!(reply.get("e2e_ms").as_f64().unwrap() > 0.0);
    assert!(reply.get("ttft_ms").as_f64().unwrap() > 0.0);
    assert_eq!(reply.get("generated").as_usize(), Some(10));
    server.shutdown();
}

#[test]
fn stats_accumulate() {
    let server = boot(2);
    let mut a = Client::connect(server.addr).unwrap();
    let mut b = Client::connect(server.addr).unwrap();
    for client in [&mut a, &mut b] {
        let reply = client
            .call(
                &Json::parse(
                    r#"{"op":"generate","task":"code","input_len":50,"max_tokens":5,
                        "slo":{"kind":"e2e","e2e_ms":60000}}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
    }
    let stats = a.call(&Json::parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.get("served").as_usize(), Some(2));
    assert!(stats.get("attainment").as_f64().unwrap() > 0.0);
    server.shutdown();
}

#[test]
fn malformed_requests_rejected() {
    let server = boot(1);
    let mut client = Client::connect(server.addr).unwrap();
    // bad json
    let reply = client.call(&Json::str("not an op")).unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    // missing fields
    let reply = client
        .call(&Json::parse(r#"{"op":"generate"}"#).unwrap())
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    // unknown op
    let reply = client
        .call(&Json::parse(r#"{"op":"fly"}"#).unwrap())
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    // oversized request
    let reply = client
        .call(
            &Json::parse(
                r#"{"op":"generate","input_len":999999,"max_tokens":10}"#,
            )
            .unwrap(),
        )
        .unwrap();
    assert_eq!(reply.get("ok"), &Json::Bool(false));
    server.shutdown();
}

#[test]
fn concurrent_clients_batched_together() {
    let server = boot(1);
    let addr = server.addr;
    let threads: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.call(
                    &Json::parse(
                        r#"{"op":"generate","task":"chat","input_len":80,"max_tokens":6}"#,
                    )
                    .unwrap(),
                )
                .unwrap()
            })
        })
        .collect();
    let mut max_batch_seen = 0;
    for t in threads {
        let reply = t.join().unwrap();
        assert_eq!(reply.get("ok"), &Json::Bool(true), "{reply}");
        max_batch_seen =
            max_batch_seen.max(reply.get("batch_size").as_usize().unwrap());
    }
    // at least some of the 4 concurrent requests shared a batch
    assert!(max_batch_seen >= 2, "no batching observed");
    server.shutdown();
}
