//! Seeded PRNG + distributions substrate.
//!
//! The `rand` crate is unavailable in this offline environment (DESIGN.md
//! §2), so this module provides the deterministic randomness used across the
//! workload generators, the simulated-annealing search, the simulated
//! engine's latency noise, and the property-test harness.
//!
//! The generator is xoshiro256++ seeded via SplitMix64 — fast, well-mixed,
//! and reproducible across platforms (everything is explicit u64 math).

/// Deterministic, seedable PRNG (xoshiro256++).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a seed; equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-instance / per-request
    /// reproducibility regardless of draw order elsewhere).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free-enough mapping; bias is negligible for
        // the n (< 2^32) used here, but we reject to be exact.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "Rng::range: lo > hi");
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))` — the shape of LLM request-length
    /// distributions in the ShareGPT-family datasets.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.gaussian(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (Poisson-process inter-arrival gaps).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.lognormal(4.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(10);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(11);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
