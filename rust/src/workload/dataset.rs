//! Synthetic dataset generators matching the ShareGPT-family marginals.

use crate::config::SloTargets;
use crate::coordinator::request::{Request, TaskType};
use crate::util::rng::Rng;

/// Length-distribution spec for one task class (log-normal, truncated).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    pub task: TaskType,
    /// log-normal parameters for input length (tokens)
    pub input_mu: f64,
    pub input_sigma: f64,
    /// log-normal parameters for output length (tokens)
    pub output_mu: f64,
    pub output_sigma: f64,
    /// truncation caps (the paper restricts requests to < 2 k tokens)
    pub min_tokens: usize,
    pub max_input: usize,
    pub max_output: usize,
}

impl DatasetSpec {
    /// ShareGPT_Vicuna_unfiltered-like chat traffic: conversational prompts
    /// (median ≈ 90 tokens, heavy tail) with medium responses (median ≈ 200).
    pub fn sharegpt_chat() -> DatasetSpec {
        DatasetSpec {
            task: TaskType::Chat,
            input_mu: 4.5, // e^4.5 ≈ 90
            input_sigma: 1.0,
            output_mu: 5.3, // e^5.3 ≈ 200
            output_sigma: 0.35,
            min_tokens: 4,
            max_input: 1500,
            max_output: 500,
        }
    }

    /// Python-Code-23k-ShareGPT-like code generation: instruction prompts
    /// (median ≈ 150) with long completions (median ≈ 330) — "a code is
    /// useful only when completed".
    pub fn python_code() -> DatasetSpec {
        DatasetSpec {
            task: TaskType::Code,
            input_mu: 5.0, // e^5.0 ≈ 150
            input_sigma: 0.7,
            output_mu: 5.8, // e^5.8 ≈ 330
            output_sigma: 0.3,
            min_tokens: 8,
            max_input: 1500,
            max_output: 500,
        }
    }

    /// Scaled copy fitting a smaller engine (the TinyLM CPU testbed).
    pub fn scaled_to(&self, max_input: usize, max_output: usize) -> DatasetSpec {
        let in_scale = max_input as f64 / self.max_input as f64;
        let out_scale = max_output as f64 / self.max_output as f64;
        DatasetSpec {
            input_mu: self.input_mu + in_scale.ln(),
            output_mu: self.output_mu + out_scale.ln(),
            max_input,
            max_output,
            min_tokens: self.min_tokens.min(max_input / 2).max(1),
            ..*self
        }
    }

    /// Draw (input_len, output_len).
    pub fn sample_lengths(&self, rng: &mut Rng) -> (usize, usize) {
        let draw = |rng: &mut Rng, mu: f64, sigma: f64, cap: usize, min: usize| {
            let v = rng.lognormal(mu, sigma).round() as usize;
            v.clamp(min, cap)
        };
        (
            draw(rng, self.input_mu, self.input_sigma, self.max_input, self.min_tokens),
            draw(rng, self.output_mu, self.output_sigma, self.max_output, 1),
        )
    }
}

/// Builds request waves from dataset specs + SLO targets (the paper's
/// mixed-dataset workflow: equal sampling, tagged by task, shuffled).
#[derive(Debug, Clone)]
pub struct RequestFactory {
    pub chat: DatasetSpec,
    pub code: DatasetSpec,
    pub slos: SloTargets,
    rng: Rng,
    next_id: u64,
}

impl RequestFactory {
    pub fn new(seed: u64, slos: SloTargets) -> RequestFactory {
        RequestFactory {
            chat: DatasetSpec::sharegpt_chat(),
            code: DatasetSpec::python_code(),
            slos,
            rng: Rng::new(seed ^ 0xDA7A_5E7),
            next_id: 0,
        }
    }

    /// Cap lengths for a smaller engine (e.g. TinyLM: ≤ max_total tokens).
    pub fn with_caps(mut self, max_input: usize, max_output: usize) -> Self {
        self.chat = self.chat.scaled_to(max_input, max_output);
        self.code = self.code.scaled_to(max_input, max_output);
        self
    }

    fn make(&mut self, spec_is_code: bool) -> Request {
        let spec = if spec_is_code { self.code } else { self.chat };
        let (input, output) = spec.sample_lengths(&mut self.rng);
        let slo = if spec_is_code {
            self.slos.code_slo()
        } else {
            self.slos.chat_slo()
        };
        let id = self.next_id;
        self.next_id += 1;
        Request::synthetic(id, spec.task, input, output, slo)
    }

    /// The paper's mixed wave: ⌈n/2⌉ code + ⌊n/2⌋ chat, shuffled.
    pub fn mixed_wave(&mut self, n: usize) -> Vec<Request> {
        let mut out: Vec<Request> = (0..n)
            .map(|i| self.make(i < n.div_ceil(2)))
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut order);
        let mut shuffled: Vec<Request> =
            order.into_iter().map(|i| out[i].clone()).collect();
        // ids follow the shuffled arrival order
        for (i, r) in shuffled.iter_mut().enumerate() {
            r.id = i as u64;
        }
        out.clear();
        shuffled
    }

    /// Single-task wave.
    pub fn uniform_wave(&mut self, n: usize, task: TaskType) -> Vec<Request> {
        (0..n).map(|_| self.make(task == TaskType::Code)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_respect_caps() {
        let mut rng = Rng::new(0);
        for spec in [DatasetSpec::sharegpt_chat(), DatasetSpec::python_code()] {
            for _ in 0..2000 {
                let (i, o) = spec.sample_lengths(&mut rng);
                assert!(i >= spec.min_tokens && i <= spec.max_input);
                assert!(o >= 1 && o <= spec.max_output);
            }
        }
    }

    #[test]
    fn chat_and_code_marginals_differ() {
        let mut rng = Rng::new(1);
        let mean = |spec: &DatasetSpec, rng: &mut Rng| {
            let n = 3000;
            let s: usize =
                (0..n).map(|_| spec.sample_lengths(rng).1).sum();
            s as f64 / n as f64
        };
        let chat_out = mean(&DatasetSpec::sharegpt_chat(), &mut rng);
        let code_out = mean(&DatasetSpec::python_code(), &mut rng);
        assert!(
            code_out > chat_out,
            "code outputs ({code_out:.0}) should exceed chat ({chat_out:.0})"
        );
    }

    #[test]
    fn mixed_wave_is_half_and_half() {
        let mut f = RequestFactory::new(7, SloTargets::default());
        let wave = f.mixed_wave(20);
        assert_eq!(wave.len(), 20);
        let code = wave.iter().filter(|r| r.task == TaskType::Code).count();
        assert_eq!(code, 10);
        // ids are arrival-ordered
        for (i, r) in wave.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        // SLO matches task
        for r in &wave {
            match r.task {
                TaskType::Code => assert!(r.slo.prioritizes_e2e()),
                _ => assert!(!r.slo.prioritizes_e2e()),
            }
        }
    }

    #[test]
    fn odd_wave_rounds_up_code() {
        let mut f = RequestFactory::new(3, SloTargets::default());
        let wave = f.mixed_wave(7);
        let code = wave.iter().filter(|r| r.task == TaskType::Code).count();
        assert_eq!(code, 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let gen = |seed| {
            let mut f = RequestFactory::new(seed, SloTargets::default());
            f.mixed_wave(10)
                .iter()
                .map(|r| (r.input_len, r.output_len))
                .collect::<Vec<_>>()
        };
        assert_eq!(gen(5), gen(5));
        assert_ne!(gen(5), gen(6));
    }

    #[test]
    fn scaled_caps_apply() {
        let mut f = RequestFactory::new(11, SloTargets::default())
            .with_caps(200, 60);
        for r in f.mixed_wave(200) {
            assert!(r.input_len <= 200);
            assert!(r.output_len <= 60);
        }
    }
}
