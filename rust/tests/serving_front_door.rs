//! Serving front door integration (ISSUE 7 acceptance):
//!
//! * **invariant 12** — the single-shard, zero-queue replay
//!   (`serve_trace` with `shards == 1`) reproduces `run_online_opts` on
//!   the same recorded trace byte for byte: completions, deterministic
//!   stats counters, final eval, and per-request predictions.
//! * **sharded replay** — deterministic, and the shard partition covers
//!   every request exactly once.
//! * **live door** — a real 2-shard door (threads + bounded queues)
//!   serves every accepted request and reports coherent counters.

use slo_serve::config::profiles::by_name;
use slo_serve::config::{OutputPrediction, SloTargets};
use slo_serve::coordinator::online::{run_online_opts, OnlineStats};
use slo_serve::coordinator::predict_outputs;
use slo_serve::coordinator::profiler::RequestProfiler;
use slo_serve::coordinator::request::{Completion, Request};
use slo_serve::engine::sim::SimEngine;
use slo_serve::engine::Engine;
use slo_serve::server::{
    serve_trace, session_shard, FrontDoor, FrontDoorConfig,
};
use slo_serve::util::rng::Rng;
use slo_serve::workload::dataset::RequestFactory;
use slo_serve::workload::trace::{ArrivalProcess, ClassMix};

fn paper_predictor() -> slo_serve::coordinator::predictor::LatencyPredictor {
    slo_serve::coordinator::predictor::LatencyPredictor::paper_table2()
}

fn poisson_trace(n: usize, seed: u64) -> (Vec<Request>, Vec<usize>) {
    let mut factory =
        RequestFactory::new(seed, SloTargets::default().scaled(0.6));
    let mut trace_rng = Rng::new(seed ^ 0x0411_13E);
    let trace = ClassMix::chat_code(
        n,
        ArrivalProcess::Poisson { rps: 10.0 },
        ArrivalProcess::Poisson { rps: 6.0 },
    )
    .generate(&mut factory, &mut trace_rng);
    let profiler = RequestProfiler::new();
    let mut pred_rng = Rng::new(seed);
    let outs = predict_outputs(
        &trace,
        &profiler,
        OutputPrediction::Oracle { rel_err: 0.0 },
        &mut pred_rng,
        2000,
    );
    (trace, outs)
}

fn noiseless_engine(seed: u64) -> SimEngine {
    let mut profile = by_name("qwen7b-v100x2-vllm").unwrap();
    profile.noise_std = 0.0;
    SimEngine::new(profile, 4, seed)
}

fn door_cfg(shards: usize, seed: u64) -> FrontDoorConfig {
    let mut cfg = FrontDoorConfig::new(paper_predictor(), 4096);
    cfg.shards = shards;
    cfg.sa.max_batch = 4;
    cfg.sa.seed = seed;
    cfg
}

/// Completion equality, bit for bit (f64 fields via `to_bits`).
fn completion_bits(
    c: &Completion,
) -> (u64, usize, usize, usize, u64, u64, u64, u64, usize) {
    (
        c.id,
        c.input_len,
        c.predicted_lo,
        c.generated,
        c.e2e_ms.to_bits(),
        c.ttft_ms.to_bits(),
        c.tpot_ms.to_bits(),
        c.wait_ms.to_bits(),
        c.batch_size,
    )
}

/// The deterministic subset of [`OnlineStats`]: everything except the
/// wall-clock timing accumulators.
#[allow(clippy::type_complexity)]
fn stats_bits(
    s: &OnlineStats,
) -> (usize, usize, usize, usize, usize, usize, usize, u64, usize, u64, usize)
{
    (
        s.admitted,
        s.replans,
        s.budget_replans,
        s.sa_evals,
        s.dispatched_batches,
        s.dispatched_jobs,
        s.drift_replans,
        s.max_abs_drift_ms.to_bits(),
        s.reconciled_jobs,
        s.lo_abs_divergence_sum.to_bits(),
        s.deferrals,
    )
}

/// Invariant 12: `serve_trace` at one shard IS `run_online_opts`.
#[test]
fn single_shard_replay_equals_run_online() {
    for seed in [3u64, 42] {
        let (trace, outs) = poisson_trace(20, seed);
        let cfg = door_cfg(1, seed);

        let mut direct_engine = noiseless_engine(seed);
        let direct = run_online_opts(
            &trace,
            &outs,
            &mut direct_engine,
            &cfg.predictor,
            &cfg.sa,
            cfg.strategy,
            cfg.opts,
        )
        .unwrap();

        let mut engines: Vec<Box<dyn Engine + Send>> =
            vec![Box::new(noiseless_engine(seed))];
        let (completions, outcomes) =
            serve_trace(&cfg, &trace, &outs, &mut engines).unwrap();

        assert_eq!(completions.len(), direct.completions.len());
        for (a, b) in completions.iter().zip(&direct.completions) {
            assert_eq!(
                completion_bits(a),
                completion_bits(b),
                "seed {seed}: completion diverged"
            );
        }
        assert_eq!(outcomes.len(), 1);
        let (shard, outcome) = &outcomes[0];
        assert_eq!(*shard, 0);
        assert_eq!(outcome.seed, direct.seed, "shard 0 runs the base seed");
        assert_eq!(stats_bits(&outcome.stats), stats_bits(&direct.stats));
        assert_eq!(
            outcome.final_eval, direct.final_eval,
            "seed {seed}: final eval diverged"
        );
        assert_eq!(outcome.predicted.len(), direct.predicted.len());
        for (a, b) in outcome.predicted.iter().zip(&direct.predicted) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.wait_ms.to_bits(), b.wait_ms.to_bits());
            assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits());
        }
    }
}

/// Sharded replay: deterministic across runs, and the hash partition
/// covers every request exactly once.
#[test]
fn sharded_replay_deterministic_and_complete() {
    let seed = 11u64;
    let (trace, outs) = poisson_trace(24, seed);
    let run = || {
        let cfg = door_cfg(2, seed);
        let mut engines: Vec<Box<dyn Engine + Send>> = vec![
            Box::new(noiseless_engine(seed)),
            Box::new(noiseless_engine(seed ^ 0xE531_7AB1)),
        ];
        serve_trace(&cfg, &trace, &outs, &mut engines).unwrap()
    };
    let (ca, oa) = run();
    let (cb, ob) = run();

    // complete: merged ids are exactly the trace's ids, each once
    let mut ids: Vec<u64> = ca.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), trace.len(), "every request served once");

    // each shard only saw its own partition
    for (s, outcome) in &oa {
        for c in &outcome.completions {
            assert_eq!(session_shard(c.id, 2), *s);
        }
    }

    // deterministic
    assert_eq!(ca.len(), cb.len());
    for (a, b) in ca.iter().zip(&cb) {
        assert_eq!(completion_bits(a), completion_bits(b));
    }
    assert_eq!(oa.len(), ob.len());
    for ((sa_, a), (sb, b)) in oa.iter().zip(&ob) {
        assert_eq!(sa_, sb);
        assert_eq!(stats_bits(&a.stats), stats_bits(&b.stats));
    }
}

/// Live 2-shard door: every accepted request completes, counters add up.
#[test]
fn live_door_serves_every_accepted_request() {
    let seed = 7u64;
    let mut cfg = door_cfg(2, seed);
    cfg.queue_depth = 64;
    cfg.sa.iters_per_temp = 5;
    let max_total = cfg.max_total_tokens;
    let engines: Vec<Box<dyn Engine + Send>> = (0..2)
        .map(|s| {
            Box::new(noiseless_engine(seed ^ s)) as Box<dyn Engine + Send>
        })
        .collect();
    let door = FrontDoor::start(cfg, engines).unwrap();

    let mut factory =
        RequestFactory::new(seed, SloTargets::default().scaled(10.0));
    let mut handles = Vec::new();
    for (i, r) in factory.mixed_wave(32).into_iter().enumerate() {
        assert!(r.input_len + r.output_len <= max_total);
        handles.push(door.submit(i as u64, r, false).unwrap());
    }
    assert!(door.wait_drained(60_000), "door must drain");
    let d = door.door_stats();
    assert_eq!(d.accepted, 32);
    assert_eq!(d.rejected, 0);
    assert_eq!(d.invalid, 0);
    assert_eq!(d.inflight, 0);
    assert!(d.peak_inflight >= 1);
    assert_eq!(door.served(), 32, "served == accepted");

    // both shards saw traffic (32 sessions hash across 2 shards)
    let shards_hit: std::collections::HashSet<usize> =
        handles.iter().map(|h| h.shard).collect();
    assert_eq!(shards_hit.len(), 2);

    for h in handles {
        let c = h.wait_done().expect("request must complete");
        assert!(c.generated >= 1);
        assert!(c.e2e_ms > 0.0);
    }
    door.shutdown();
    let stats = door.stats_json();
    assert_eq!(stats.get("served").as_usize(), Some(32));
    assert_eq!(stats.get("failed").as_usize(), Some(0));
    assert!(stats.get("attainment").as_f64().unwrap() > 0.0);
    assert!(
        stats.get("admission_ms").get("count").as_usize().unwrap() >= 32
    );
}
