//! Workload generation: synthetic stand-ins for the paper's datasets.
//!
//! The paper mixes two ShareGPT-family datasets 50/50 (§5.1):
//!
//! * **ShareGPT_Vicuna_unfiltered** — chatbot conversations; judged on
//!   TTFT + TPOT.
//! * **Python-Code-23k-ShareGPT** — code generation; judged on e2e latency.
//!
//! The datasets themselves are not redistributable here (DESIGN.md §2); the
//! generators reproduce their *length marginals* — log-normal input/output
//! token lengths with the published medians, truncated to the paper's 2 k
//! cap — which is all the scheduler consumes (task type, lengths, SLO).

pub mod dataset;
pub mod trace;

pub use dataset::{DatasetSpec, RequestFactory};
pub use trace::{finalize_trace, ArrivalProcess, ClassMix, ClassSpec, TraceSpec};
