//! Request model: task types, SLO specifications, lifecycle timestamps.
//!
//! Mirrors the paper's problem formulation (§3.1): every request carries a
//! task type `h_i` (e2e-latency-oriented vs interactivity-oriented) and the
//! corresponding SLO targets; attainment `x_i` is judged per Eq. 7.

use crate::util::json::Json;

/// Application task class. The paper's evaluation mixes two streaming
/// service types (§3.1); `Custom` supports additional classes in configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TaskType {
    /// Chatbot-style interaction (ShareGPT_Vicuna_unfiltered): judged on
    /// TTFT + TPOT.
    Chat,
    /// Code generation (Python-Code-23k-ShareGPT): judged on e2e latency —
    /// "a code is useful only when completed".
    Code,
    /// Config-defined class (id into the workload spec).
    Custom(u8),
}

impl TaskType {
    pub fn name(&self) -> String {
        match self {
            TaskType::Chat => "chat".into(),
            TaskType::Code => "code".into(),
            TaskType::Custom(i) => format!("custom{i}"),
        }
    }

    pub fn from_name(name: &str) -> Option<TaskType> {
        match name {
            "chat" => Some(TaskType::Chat),
            "code" => Some(TaskType::Code),
            other => other
                .strip_prefix("custom")
                .and_then(|i| i.parse().ok())
                .map(TaskType::Custom),
        }
    }
}

/// Per-request service-level objective (all milliseconds).
///
/// `h_i = 1` (e2e-prioritizing) requests use [`Slo::E2e`]; `h_i = 0` use
/// [`Slo::Interactive`] (Eq. 5/7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// End-to-end latency bound: `t_e2e <= e2e_ms`.
    E2e { e2e_ms: f64 },
    /// Interactivity bounds: `t_TTFT <= ttft_ms && t_TPOT <= tpot_ms`.
    Interactive { ttft_ms: f64, tpot_ms: f64 },
}

impl Slo {
    /// `h_i` indicator from Eq. 5.
    pub fn prioritizes_e2e(&self) -> bool {
        matches!(self, Slo::E2e { .. })
    }

    /// Eq. 7: does a measured (e2e, ttft, tpot) triple meet this SLO?
    pub fn met(&self, e2e_ms: f64, ttft_ms: f64, tpot_ms: f64) -> bool {
        match *self {
            Slo::E2e { e2e_ms: bound } => e2e_ms <= bound,
            Slo::Interactive { ttft_ms: tb, tpot_ms: pb } => {
                ttft_ms <= tb && tpot_ms <= pb
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            Slo::E2e { e2e_ms } => Json::obj(vec![
                ("kind", Json::str("e2e")),
                ("e2e_ms", Json::num(e2e_ms)),
            ]),
            Slo::Interactive { ttft_ms, tpot_ms } => Json::obj(vec![
                ("kind", Json::str("interactive")),
                ("ttft_ms", Json::num(ttft_ms)),
                ("tpot_ms", Json::num(tpot_ms)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Option<Slo> {
        match v.get("kind").as_str()? {
            "e2e" => Some(Slo::E2e { e2e_ms: v.get("e2e_ms").as_f64()? }),
            "interactive" => Some(Slo::Interactive {
                ttft_ms: v.get("ttft_ms").as_f64()?,
                tpot_ms: v.get("tpot_ms").as_f64()?,
            }),
            _ => None,
        }
    }
}

/// An inference request as seen by the scheduler.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub task: TaskType,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// True output length (generation stops here or at EOS). The scheduler
    /// must NOT read this — it is ground truth for the engine and for the
    /// oracle output-length predictors in Fig. 9.
    pub output_len: usize,
    pub slo: Slo,
    /// Arrival time on the coordinator clock (ms).
    pub arrival_ms: f64,
    /// Raw prompt bytes for the real engine (None ⇒ synthetic length-only).
    pub prompt: Option<Vec<u8>>,
}

impl Request {
    pub fn synthetic(
        id: u64,
        task: TaskType,
        input_len: usize,
        output_len: usize,
        slo: Slo,
    ) -> Request {
        Request {
            id,
            task,
            input_len,
            output_len,
            slo,
            arrival_ms: 0.0,
            prompt: None,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("task", Json::str(self.task.name())),
            ("input_len", Json::num(self.input_len as f64)),
            ("output_len", Json::num(self.output_len as f64)),
            ("slo", self.slo.to_json()),
            ("arrival_ms", Json::num(self.arrival_ms)),
        ])
    }
}

/// Completion record produced by an engine for a finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub task: TaskType,
    pub slo: Slo,
    pub input_len: usize,
    /// Output length the scheduler planned this request at (its predicted
    /// `l_o`); compare against `generated` — the actual `l_o` — to
    /// measure output-length divergence per request.
    pub predicted_lo: usize,
    /// Tokens actually generated (the actual `l_o`).
    pub generated: usize,
    /// Wall/virtual-clock timings (ms).
    pub e2e_ms: f64,
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    pub wait_ms: f64,
    /// Engine batch size this request was prefilled at (diagnostics).
    pub batch_size: usize,
    /// Generated text for real-engine runs.
    pub text: Option<Vec<u8>>,
}

impl Completion {
    /// Eq. 7 attainment flag.
    pub fn slo_met(&self) -> bool {
        self.slo.met(self.e2e_ms, self.ttft_ms, self.tpot_ms)
    }

    /// Signed actual-minus-predicted output-length divergence (tokens):
    /// positive for overruns, negative for early EOS.
    pub fn lo_divergence(&self) -> i64 {
        self.generated as i64 - self.predicted_lo as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_e2e_judgement() {
        let slo = Slo::E2e { e2e_ms: 100.0 };
        assert!(slo.met(100.0, 999.0, 999.0)); // boundary inclusive
        assert!(!slo.met(100.1, 0.0, 0.0));
        assert!(slo.prioritizes_e2e());
    }

    #[test]
    fn slo_interactive_judgement() {
        let slo = Slo::Interactive { ttft_ms: 10.0, tpot_ms: 1.0 };
        assert!(slo.met(1e9, 10.0, 1.0)); // e2e irrelevant
        assert!(!slo.met(0.0, 10.1, 1.0));
        assert!(!slo.met(0.0, 10.0, 1.1));
        assert!(!slo.prioritizes_e2e());
    }

    #[test]
    fn slo_json_roundtrip() {
        for slo in [
            Slo::E2e { e2e_ms: 30_000.0 },
            Slo::Interactive { ttft_ms: 10_000.0, tpot_ms: 50.0 },
        ] {
            assert_eq!(Slo::from_json(&slo.to_json()), Some(slo));
        }
        assert_eq!(Slo::from_json(&Json::Null), None);
    }

    #[test]
    fn task_type_names_roundtrip() {
        for t in [TaskType::Chat, TaskType::Code, TaskType::Custom(3)] {
            assert_eq!(TaskType::from_name(&t.name()), Some(t));
        }
        assert_eq!(TaskType::from_name("bogus"), None);
    }

    #[test]
    fn completion_attainment() {
        let c = Completion {
            id: 1,
            task: TaskType::Code,
            slo: Slo::E2e { e2e_ms: 50.0 },
            input_len: 10,
            predicted_lo: 8,
            generated: 5,
            e2e_ms: 49.0,
            ttft_ms: 1.0,
            tpot_ms: 1.0,
            wait_ms: 0.0,
            batch_size: 1,
            text: None,
        };
        assert!(c.slo_met());
        assert_eq!(c.lo_divergence(), -3); // 5 generated vs 8 predicted
    }
}
